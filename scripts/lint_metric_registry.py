#!/usr/bin/env python
"""Lint: every metric key written in src/repro must exist in the registry.

The metric contract (engine/api.py) is derived from `repro.obs.registry`;
a module inventing a key inline would ship an unregistered, undocumented
metric that the strict in-memory tracker rejects and the README table
misses. This script AST-scans `src/repro` for static metric writes —

    metrics["key"] = ...            subscript assignment
    metrics.setdefault("key", ...)  contract defaulting
    metrics.update({"key": ...})    bulk merge
    metrics = {"key": ...}          dict-literal rebind

(on any name ending in "metrics") and fails if a constant-string key is
absent from `repro.obs.registry.REGISTRY`. Dynamic keys (`metrics[k]`)
are runtime-checked by the strict tracker instead.

    python scripts/lint_metric_registry.py        # exit 0 = clean
"""
from __future__ import annotations

import ast
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.obs.registry import REGISTRY  # noqa: E402


def _is_metrics_name(node: ast.AST) -> bool:
    return isinstance(node, ast.Name) and node.id.endswith("metrics")


def _const_str(node: ast.AST):
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def scan_file(path: pathlib.Path) -> list:
    """-> [(lineno, key)] for every statically-written metric key."""
    tree = ast.parse(path.read_text(), filename=str(path))
    found = []

    def add(lineno, key):
        if key is not None:
            found.append((lineno, key))

    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                # metrics["key"] = ...
                if isinstance(tgt, ast.Subscript) \
                        and _is_metrics_name(tgt.value):
                    add(node.lineno, _const_str(tgt.slice))
                # metrics = {"key": ...}
                if _is_metrics_name(tgt) and isinstance(node.value, ast.Dict):
                    for k in node.value.keys:
                        add(node.lineno, _const_str(k))
        elif isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in ("setdefault", "update") \
                and _is_metrics_name(node.func.value):
            if node.func.attr == "setdefault" and node.args:
                add(node.lineno, _const_str(node.args[0]))
            elif node.func.attr == "update":
                for arg in node.args:
                    if isinstance(arg, ast.Dict):
                        for k in arg.keys:
                            add(node.lineno, _const_str(k))
    return found


def main() -> int:
    bad = []
    n_writes = 0
    for path in sorted((ROOT / "src" / "repro").rglob("*.py")):
        for lineno, key in scan_file(path):
            n_writes += 1
            if key not in REGISTRY:
                bad.append(f"{path.relative_to(ROOT)}:{lineno}: "
                           f"unregistered metric key {key!r}")
    if bad:
        print("\n".join(bad))
        print(f"\n{len(bad)} unregistered metric write(s); add the key to "
              "src/repro/obs/registry.py or rename it.")
        return 1
    print(f"metric-registry lint: {n_writes} static metric writes, "
          f"all registered ({len(REGISTRY)} keys).")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
