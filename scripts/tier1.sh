#!/usr/bin/env bash
# Tier-1 verify: one invocation, correct PYTHONPATH, from any cwd.
#   ./scripts/tier1.sh            # whole suite
#   ./scripts/tier1.sh tests/test_engine.py -k parity
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
