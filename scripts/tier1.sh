#!/usr/bin/env bash
# Tier-1 verify: one invocation, correct PYTHONPATH, from any cwd.
#   ./scripts/tier1.sh                       # whole suite
#   ./scripts/tier1.sh tests/test_engine.py -k parity
#   ./scripts/tier1.sh --kernels-interpret   # Pallas-vs-oracle lane only
#                                            # (interpret-mode kernel sweep)
#   ./scripts/tier1.sh --service             # multi-host ascent service lane
#                                            # (loopback tests with a spawned
#                                            # server subprocess; hard timeout
#                                            # so a wedged socket can't hang).
#                                            # Runs with REPRO_KERNELS=interpret
#                                            # so the JOB delta-encode kernels
#                                            # execute as Pallas interpret-mode
#                                            # code, covering the delta/resync
#                                            # tests on the kernel path
#   ./scripts/tier1.sh --resident            # bucket-resident lane: fused
#                                            # parity + checkpoint-interop
#                                            # tests with REPRO_FUSED=1, i.e.
#                                            # fused path forced and kernels
#                                            # in Pallas interpret mode on CPU
#   ./scripts/tier1.sh --pool                # multi-client ascent pool lane
#                                            # (N concurrent clients, shared
#                                            # canonical shadow, BUSY/auth
#                                            # hardening, subprocess fleet
#                                            # acceptance) under the same hard
#                                            # timeout + interpret kernels as
#                                            # the --service lane
#   ./scripts/tier1.sh --elastic             # elastic/chaos lane: mesh
#                                            # shrink/grow trajectories,
#                                            # restore-onto-survivors, the
#                                            # remote resize-with-live-pool
#                                            # acceptance test — multi-device
#                                            # subprocesses + a spawned server,
#                                            # so the same hard timeout +
#                                            # interpret kernels as --service
#   ./scripts/tier1.sh --obs                 # observability lane: metric
#                                            # registry lint (no module logs a
#                                            # key outside the registry), then
#                                            # tracker/sink/trace + STATS-frame
#                                            # tests under the same hard
#                                            # timeout + interpret kernels
#   ./scripts/tier1.sh --netchaos            # wire-chaos lane: chaos-proxy
#                                            # soak (every fault kind through
#                                            # the frame-aware proxy), the
#                                            # health/ladder/watchdog units,
#                                            # lockstep bitwise-transparency
#                                            # under transient faults, and the
#                                            # checkpoint-integrity tests —
#                                            # same hard timeout + interpret
#                                            # kernels as --service
#   ./scripts/tier1.sh --guard               # numerics-guard lane: in-step
#                                            # non-finite skip, spike/stale
#                                            # detection, the rho
#                                            # de-escalation ladder,
#                                            # NumericChaos soak + poison-
#                                            # rollback livelock pins, and the
#                                            # guard x lane-ladder interplay
#                                            # test (spawns an ascent server +
#                                            # chaos proxy) — same hard
#                                            # timeout + interpret kernels as
#                                            # --service
#   ./scripts/tier1.sh --all                 # every lane above plus the base
#                                            # suite, sequentially; exits
#                                            # non-zero on the first failing
#                                            # lane (CI meta-entry point)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [[ "${1:-}" == "--kernels-interpret" ]]; then
  shift
  exec python -m pytest -q tests/test_kernels.py "$@"
fi
if [[ "${1:-}" == "--resident" ]]; then
  shift
  exec timeout --signal=TERM --kill-after=30 900 \
    env REPRO_FUSED=1 python -m pytest -q tests/test_fused_update.py \
      -k "matches or resident or interop or resilient" "$@"
fi
if [[ "${1:-}" == "--service" ]]; then
  shift
  exec timeout --signal=TERM --kill-after=30 900 \
    env REPRO_KERNELS=interpret python -m pytest -q tests/test_service.py "$@"
fi
if [[ "${1:-}" == "--pool" ]]; then
  shift
  exec timeout --signal=TERM --kill-after=30 900 \
    env REPRO_KERNELS=interpret python -m pytest -q tests/test_pool.py "$@"
fi
if [[ "${1:-}" == "--elastic" ]]; then
  shift
  exec timeout --signal=TERM --kill-after=30 900 \
    env REPRO_KERNELS=interpret python -m pytest -q tests/test_elastic.py "$@"
fi
if [[ "${1:-}" == "--obs" ]]; then
  shift
  python scripts/lint_metric_registry.py
  exec timeout --signal=TERM --kill-after=30 900 \
    env REPRO_KERNELS=interpret python -m pytest -q tests/test_obs.py "$@"
fi
if [[ "${1:-}" == "--netchaos" ]]; then
  shift
  exec timeout --signal=TERM --kill-after=30 900 \
    env REPRO_KERNELS=interpret python -m pytest -q tests/test_netchaos.py "$@"
fi
if [[ "${1:-}" == "--guard" ]]; then
  shift
  exec timeout --signal=TERM --kill-after=30 900 \
    env REPRO_KERNELS=interpret python -m pytest -q tests/test_guard.py "$@"
fi
if [[ "${1:-}" == "--all" ]]; then
  shift
  # each lane re-enters this script so it keeps its own hard timeout; no
  # exec — the loop must survive to run the next lane
  for lane in "" --kernels-interpret --resident --service --pool \
              --elastic --obs --netchaos --guard; do
    echo "== tier1 lane: ${lane:-base} =="
    if [[ -z "$lane" ]]; then
      "$0" "$@"
    else
      "$0" "$lane" "$@"
    fi
  done
  exit 0
fi
exec python -m pytest -x -q "$@"
