"""Pallas TPU kernel for the RWKV6 ("Finch") wkv recurrence.

The recurrence  S_t = diag(exp(w_t)) S_{t-1} + k_t v_tᵀ ;  y_t = r_t·(S_{t-1}
+ u∘k_t ⊗ v_t)  is sequential in t with per-channel data-dependent decay, so
the MXU-friendly "chunked matmul" form needs exp(-cum) rescaling that
overflows fp32 for realistic decay magnitudes. This kernel instead keeps the
(K, V) state resident in VMEM and walks the sequence in chunks:

* grid (B, H, n_chunks), chunk axis sequential, state (K,V) fp32 in scratch;
* per chunk, r/k/v/w (T,K|V) tiles are loaded once from HBM; the T inner
  steps are VPU rank-1 updates on the VMEM state — HBM traffic is O(S·K)
  instead of O(S·K·V) for a naive per-token implementation.

Oracle: ref.rwkv6_scan_ref (tests sweep shapes/dtypes in interpret mode).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref, s_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, 0, 0].astype(jnp.float32)        # (T, K)
    k = k_ref[0, 0, 0].astype(jnp.float32)        # (T, K)
    v = v_ref[0, 0, 0].astype(jnp.float32)        # (T, V)
    w = w_ref[0, 0, 0].astype(jnp.float32)        # (T, K) log decay (<0)
    u = u_ref[0].astype(jnp.float32)              # (K,)

    def step(t, carry):
        s, y = carry
        rt, kt, vt, wt = r[t], k[t], v[t], w[t]   # (K,),(K,),(V,),(K,)
        kv = kt[:, None] * vt[None, :]            # (K, V) rank-1
        yt = jnp.sum((s + u[:, None] * kv) * rt[:, None], axis=0)  # (V,)
        s = jnp.exp(wt)[:, None] * s + kv
        y = jax.lax.dynamic_update_slice(y, yt[None], (t, 0))
        return s, y

    y0 = jnp.zeros((chunk, v.shape[-1]), jnp.float32)
    s_final, y = jax.lax.fori_loop(0, chunk, step, (s_ref[...], y0))
    s_ref[...] = s_final
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    s_out_ref[0, 0] = s_final                     # final chunk's write wins


def rwkv6_chunked(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                  u: jax.Array, *, chunk: int = 64,
                  init_state: Optional[jax.Array] = None,
                  interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """r,k,w (B,S,H,K); v (B,S,H,V); u (H,K). Returns (y (B,S,H,V), state)."""
    from repro.kernels import ref

    B, S, H, K = r.shape
    V = v.shape[-1]
    if S % chunk != 0 or init_state is not None:
        return ref.rwkv6_scan_ref(r, k, v, w, u, init_state=init_state)
    nc = S // chunk

    def tile(x, d):
        return jnp.moveaxis(x, 2, 1).reshape(B, H, nc, chunk, d)

    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    y, s_final = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, K), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, K), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, V), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, K), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, K), lambda bi, hi, ci: (hi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, V), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, K, V), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, chunk, V), r.dtype),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tile(r, K), tile(k, K), tile(v, V), tile(w, K), u)

    y = jnp.moveaxis(y.reshape(B, H, S, V), 1, 2)
    return y, s_final
