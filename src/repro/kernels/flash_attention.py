"""Pallas TPU flash attention (causal / sliding-window / GQA).

Design (TPU v5e target):
* layout (B, H, S, hd) inside the kernel — contiguous (S, hd) tiles feed the
  MXU directly; the public wrapper transposes from the model's (B, S, H, hd);
* grid (B*H, q_blocks, kv_blocks) with the kv axis innermost and sequential
  ("arbitrary"), carrying the online-softmax state (m, l, acc) in VMEM scratch
  across kv steps;
* BlockSpec tiles: q (block_q, hd), k/v (block_k, hd) — hd is 64...256 for
  every assigned arch, so tiles are (128, 128)-aligned for the MXU with fp32
  accumulation in scratch;
* causal + sliding-window masking via block-level early-out: fully-masked kv
  blocks write nothing and fully-visible blocks skip the mask computation;
* GQA folds the kv-head index in the k/v index_map (no materialized repeat).

Validated against repro.kernels.ref.mha_reference in interpret mode
(tests/test_kernels.py sweeps shapes and dtypes).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               block_q: int, block_k: int, sm_scale: float,
               causal: bool, window: Optional[int], kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    def compute():
        q = q_ref[0].astype(jnp.float32) * sm_scale         # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                    # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        scale = jnp.exp(m_prev - m_new)
        l_new = l_prev * scale + jnp.sum(p, axis=-1)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = (acc_ref[...] * scale[:, None]
                        + jax.lax.dot(p.astype(v.dtype), v))
        m_ref[...] = m_new
        l_ref[...] = l_new

    if causal or window is not None:
        # block-level visibility: skip fully-masked kv blocks
        visible = jnp.asarray(True)
        if causal:
            visible &= k_start <= q_start + block_q - 1
        if window is not None:
            visible &= q_start - (k_start + block_k - 1) < window

        @pl.when(visible)
        def _():
            compute()
    else:
        compute()

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q (B,Sq,H,hd); k/v (B,Sk,K,hd) with K | H. Returns (B,Sq,H,hd)."""
    b, sq, h, hd = q.shape
    sk, n_kv = k.shape[1], k.shape[2]
    assert h % n_kv == 0
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, block_q, sk, block_k)

    qt = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, hd)
    kt = jnp.moveaxis(k, 2, 1).reshape(b * n_kv, sk, hd)
    vt = jnp.moveaxis(v, 2, 1).reshape(b * n_kv, sk, hd)
    group = h // n_kv

    grid = (b * h, sq // block_q, sk // block_k)

    kernel = functools.partial(
        _fa_kernel, block_q=block_q, block_k=block_k,
        sm_scale=1.0 / math.sqrt(hd), causal=causal, window=window, kv_len=sk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qi, ki: (bh // group, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qi, ki: (bh // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # running max m
            pltpu.VMEM((block_q,), jnp.float32),       # running sum l
            pltpu.VMEM((block_q, hd), jnp.float32),    # output accumulator
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)

    return jnp.moveaxis(out.reshape(b, h, sq, hd), 1, 2)
