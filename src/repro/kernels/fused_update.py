"""Pallas TPU kernels for the fused weight-space epilogue.

The per-step epilogue — clip scale, weight decay, momentum/Adam, lr scale,
apply — runs as ~6-10 per-leaf jnp passes in the unfused path, each
re-streaming every parameter element through HBM. These kernels collapse the
whole optimizer tail into ONE pass per dtype bucket: read (w, g, state),
write (w', state'), everything else lives in VMEM registers.

  sgd_epilogue     w' = w - lr * d,  d = nesterov/momentum(clip*g + wd*w)
  adamw_epilogue   w' = w - lr * ((mu'/c1)/(sqrt(nu'/c2)+eps) + wd*w)
  fused_axpy       out = y + alpha * x          (the SAM perturbation axpy)
  fused_dot_norms  (<a,b>, ||a||^2, ||b||^2)    (AsyncSAM ascent refresh)
  delta_amax       max|p - s + e|               (JOB-delta int8 scale probe)
  delta_encode_i8  q = int8((p-s+e)/scale); s' = s + scale*q; e' = d - scale*q
                   (the remote lane's delta+quantize JOB encoding: one read
                   pass over the resident param / shadow / residual buckets
                   instead of per-leaf host-side tree walks)

Scalar operands (clip scale, lr, bias corrections) enter through SMEM;
static hyperparameters (momentum, betas, weight decay) are baked into the
kernel. All accumulation is fp32 regardless of operand dtype; mixed-dtype
operand pairs (bf16 params + fp32 gradient/state buckets) are supported.
Chunks follow kernels.sam_perturb: (8,128)-lane-aligned 1-D blocks, padded.
The jnp oracles live in kernels.ref (tests/test_kernels.py sweeps both).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.sam_perturb import CHUNK, _pad_flat

_VEC = pl.BlockSpec((CHUNK,), lambda i: (i,))
_PART = pl.BlockSpec((1,), lambda i: (i,))
_SCAL = pl.BlockSpec(memory_space=pltpu.SMEM)


def _f32(ref):
    return ref[...].astype(jnp.float32)


# ---------------------------------------------------------------------------
# axpy: out = y + alpha * x
# ---------------------------------------------------------------------------

def _axpy_kernel(scale_ref, x_ref, y_ref, out_ref):
    out_ref[...] = (_f32(y_ref) + scale_ref[0] * _f32(x_ref)).astype(out_ref.dtype)


def fused_axpy(alpha, x_flat: jax.Array, y_flat: jax.Array, *,
               interpret: bool = False) -> jax.Array:
    """Single-pass  y + alpha * x  over flat vectors; output dtype = y's."""
    x, n = _pad_flat(x_flat)
    y, _ = _pad_flat(y_flat)
    n_chunks = y.shape[0] // CHUNK
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1)
    out = pl.pallas_call(
        _axpy_kernel,
        grid=(n_chunks,),
        in_specs=[_SCAL, _VEC, _VEC],
        out_specs=_VEC,
        out_shape=jax.ShapeDtypeStruct(y.shape, y_flat.dtype),
        interpret=interpret,
    )(alpha, x, y)
    return out[:n]


# ---------------------------------------------------------------------------
# dot + both squared norms, one pass
# ---------------------------------------------------------------------------

def _dot_norms_kernel(a_ref, b_ref, dot_ref, aa_ref, bb_ref):
    a = _f32(a_ref)
    b = _f32(b_ref)
    dot_ref[0] = jnp.sum(a * b)
    aa_ref[0] = jnp.sum(a * a)
    bb_ref[0] = jnp.sum(b * b)


def fused_dot_norms(a_flat: jax.Array, b_flat: jax.Array, *,
                    interpret: bool = False
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(<a,b>, ||a||^2, ||b||^2) with fp32 chunk partials summed outside."""
    a, _ = _pad_flat(a_flat)
    b, _ = _pad_flat(b_flat)
    n_chunks = a.shape[0] // CHUNK
    part = jax.ShapeDtypeStruct((n_chunks,), jnp.float32)
    dot, aa, bb = pl.pallas_call(
        _dot_norms_kernel,
        grid=(n_chunks,),
        in_specs=[_VEC, _VEC],
        out_specs=[_PART, _PART, _PART],
        out_shape=[part, part, part],
        interpret=interpret,
    )(a, b)
    return jnp.sum(dot), jnp.sum(aa), jnp.sum(bb)


# ---------------------------------------------------------------------------
# JOB-delta encoding: amax probe + quantize/shadow/residual in one pass
# ---------------------------------------------------------------------------

def _delta_amax_kernel(p_ref, s_ref, e_ref, out_ref):
    d = _f32(p_ref) - _f32(s_ref) + _f32(e_ref)
    out_ref[0] = jnp.max(jnp.abs(d))


def delta_amax(p_flat: jax.Array, s_flat: jax.Array, e_flat: jax.Array, *,
               interpret: bool = False) -> jax.Array:
    """max |p - s + e| (fp32 chunk partials, final max outside).

    The scale probe for the int8 JOB-delta encoding: one read pass over the
    params bucket, its shadow, and the error-feedback residual.
    """
    p, _ = _pad_flat(p_flat)     # zero padding is |.|-neutral
    s, _ = _pad_flat(s_flat)
    e, _ = _pad_flat(e_flat)
    n_chunks = p.shape[0] // CHUNK
    partials = pl.pallas_call(
        _delta_amax_kernel,
        grid=(n_chunks,),
        in_specs=[_VEC, _VEC, _VEC],
        out_specs=_PART,
        out_shape=jax.ShapeDtypeStruct((n_chunks,), jnp.float32),
        interpret=interpret,
    )(p, s, e)
    return jnp.max(partials)


def _delta_i8_kernel(scale_ref, p_ref, s_ref, e_ref, q_out, s_out, e_out):
    scale = scale_ref[0]
    s = _f32(s_ref)
    d = _f32(p_ref) - s + _f32(e_ref)
    q = jnp.clip(jnp.round(d / scale), -127, 127)
    recon = q * scale
    q_out[...] = q.astype(jnp.int8)
    s_out[...] = s + recon
    e_out[...] = d - recon


def delta_encode_i8(p_flat: jax.Array, s_flat: jax.Array, e_flat: jax.Array,
                    scale, *, interpret: bool = False
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-pass int8 delta encode: (q, shadow', residual').

    Reads (p, s, e) once and writes the int8 payload plus the advanced fp32
    shadow/residual buckets; `scale` is a traced scalar (SMEM). The oracle is
    ref.delta_encode_i8_flat_jnp; the shadow advance is exactly
    `q.astype(f32) * f32(scale)` so the server's numpy apply reconstructs the
    same fp32 shadow.
    """
    p, n = _pad_flat(p_flat)
    s, _ = _pad_flat(s_flat)
    e, _ = _pad_flat(e_flat)
    n_chunks = p.shape[0] // CHUNK
    scale = jnp.asarray(scale, jnp.float32).reshape(1)
    q, s_new, e_new = pl.pallas_call(
        _delta_i8_kernel,
        grid=(n_chunks,),
        in_specs=[_SCAL, _VEC, _VEC, _VEC],
        out_specs=[_VEC, _VEC, _VEC],
        out_shape=[jax.ShapeDtypeStruct(p.shape, jnp.int8),
                   jax.ShapeDtypeStruct(p.shape, jnp.float32),
                   jax.ShapeDtypeStruct(p.shape, jnp.float32)],
        interpret=interpret,
    )(scale, p, s, e)
    return q[:n], s_new[:n], e_new[:n]


# ---------------------------------------------------------------------------
# SGD-family epilogue: clip-wd-momentum-lr-apply in one pass
# ---------------------------------------------------------------------------

def _sgd_kernel(scal_ref, w_ref, g_ref, m_ref, w_out, m_out, *,
                momentum, nesterov, weight_decay):
    w = _f32(w_ref)
    u = _f32(g_ref) * scal_ref[0]
    if weight_decay:
        u = u + weight_decay * w
    m = momentum * _f32(m_ref) + u
    d = momentum * m + u if nesterov else m
    w_out[...] = (w - scal_ref[1] * d).astype(w_out.dtype)
    m_out[...] = m


def _sgd_kernel_nomom(scal_ref, w_ref, g_ref, w_out, *, weight_decay):
    w = _f32(w_ref)
    u = _f32(g_ref) * scal_ref[0]
    if weight_decay:
        u = u + weight_decay * w
    w_out[...] = (w - scal_ref[1] * u).astype(w_out.dtype)


def sgd_epilogue(w_flat: jax.Array, g_flat: jax.Array, m_flat, clip_scale, lr,
                 *, momentum: float = 0.0, nesterov: bool = False,
                 weight_decay: float = 0.0, interpret: bool = False):
    """One-pass SGD tail. Returns (w', m') — m' is None when momentum == 0.

    `clip_scale` and `lr` are traced scalars (SMEM); `momentum`, `nesterov`
    and `weight_decay` are static and baked into the kernel.
    """
    w, n = _pad_flat(w_flat)
    g, _ = _pad_flat(g_flat)
    n_chunks = w.shape[0] // CHUNK
    scal = jnp.stack([jnp.asarray(clip_scale, jnp.float32),
                      jnp.asarray(lr, jnp.float32)])
    if momentum:
        m, _ = _pad_flat(m_flat)
        w_new, m_new = pl.pallas_call(
            functools.partial(_sgd_kernel, momentum=momentum,
                              nesterov=nesterov, weight_decay=weight_decay),
            grid=(n_chunks,),
            in_specs=[_SCAL, _VEC, _VEC, _VEC],
            out_specs=[_VEC, _VEC],
            out_shape=[jax.ShapeDtypeStruct(w.shape, w_flat.dtype),
                       jax.ShapeDtypeStruct(w.shape, jnp.float32)],
            interpret=interpret,
        )(scal, w, g, m)
        return w_new[:n], m_new[:n]
    w_new = pl.pallas_call(
        functools.partial(_sgd_kernel_nomom, weight_decay=weight_decay),
        grid=(n_chunks,),
        in_specs=[_SCAL, _VEC, _VEC],
        out_specs=_VEC,
        out_shape=jax.ShapeDtypeStruct(w.shape, w_flat.dtype),
        interpret=interpret,
    )(scal, w, g)
    return w_new[:n], None


# ---------------------------------------------------------------------------
# AdamW-family epilogue: clip-adam-wd-lr-apply in one pass
# ---------------------------------------------------------------------------

def _adam_kernel(scal_ref, w_ref, g_ref, mu_ref, nu_ref,
                 w_out, mu_out, nu_out, *, b1, b2, eps, weight_decay):
    w = _f32(w_ref)
    g = _f32(g_ref) * scal_ref[0]
    mu = b1 * _f32(mu_ref) + (1.0 - b1) * g
    nu = b2 * _f32(nu_ref) + (1.0 - b2) * g * g
    upd = (mu / scal_ref[2]) / (jnp.sqrt(nu / scal_ref[3]) + eps)
    if weight_decay:
        upd = upd + weight_decay * w
    w_out[...] = (w - scal_ref[1] * upd).astype(w_out.dtype)
    mu_out[...] = mu
    nu_out[...] = nu


def adamw_epilogue(w_flat: jax.Array, g_flat: jax.Array, mu_flat: jax.Array,
                   nu_flat: jax.Array, clip_scale, lr, c1, c2, *,
                   b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                   weight_decay: float = 0.0, interpret: bool = False):
    """One-pass AdamW tail. Returns (w', mu', nu').

    `clip_scale`, `lr` and the bias corrections `c1 = 1-b1^t`, `c2 = 1-b2^t`
    are traced scalars (SMEM); betas/eps/weight_decay are static.
    """
    w, n = _pad_flat(w_flat)
    g, _ = _pad_flat(g_flat)
    mu, _ = _pad_flat(mu_flat)
    nu, _ = _pad_flat(nu_flat)
    n_chunks = w.shape[0] // CHUNK
    scal = jnp.stack([jnp.asarray(clip_scale, jnp.float32),
                      jnp.asarray(lr, jnp.float32),
                      jnp.asarray(c1, jnp.float32),
                      jnp.asarray(c2, jnp.float32)])
    w_new, mu_new, nu_new = pl.pallas_call(
        functools.partial(_adam_kernel, b1=b1, b2=b2, eps=eps,
                          weight_decay=weight_decay),
        grid=(n_chunks,),
        in_specs=[_SCAL, _VEC, _VEC, _VEC, _VEC],
        out_specs=[_VEC, _VEC, _VEC],
        out_shape=[jax.ShapeDtypeStruct(w.shape, w_flat.dtype),
                   jax.ShapeDtypeStruct(w.shape, jnp.float32),
                   jax.ShapeDtypeStruct(w.shape, jnp.float32)],
        interpret=interpret,
    )(scal, w, g, mu, nu)
    return w_new[:n], mu_new[:n], nu_new[:n]
