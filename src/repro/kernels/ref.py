"""Pure-jnp reference oracles for every kernel in repro.kernels.

These are the ground truth the Pallas kernels are validated against
(tests/test_kernels.py sweeps shapes/dtypes with assert_allclose) and the
implementation used on CPU — including the 512-device dry-run, where the
Mosaic TPU backend is unavailable. They are written to be FLOP-equivalent to
the kernels so the roofline compute term is meaningful on either path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def _gqa_expand(k: jax.Array, n_heads: int) -> jax.Array:
    """(B,S,K,hd) -> (B,S,H,hd) by repeating kv heads for GQA."""
    n_kv = k.shape[-2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=-2)


def mha_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  q_offset: int | jax.Array = 0,
                  kv_valid_len: Optional[jax.Array] = None) -> jax.Array:
    """Naive materialized attention. q (B,Sq,H,hd); k/v (B,Sk,K,hd).

    `q_offset`: absolute position of q[0] (decode: pos). `kv_valid_len`: number
    of valid cache entries (decode masking).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kx = _gqa_expand(k, h).astype(jnp.float32)
    vx = _gqa_expand(v, h).astype(jnp.float32)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kx)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    qpos = jnp.arange(sq) + q_offset          # (Sq,)
    kpos = jnp.arange(sk)                     # (Sk,)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    if kv_valid_len is not None:
        mask &= kpos[None, :] < kv_valid_len
    scores = jnp.where(mask[None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vx)
    return out.astype(q.dtype)


def flash_attention_jnp(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        kv_block: int = 512) -> jax.Array:
    """Online-softmax (flash) attention as a kv-block lax.scan.

    Memory is O(Sq * kv_block) instead of O(Sq * Sk); this is the path the
    512-device dry-run lowers (prefill_32k would otherwise materialize
    multi-TB score tensors). FLOP-equivalent to mha_reference up to masked
    blocks.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    if sk % kv_block != 0:
        return mha_reference(q, k, v, causal=causal, window=window)
    n_blocks = sk // kv_block
    n_kv = k.shape[2]
    g = h // n_kv
    hd_v = v.shape[-1]                       # may differ from qk dim (MLA)
    qg = q.reshape(b, sq, n_kv, g, hd).astype(jnp.float32)
    qg = qg / jnp.sqrt(jnp.float32(hd))
    kb = k.reshape(b, n_blocks, kv_block, n_kv, hd)
    vb = v.reshape(b, n_blocks, kv_block, n_kv, hd_v)
    qpos = jnp.arange(sq)

    def body(carry, inputs):
        m, l, acc = carry
        blk_idx, kblk, vblk = inputs
        kpos = blk_idx * kv_block + jnp.arange(kv_block)
        # grouped GQA: contract per kv head without materializing the repeat
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kblk.astype(jnp.float32))
        mask = jnp.ones((sq, kv_block), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + jnp.sum(p, axis=-1)
        acc_new = acc * scale[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, n_kv, g, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, n_kv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, n_kv, g, sq, hd_v), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (jnp.arange(n_blocks), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
    out = acc / jnp.maximum(l[..., None], 1e-30)            # (B,K,G,Sq,hdv)
    out = jnp.moveaxis(out.reshape(b, h, sq, hd_v), 1, 2)   # -> (B,Sq,H,hdv)
    return out.astype(q.dtype)


def decode_attention_jnp(q: jax.Array, k: jax.Array, v: jax.Array,
                         valid_len: jax.Array, *,
                         window: Optional[int] = None) -> jax.Array:
    """Single-position attention over a (possibly seq-sharded) KV cache.

    q (B,1,H,hd); k/v (B,S_max,K,hd). Reductions over S_max lower to partial
    reduce + psum under pjit when the cache's seq dim is sharded (flash-decode
    pattern, DESIGN.md §5).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    n_kv = k.shape[2]
    g = h // n_kv
    qg = q.reshape(b, sq, n_kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))
    kpos = jnp.arange(sk)
    mask = kpos[None, :] < valid_len                      # (1, Sk)
    if window is not None:
        mask &= kpos[None, :] > valid_len - 1 - window
    s = jnp.where(mask[None, None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# SAM perturbation (fused axpy-normalize) reference
# ---------------------------------------------------------------------------

def sam_perturb_flat_jnp(w: jax.Array, g: jax.Array, rho: jax.Array,
                         sq_norm: jax.Array) -> jax.Array:
    """w + rho * g / sqrt(sq_norm) over flat fp32 vectors."""
    scale = rho / (jnp.sqrt(sq_norm) + 1e-12)
    return w + scale * g


def sq_norm_jnp(g: jax.Array) -> jax.Array:
    return jnp.sum(jnp.square(g.astype(jnp.float32)))


def axpy_flat_jnp(alpha, x: jax.Array, y: jax.Array) -> jax.Array:
    """y + alpha * x (fp32 accumulation, y's dtype out)."""
    return (y.astype(jnp.float32)
            + jnp.asarray(alpha, jnp.float32) * x.astype(jnp.float32)
            ).astype(y.dtype)


def dot_norms_flat_jnp(a: jax.Array, b: jax.Array
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(<a,b>, ||a||^2, ||b||^2) in fp32."""
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    return jnp.sum(a32 * b32), jnp.sum(a32 * a32), jnp.sum(b32 * b32)


def delta_amax_flat_jnp(p: jax.Array, s: jax.Array, e: jax.Array) -> jax.Array:
    """max |p - s + e| over flat vectors (fp32) — the int8 delta scale probe.

    `p` is the current params bucket (native dtype), `s` the fp32 shadow of
    the last-synced params, `e` the fp32 error-feedback residual.
    """
    d = p.astype(jnp.float32) - s.astype(jnp.float32) + e.astype(jnp.float32)
    return jnp.max(jnp.abs(d))


def delta_encode_i8_flat_jnp(p: jax.Array, s: jax.Array, e: jax.Array, scale
                             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for kernels.fused_update.delta_encode_i8.

    One pass over (p, s, e): quantize the error-corrected delta
    d = p - s + e to int8 at `scale`, advance the shadow by the *quantized*
    value (so client and server shadows stay in lockstep), and carry the
    quantization error forward:

        q  = clip(round(d / scale), -127, 127)
        s' = s + scale * q
        e' = d - scale * q

    Returns (q int8, s' fp32, e' fp32). All arithmetic in fp32; the shadow
    update uses exactly `q.astype(f32) * f32(scale)` so the receiver's numpy
    reconstruction is bit-compatible.
    """
    scale = jnp.asarray(scale, jnp.float32)
    d = p.astype(jnp.float32) - s.astype(jnp.float32) + e.astype(jnp.float32)
    q = jnp.clip(jnp.round(d / scale), -127, 127).astype(jnp.int8)
    recon = q.astype(jnp.float32) * scale
    return q, s.astype(jnp.float32) + recon, d - recon


def sgd_epilogue_flat_jnp(w: jax.Array, g: jax.Array, m, clip_scale, lr, *,
                          momentum: float = 0.0, nesterov: bool = False,
                          weight_decay: float = 0.0):
    """Oracle for kernels.fused_update.sgd_epilogue: (w', m'-or-None)."""
    w32 = w.astype(jnp.float32)
    u = g.astype(jnp.float32) * jnp.asarray(clip_scale, jnp.float32)
    if weight_decay:
        u = u + weight_decay * w32
    lr = jnp.asarray(lr, jnp.float32)
    if not momentum:
        return (w32 - lr * u).astype(w.dtype), None
    m_new = momentum * m.astype(jnp.float32) + u
    d = momentum * m_new + u if nesterov else m_new
    return (w32 - lr * d).astype(w.dtype), m_new


def adamw_epilogue_flat_jnp(w: jax.Array, g: jax.Array, mu: jax.Array,
                            nu: jax.Array, clip_scale, lr, c1, c2, *,
                            b1: float = 0.9, b2: float = 0.999,
                            eps: float = 1e-8, weight_decay: float = 0.0):
    """Oracle for kernels.fused_update.adamw_epilogue: (w', mu', nu')."""
    w32 = w.astype(jnp.float32)
    g32 = g.astype(jnp.float32) * jnp.asarray(clip_scale, jnp.float32)
    mu_new = b1 * mu.astype(jnp.float32) + (1.0 - b1) * g32
    nu_new = b2 * nu.astype(jnp.float32) + (1.0 - b2) * jnp.square(g32)
    upd = ((mu_new / jnp.asarray(c1, jnp.float32))
           / (jnp.sqrt(nu_new / jnp.asarray(c2, jnp.float32)) + eps))
    if weight_decay:
        upd = upd + weight_decay * w32
    w_new = (w32 - jnp.asarray(lr, jnp.float32) * upd).astype(w.dtype)
    return w_new, mu_new, nu_new


# ---------------------------------------------------------------------------
# Mamba2 (SSD) reference: sequential scan
# ---------------------------------------------------------------------------

def mamba2_scan_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                    c: jax.Array, d: jax.Array,
                    init_state: Optional[jax.Array] = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Sequential SSD recurrence (oracle for the chunked kernel).

    x  (B,S,H,P)   input per head
    dt (B,S,H)     softplus'd timestep
    a  (H,)        negative decay rate (A = -exp(a_log))
    b  (B,S,G,N)   input gate (G groups broadcast over heads)
    c  (B,S,G,N)   output gate
    d  (H,)        skip
    returns y (B,S,H,P), final state (B,H,P,N)
    """
    B, S, H, P = x.shape
    G = b.shape[2]
    N = b.shape[3]
    rep = H // G
    bb = jnp.repeat(b, rep, axis=2).astype(jnp.float32)      # (B,S,H,N)
    cc = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * a[None, None, :])                  # (B,S,H)  a<0

    def step(h_prev, inp):
        xt, bt, ct, dk, dtt = inp                            # (B,H,P),(B,H,N),...
        h_new = h_prev * dk[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", xt * dtt[..., None], bt)
        y = jnp.einsum("bhpn,bhn->bhp", h_new, ct)
        return h_new, y

    h0 = (jnp.zeros((B, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(bb, 1, 0), jnp.moveaxis(cc, 1, 0),
          jnp.moveaxis(decay, 1, 0), jnp.moveaxis(dtf, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + xf * d[None, None, :, None]
    return y.astype(x.dtype), h_final


def mamba2_chunked_jnp(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                       c: jax.Array, d: jax.Array, chunk: int = 128,
                       init_state: Optional[jax.Array] = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD: intra-chunk dense (MXU-friendly) + inter-chunk carry.

    Same math as mamba2_scan_ref; this is the jnp mirror of the Pallas kernel's
    blocking strategy and the training path used on CPU/dry-run.
    """
    B, S, H, P = x.shape
    if S % chunk != 0:
        return mamba2_scan_ref(x, dt, a, b, c, d, init_state)
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    nc = S // chunk
    bb = jnp.repeat(b, rep, axis=2).astype(jnp.float32).reshape(B, nc, chunk, H, N)
    cc = jnp.repeat(c, rep, axis=2).astype(jnp.float32).reshape(B, nc, chunk, H, N)
    xf = (x.astype(jnp.float32)
          * dt.astype(jnp.float32)[..., None]).reshape(B, nc, chunk, H, P)  # dt-scaled input
    dtc = dt.astype(jnp.float32).reshape(B, nc, chunk, H)
    la = dtc * a[None, None, None, :]                        # log decay per step (<0)
    cum = jnp.cumsum(la, axis=2)                             # (B,nc,chunk,H)
    total = cum[:, :, -1]                                    # (B,nc,H)

    # Intra-chunk: y_intra[t] = sum_{s<=t} exp(cum[t]-cum[s]) * (C_t . B_s) * x_s
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,nc,T,Sc,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    seg = jnp.where(tri[None, None, :, :, None], seg, -jnp.inf)
    gmat = jnp.exp(seg)                                      # decay matrix
    cb = jnp.einsum("bntHm,bnsHm->bntsH", cc, bb)            # (B,nc,T,Sc,H)
    y_intra = jnp.einsum("bntsH,bntsH,bnsHp->bntHp", cb, gmat, xf)

    # Chunk states: state_n = sum_s exp(total - cum[s]) * B_s x_s
    sdecay = jnp.exp(total[:, :, None, :] - cum)             # (B,nc,Sc,H)
    chunk_state = jnp.einsum("bnsHm,bnsH,bnsHp->bnHpm", bb, sdecay, xf)

    # Inter-chunk recurrence over nc chunks
    def carry_fn(h_prev, inp):
        st, tot = inp                                        # (B,H,P,N), (B,H)
        h_new = h_prev * jnp.exp(tot)[..., None, None] + st
        return h_new, h_prev

    h0 = (jnp.zeros((B, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    h_final, h_prevs = jax.lax.scan(
        carry_fn, h0, (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(total, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                    # (B,nc,H,P,N) entering states

    # Contribution of the entering state to each position
    y_inter = jnp.einsum("bntHm,bntH,bnHpm->bntHp", cc, jnp.exp(cum), h_prevs)
    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + x.astype(jnp.float32) * d[None, None, :, None]
    return y.astype(x.dtype), h_final


# ---------------------------------------------------------------------------
# RWKV6 (Finch) reference: sequential wkv scan
# ---------------------------------------------------------------------------

def rwkv6_scan_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                   u: jax.Array, init_state: Optional[jax.Array] = None
                   ) -> tuple[jax.Array, jax.Array]:
    """RWKV6 recurrence with data-dependent decay.

    r,k,w (B,S,H,K); v (B,S,H,V); u (H,K) bonus. w is the *log* decay (<0).
      y_t   = (S_{t-1} + (u ⊙ k_t) ⊗ v_t)ᵀ r_t
      S_t   = diag(exp(w_t)) S_{t-1} + k_t ⊗ v_t
    returns y (B,S,H,V), final state (B,H,K,V).
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    wf = w.astype(jnp.float32)

    def step(s_prev, inp):
        rt, kt, vt, wt = inp                                  # (B,H,K),(B,H,V),(B,H,K)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s_prev + u[None, :, :, None] * kv)
        s_new = jnp.exp(wt)[..., None] * s_prev + kv
        return s_new, y

    s0 = (jnp.zeros((B, H, K, V), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    xs = (jnp.moveaxis(rf, 1, 0), jnp.moveaxis(kf, 1, 0),
          jnp.moveaxis(vf, 1, 0), jnp.moveaxis(wf, 1, 0))
    s_final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), s_final
