"""Pallas TPU kernels for the SAM perturbation:  w + rho * g / ||g||.

At pod scale the perturbation touches every parameter element twice per step
(read w, read g, write w_hat) on top of the optimizer update. Fusing the
norm-scale-axpy into two single-pass kernels halves the HBM traffic of the
perturb path versus the unfused jnp composition (norm reduce + scalar bcast +
mul + add each re-streaming the tensors):

  kernel 1 (sq_norm): grid over 1-D chunks, partial sum-of-squares per chunk
      (fp32 accumulation), final scalar sum outside (one tiny reduce);
  kernel 2 (perturb): grid over the same chunks, out = w + (rho/sqrt(n)) * g,
      with the precomputed scale entering through SMEM.

Chunks are (8, 128)-lane aligned. The jnp oracle is ref.sam_perturb_flat_jnp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 64 * 1024  # fp32 elements per grid step: 256 KiB VMEM per operand


def _pad_flat(x: jax.Array) -> tuple[jax.Array, int]:
    n = x.shape[0]
    padded = (n + CHUNK - 1) // CHUNK * CHUNK
    if padded != n:
        x = jnp.pad(x, (0, padded - n))
    return x, n


def _sq_norm_kernel(g_ref, out_ref):
    g = g_ref[...].astype(jnp.float32)
    out_ref[0] = jnp.sum(g * g)


def sq_norm(g_flat: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Sum of squares of a flat vector (partial per chunk, summed outside)."""
    g, _ = _pad_flat(g_flat)
    n_chunks = g.shape[0] // CHUNK
    partials = pl.pallas_call(
        _sq_norm_kernel,
        grid=(n_chunks,),
        in_specs=[pl.BlockSpec((CHUNK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_chunks,), jnp.float32),
        interpret=interpret,
    )(g)
    return jnp.sum(partials)


def _perturb_kernel(scale_ref, w_ref, g_ref, out_ref):
    scale = scale_ref[0]
    out_ref[...] = (w_ref[...].astype(jnp.float32)
                    + scale * g_ref[...].astype(jnp.float32)
                    ).astype(out_ref.dtype)


def sam_perturb(w_flat: jax.Array, g_flat: jax.Array, rho, sq_norm_val, *,
                interpret: bool = False) -> jax.Array:
    """Fused w + rho * g / sqrt(sq_norm) over flat vectors (single HBM pass)."""
    w, n = _pad_flat(w_flat)
    g, _ = _pad_flat(g_flat)
    n_chunks = w.shape[0] // CHUNK
    scale = (jnp.asarray(rho, jnp.float32)
             / (jnp.sqrt(jnp.asarray(sq_norm_val, jnp.float32)) + 1e-12))
    out = pl.pallas_call(
        _perturb_kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),        # scalar scale
            pl.BlockSpec((CHUNK,), lambda i: (i,)),
            pl.BlockSpec((CHUNK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((CHUNK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(w.shape, w_flat.dtype),
        interpret=interpret,
    )(scale.reshape(1), w, g)
    return out[:n]
