"""TPU Pallas kernels (flash attention, SAM perturb, Mamba2 SSD, RWKV6 wkv).

Models call through repro.kernels.ops which dispatches TPU->Pallas,
CPU/dry-run->the jnp mirrors in repro.kernels.ref.
"""
from repro.kernels import ops, ref  # noqa: F401
