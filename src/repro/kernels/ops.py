"""Kernel dispatch layer: TPU -> Pallas, CPU/dry-run -> jnp reference.

Models call these entry points only; the backend choice is per-call overridable
(`impl=`) and defaults to the platform: the Mosaic kernels on TPU, the
FLOP-equivalent jnp paths everywhere else (including the 512-fake-device CPU
dry-run, which cannot lower TPU Pallas). `interpret=True` Pallas execution is
reserved for the correctness tests.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref

_FORCED_IMPL: Optional[str] = None  # test hook: "jnp" | "pallas" | "pallas_interpret"


def set_default_impl(impl: Optional[str]) -> None:
    global _FORCED_IMPL
    _FORCED_IMPL = impl


def _resolve(impl: Optional[str]) -> str:
    if impl is not None:
        return impl
    if _FORCED_IMPL is not None:
        return _FORCED_IMPL
    platform = jax.default_backend()
    return "pallas" if platform == "tpu" else "jnp"


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    impl: Optional[str] = None) -> jax.Array:
    """Blocked attention. q (B,Sq,H,hd); k/v (B,Sk,K,hd) with GQA K<=H."""
    mode = _resolve(impl)
    if mode == "jnp":
        return ref.flash_attention_jnp(q, k, v, causal=causal, window=window)
    from repro.kernels import flash_attention as fa
    return fa.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=(mode == "pallas_interpret"))


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid_len: jax.Array, *, window: Optional[int] = None,
                     impl: Optional[str] = None) -> jax.Array:
    """One-token attention over a KV cache (flash-decode combine under pjit)."""
    mode = _resolve(impl)
    # decode is bandwidth-bound and already lowers to partial-reduce + psum on
    # sharded caches; the jnp path is used on all platforms unless profiling
    # shows a kernel win (EXPERIMENTS §Perf).
    del mode
    return ref.decode_attention_jnp(q, k, v, valid_len, window=window)


def mamba2_mix(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
               c: jax.Array, d: jax.Array, *, chunk: int = 128,
               init_state: Optional[jax.Array] = None,
               impl: Optional[str] = None) -> tuple[jax.Array, jax.Array]:
    """Mamba2/SSD sequence mixing. Returns (y, final_state)."""
    mode = _resolve(impl)
    if mode == "jnp":
        return ref.mamba2_chunked_jnp(x, dt, a, b, c, d, chunk=chunk,
                                      init_state=init_state)
    from repro.kernels import mamba2_scan as m2
    return m2.mamba2_chunked(x, dt, a, b, c, d, chunk=chunk,
                             init_state=init_state,
                             interpret=(mode == "pallas_interpret"))


def mamba2_decode_step(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                       c: jax.Array, d: jax.Array,
                       state: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Single-token SSD update (serving): state (B,H,P,N)."""
    y, new_state = ref.mamba2_scan_ref(x, dt, a, b, c, d, init_state=state)
    return y, new_state


def rwkv6_mix(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array, *, init_state: Optional[jax.Array] = None,
              impl: Optional[str] = None) -> tuple[jax.Array, jax.Array]:
    """RWKV6 wkv recurrence. Returns (y, final_state)."""
    mode = _resolve(impl)
    if mode == "jnp":
        return ref.rwkv6_scan_ref(r, k, v, w, u, init_state=init_state)
    from repro.kernels import rwkv6_scan as r6
    return r6.rwkv6_chunked(r, k, v, w, u, init_state=init_state,
                            interpret=(mode == "pallas_interpret"))


def sam_perturb(w_flat: jax.Array, g_flat: jax.Array, rho, sq_norm, *,
                impl: Optional[str] = None) -> jax.Array:
    """Fused  w + rho * g / ||g||  over a flat fp32 vector."""
    mode = _resolve(impl)
    if mode == "jnp":
        return ref.sam_perturb_flat_jnp(w_flat, g_flat, rho, sq_norm)
    from repro.kernels import sam_perturb as sp
    return sp.sam_perturb(w_flat, g_flat, rho, sq_norm,
                          interpret=(mode == "pallas_interpret"))


def sq_norm(g_flat: jax.Array, *, impl: Optional[str] = None) -> jax.Array:
    """Sum of squares of a flat vector (fp32 chunk partials on TPU)."""
    mode = _resolve(impl)
    if mode == "jnp":
        return ref.sq_norm_jnp(g_flat)
    from repro.kernels import sam_perturb as sp
    return sp.sq_norm(g_flat, interpret=(mode == "pallas_interpret"))


def fused_axpy(alpha, x_flat: jax.Array, y_flat: jax.Array, *,
               impl: Optional[str] = None) -> jax.Array:
    """Single-pass  y + alpha * x  over flat vectors (y's dtype out)."""
    mode = _resolve(impl)
    if mode == "jnp":
        return ref.axpy_flat_jnp(alpha, x_flat, y_flat)
    from repro.kernels import fused_update as fu
    return fu.fused_axpy(alpha, x_flat, y_flat,
                         interpret=(mode == "pallas_interpret"))


def fused_dot_norms(a_flat: jax.Array, b_flat: jax.Array, *,
                    impl: Optional[str] = None
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(<a,b>, ||a||^2, ||b||^2) in one pass over (a, b)."""
    mode = _resolve(impl)
    if mode == "jnp":
        return ref.dot_norms_flat_jnp(a_flat, b_flat)
    from repro.kernels import fused_update as fu
    return fu.fused_dot_norms(a_flat, b_flat,
                              interpret=(mode == "pallas_interpret"))


def delta_amax(p_flat: jax.Array, s_flat: jax.Array, e_flat: jax.Array, *,
               impl: Optional[str] = None) -> jax.Array:
    """max |p - s + e| over flat buckets (JOB-delta int8 scale probe)."""
    mode = _resolve(impl)
    if mode == "jnp":
        return ref.delta_amax_flat_jnp(p_flat, s_flat, e_flat)
    from repro.kernels import fused_update as fu
    return fu.delta_amax(p_flat, s_flat, e_flat,
                         interpret=(mode == "pallas_interpret"))


def delta_encode_i8(p_flat: jax.Array, s_flat: jax.Array, e_flat: jax.Array,
                    scale, *, impl: Optional[str] = None
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-pass int8 delta encode: (q int8, shadow' fp32, residual' fp32)."""
    mode = _resolve(impl)
    if mode == "jnp":
        return ref.delta_encode_i8_flat_jnp(p_flat, s_flat, e_flat, scale)
    from repro.kernels import fused_update as fu
    return fu.delta_encode_i8(p_flat, s_flat, e_flat, scale,
                              interpret=(mode == "pallas_interpret"))


def sgd_epilogue(w_flat: jax.Array, g_flat: jax.Array, m_flat, clip_scale, lr,
                 *, momentum: float = 0.0, nesterov: bool = False,
                 weight_decay: float = 0.0, impl: Optional[str] = None):
    """Fused clip-wd-momentum-lr-apply (SGD family): (w', m'-or-None)."""
    mode = _resolve(impl)
    if mode == "jnp":
        return ref.sgd_epilogue_flat_jnp(w_flat, g_flat, m_flat, clip_scale,
                                         lr, momentum=momentum,
                                         nesterov=nesterov,
                                         weight_decay=weight_decay)
    from repro.kernels import fused_update as fu
    return fu.sgd_epilogue(w_flat, g_flat, m_flat, clip_scale, lr,
                           momentum=momentum, nesterov=nesterov,
                           weight_decay=weight_decay,
                           interpret=(mode == "pallas_interpret"))


def adamw_epilogue(w_flat: jax.Array, g_flat: jax.Array, mu_flat: jax.Array,
                   nu_flat: jax.Array, clip_scale, lr, c1, c2, *,
                   b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                   weight_decay: float = 0.0, impl: Optional[str] = None):
    """Fused clip-adam-wd-lr-apply (AdamW family): (w', mu', nu')."""
    mode = _resolve(impl)
    if mode == "jnp":
        return ref.adamw_epilogue_flat_jnp(w_flat, g_flat, mu_flat, nu_flat,
                                           clip_scale, lr, c1, c2, b1=b1,
                                           b2=b2, eps=eps,
                                           weight_decay=weight_decay)
    from repro.kernels import fused_update as fu
    return fu.adamw_epilogue(w_flat, g_flat, mu_flat, nu_flat, clip_scale, lr,
                             c1, c2, b1=b1, b2=b2, eps=eps,
                             weight_decay=weight_decay,
                             interpret=(mode == "pallas_interpret"))
