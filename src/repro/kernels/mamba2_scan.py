"""Pallas TPU chunked SSD (Mamba2) sequence-mixing kernel.

Blocking (zamba2: P=64, N=64, chunk T=128 — MXU-aligned):
* grid (B, H, n_chunks); the chunk axis is innermost and sequential
  ("arbitrary"), carrying the (P, N) state in fp32 VMEM scratch;
* per step the kernel loads x (T,P), dt (T,1), b/c (T,N) tiles and computes
    intra-chunk:  y  = (tril(C Bᵀ) ⊙ decay) (dt ⊙ x)      3 MXU matmuls
    state in/out: y += (exp(cum) ⊙ C) h_inᵀ ;  h_out = exp(total) h_in + ...
  entirely in VMEM; only y (T,P) returns to HBM per step.

The jnp mirror (ref.mamba2_chunked_jnp) is the oracle; decode steps use the
sequential reference (single token, no kernel needed).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, h_out_ref,
                h_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)       # (T, P)
    dt = dt_ref[0, 0, 0, :, 0].astype(jnp.float32)  # (T,)
    b = b_ref[0, 0, 0].astype(jnp.float32)       # (T, N)
    c = c_ref[0, 0, 0].astype(jnp.float32)       # (T, N)
    a = a_ref[0]                                 # scalar decay rate (<0)
    d = d_ref[0]                                 # scalar skip

    la = dt * a                                  # (T,) log decay per step
    cum = jnp.cumsum(la)                         # inclusive
    total = cum[-1]

    xd = x * dt[:, None]                         # (T, P)
    # intra-chunk decay matrix: exp(cum_t - cum_s) masked to s <= t
    seg = cum[:, None] - cum[None, :]            # (T, T)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    gmat = jnp.where(tri, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))   # (T, T)
    y = jax.lax.dot(cb * gmat, xd)                             # (T, P)

    # contribution of the entering state
    h_in = h_ref[...]                                          # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, h_in, (((1,), (1,)), ((), ())))                     # (T, P)

    # next chunk state: h = exp(total) h_in + (sdecay ⊙ xd)ᵀ b
    sdecay = jnp.exp(total - cum)                              # (T,)
    h_ref[...] = (jnp.exp(total) * h_in
                  + jax.lax.dot_general(xd * sdecay[:, None], b,
                                        (((0,), (0,)), ((), ()))))  # (P, N)

    y_ref[0, 0, 0] = (y + d * x).astype(y_ref.dtype)
    h_out_ref[0, 0] = h_ref[...]   # revisited each chunk; final chunk wins


def mamba2_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                   c: jax.Array, d: jax.Array, *, chunk: int = 128,
                   init_state: Optional[jax.Array] = None,
                   interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """x (B,S,H,P); dt (B,S,H); a,d (H,); b,c (B,S,G,N). Returns (y, h_final).

    Grid semantics match ref.mamba2_chunked_jnp (G groups broadcast onto H).
    init_state is consumed by the jnp path only (serving); training starts
    from zero state.
    """
    from repro.kernels import ref

    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    if S % chunk != 0 or init_state is not None:
        return ref.mamba2_chunked_jnp(x, dt, a, b, c, d, chunk=chunk,
                                      init_state=init_state)
    nc = S // chunk
    rep = H // G
    # (B,S,H,*) -> (B,H,nc,T,*) tiles
    xt = jnp.moveaxis(x, 2, 1).reshape(B, H, nc, chunk, P)
    dtt = jnp.moveaxis(dt, 2, 1).reshape(B, H, nc, chunk, 1)
    bh = jnp.repeat(jnp.moveaxis(b, 2, 1), rep, axis=1).reshape(B, H, nc, chunk, N)
    ch = jnp.repeat(jnp.moveaxis(c, 2, 1), rep, axis=1).reshape(B, H, nc, chunk, N)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, 1), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, 1, chunk, N), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, N), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, chunk, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xt, dtt, a.astype(jnp.float32), bh, ch, d.astype(jnp.float32))

    y = jnp.moveaxis(y.reshape(B, H, S, P), 1, 2)
    return y, h_final
