"""Engine API: one execution contract for every training schedule.

The paper's two realizations of AsyncSAM — the fused SPMD step (Form A,
`core/async_sam.py`) and the heterogeneous two-lane executor (Form B,
`runtime/async_executor.py`) — used to expose incompatible interfaces, so the
launcher, benchmarks, and examples each hand-rolled their own
jit/sharding/logging/checkpoint loop. This module defines the single seam they
all plug into:

    executor.init_state(params, rng)  -> TrainState       (placed + ready)
    executor.step(state, batch)       -> (state, metrics)
    executor.pre_fit(state, batch)    -> dict | None      (optional: calibration)
    executor.close()                                       (idempotent)

plus the *metric contract*: every executor's step metrics include at least
`ENGINE_METRIC_KEYS` (loss, grad_norm, tau, perturbed), so callbacks,
benchmarks, and parity tests never special-case the schedule. Future
schedules (elastic meshes, multi-host lanes, new SAM variants) are new
`StepExecutor` implementations, not new training loops.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Optional, Protocol, runtime_checkable

import jax

from repro.core import TrainState

Pytree = Any

#: Keys every executor guarantees in its step metrics.
#:   loss       — descent-lane loss at the (possibly perturbed) point
#:   grad_norm  — global norm of the applied gradient
#:   tau        — age (steps) of the ascent gradient used for the perturbation
#:                (0 = none/synchronous, 1 = paper steady state, >1 = straggler)
#:   perturbed  — 1.0 if the step used a SAM perturbation, 0.0 if it degraded
#:                to (or is) plain SGD
ENGINE_METRIC_KEYS = ("loss", "grad_norm", "tau", "perturbed")

#: Optional keys an executor MAY emit, only on steps where they are real
#: measurements (callbacks must tolerate their absence). Today these come
#: from the remote ascent lane, on the step that harvested an exchange:
#:   wire_bytes — measured bytes of that JOB+GRAD exchange (job + grad sum,
#:                kept for backward compat with pre-split telemetry)
#:   job_bytes  — the JOB frame (params direction out: full snapshot or
#:                delta-encoded bucket sections)
#:   grad_bytes — the GRAD frame (compressed ascent gradient back)
#:   rtt_s      — round-trip seconds of that exchange
#: The pool lane (multi-client ascent pool, protocol revision 3) adds:
#:   pool_depth  — queue depth the exchange was admitted behind
#:   pool_wait_s — seconds the job waited before a pool worker took it
#:   client_id   — numeric client identity (crc32 of the declared id, so
#:                 fleet jsonl traces from many clients can be joined)
#: The elastic executor (preemption-surviving mesh resizes) adds:
#:   mesh_devices  — current mesh capacity in devices (every step, so the
#:                   jsonl shows the mesh's size over the whole run)
#:   resize_events — cumulative resize count (only on the step right after
#:                   a shrink/grow, marking exactly when the run resized)
#:   resize_time_s — seconds that resize's re-place + re-lower cost
ENGINE_OPTIONAL_METRIC_KEYS = ("wire_bytes", "job_bytes", "grad_bytes",
                               "rtt_s", "pool_depth", "pool_wait_s",
                               "client_id", "mesh_devices", "resize_events",
                               "resize_time_s")


@runtime_checkable
class StepExecutor(Protocol):
    """Uniform execution surface over training schedules (see module doc)."""

    name: str

    def init_state(self, params: Pytree, rng: jax.Array) -> TrainState:
        """Build the TrainState, placed/sharded for this executor."""
        ...

    def step(self, state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        """One optimizer step; metrics satisfy ENGINE_METRIC_KEYS."""
        ...

    def close(self) -> None:
        """Release resources (threads, mesh contexts). Must be idempotent."""
        ...


@dataclasses.dataclass
class FitReport:
    """What Engine.fit returns; field-compatible with runtime.RunReport."""
    final_state: TrainState
    steps_done: int
    restarts: int
    metrics_history: list
    wall_time_s: float
    pre_fit: Optional[dict] = None   # executor pre-fit telemetry (calibration)


def ensure_metric_contract(metrics: dict, *, tau, perturbed) -> dict:
    """Fill contract keys an executor's raw step did not already emit."""
    metrics = dict(metrics)
    metrics.setdefault("tau", tau)
    metrics.setdefault("perturbed", perturbed)
    return metrics


def mesh_context(mesh) -> contextlib.AbstractContextManager:
    """Version-portable 'make `mesh` the ambient mesh' context.

    jax >= 0.6 spells this `jax.set_mesh`; on older releases (this container
    ships 0.4.37) `Mesh` itself is the context manager that scopes
    `with_sharding_constraint(PartitionSpec(...))`.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def cost_analysis_dict(compiled) -> dict:
    """Version-portable `compiled.cost_analysis()`.

    jax <= 0.4 returns a [per-device dict]; newer releases return the dict
    directly. Always returns a (possibly empty) dict.
    """
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost
