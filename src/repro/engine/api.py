"""Engine API: one execution contract for every training schedule.

The paper's two realizations of AsyncSAM — the fused SPMD step (Form A,
`core/async_sam.py`) and the heterogeneous two-lane executor (Form B,
`runtime/async_executor.py`) — used to expose incompatible interfaces, so the
launcher, benchmarks, and examples each hand-rolled their own
jit/sharding/logging/checkpoint loop. This module defines the single seam they
all plug into:

    executor.init_state(params, rng)  -> TrainState       (placed + ready)
    executor.step(state, batch)       -> (state, metrics)
    executor.pre_fit(state, batch)    -> dict | None      (optional: calibration)
    executor.close()                                       (idempotent)

plus the *metric contract*: every executor's step metrics include at least
`ENGINE_METRIC_KEYS` (loss, grad_norm, tau, perturbed), so callbacks,
benchmarks, and parity tests never special-case the schedule. Future
schedules (elastic meshes, multi-host lanes, new SAM variants) are new
`StepExecutor` implementations, not new training loops.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Optional, Protocol, runtime_checkable

import jax

from repro.core import TrainState
# The metric contract tuples are derived from the typed registry in
# repro.obs.registry (one MetricKey per scalar, with description/unit/source);
# re-exported here so every historical `from repro.engine.api import
# ENGINE_METRIC_KEYS` import keeps working.
from repro.obs.registry import (ENGINE_METRIC_KEYS,  # noqa: F401
                                ENGINE_OPTIONAL_METRIC_KEYS)

Pytree = Any


@runtime_checkable
class StepExecutor(Protocol):
    """Uniform execution surface over training schedules (see module doc)."""

    name: str

    def init_state(self, params: Pytree, rng: jax.Array) -> TrainState:
        """Build the TrainState, placed/sharded for this executor."""
        ...

    def step(self, state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        """One optimizer step; metrics satisfy ENGINE_METRIC_KEYS."""
        ...

    def close(self) -> None:
        """Release resources (threads, mesh contexts). Must be idempotent."""
        ...


@dataclasses.dataclass
class FitReport:
    """What Engine.fit returns; field-compatible with runtime.RunReport."""
    final_state: TrainState
    steps_done: int
    restarts: int
    metrics_history: list
    wall_time_s: float
    pre_fit: Optional[dict] = None   # executor pre-fit telemetry (calibration)
    poison_rollbacks: int = 0        # PoisonBatch restarts (numerics guard)


def ensure_metric_contract(metrics: dict, *, tau, perturbed) -> dict:
    """Fill contract keys an executor's raw step did not already emit."""
    metrics = dict(metrics)
    metrics.setdefault("tau", tau)
    metrics.setdefault("perturbed", perturbed)
    return metrics


def mesh_context(mesh) -> contextlib.AbstractContextManager:
    """Version-portable 'make `mesh` the ambient mesh' context.

    jax >= 0.6 spells this `jax.set_mesh`; on older releases (this container
    ships 0.4.37) `Mesh` itself is the context manager that scopes
    `with_sharding_constraint(PartitionSpec(...))`.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def cost_analysis_dict(compiled) -> dict:
    """Version-portable `compiled.cost_analysis()`.

    jax <= 0.4 returns a [per-device dict]; newer releases return the dict
    directly. Always returns a (possibly empty) dict.
    """
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost
