"""HeteroExecutor — Form B: the paper's two-lane heterogeneous schedule.

Wraps `runtime.AsyncSamExecutor` (descent lane + dedicated ascent thread,
depth-1 queue, staleness ledger) behind the `StepExecutor` surface, and
promotes the system-aware calibration of paper §3.3 to a first-class pre-fit
hook: when constructed with `calibrate=True`, `pre_fit` measures per-sample
gradient times on both lanes, reports the suggested b'/b, and from then on
caps the ascent sub-batch the slow lane sees at the calibrated size.

The flat-buffer fused weight-space path on the descent lane is governed by
`ExecutorConfig.fused_update` (None -> platform default: on for TPU, off for
CPU); lane placement on a real CPU+accelerator host comes from
`ExecutorConfig.{ascent,descent}_device` (`--ascent-device`/`--descent-device`
in the launcher).
"""
from __future__ import annotations

from typing import Any, Optional

import jax

from repro.core import (MethodConfig, TrainState, init_train_state,
                        make_method, slice_ascent_batch, split_batch)
from repro.core.api import LossFn
from repro.optim import GradientTransform
from repro.runtime.async_executor import AsyncSamExecutor, ExecutorConfig
from repro.utils import buckets

Pytree = Any


class HeteroExecutor:
    """Two-resource executor: ascent on the slow lane, descent on the fast one."""

    name = "hetero"

    def __init__(self, loss_fn: LossFn, method_cfg: Optional[MethodConfig] = None,
                 optimizer: Optional[GradientTransform] = None, *,
                 exec_cfg: Optional[ExecutorConfig] = None,
                 calibrate: bool = False, calibration_probes: int = 3,
                 ascent_lane=None):
        method_cfg = method_cfg or MethodConfig()
        assert method_cfg.name == "async_sam", \
            f"the hetero lanes realize async_sam only, got {method_cfg.name!r}"
        assert optimizer is not None, "HeteroExecutor needs an optimizer"
        self.cfg = method_cfg
        self.method = make_method(method_cfg)   # init() only; steps run split
        self.optimizer = optimizer
        self.calibrate = calibrate
        self.calibration_probes = calibration_probes
        self.calibrated_fraction: Optional[float] = None
        # ascent_lane swaps where the slow lane runs: None -> the in-process
        # thread lane; a `service.RemoteAscentClient` -> another host
        # (that is the whole difference between `hetero` and `remote`)
        self._inner = AsyncSamExecutor(loss_fn, method_cfg, optimizer,
                                       exec_cfg, ascent_lane=ascent_lane)

    @property
    def ledger(self):
        return self._inner.ledger

    @property
    def timings(self):
        return self._inner.timings

    # --- StepExecutor ---------------------------------------------------------
    def init_state(self, params: Pytree, rng: jax.Array) -> TrainState:
        # bucket-resident descent lane (ExecutorConfig.resident, resolved by
        # the inner executor): params persist as dtype buckets; optimizer /
        # method init then build congruent resident moments + ascent state
        if self._inner.resident and not buckets.is_bucketed(params):
            params = buckets.BucketedState.from_tree(params)
        return init_train_state(params, self.optimizer, self.method, rng)

    @property
    def wants_pre_fit(self) -> bool:
        """The Engine draws a probe batch only when calibration is enabled."""
        return self.calibrate

    def pre_fit(self, state: TrainState, batch: dict) -> Optional[dict]:
        """System-aware b' calibration (paper §3.3); runs before the fit loop."""
        if not self.calibrate:
            return None
        frac = self._inner.calibrate(state, batch,
                                     probes=self.calibration_probes)
        self.calibrated_fraction = frac
        return {"configured_ascent_fraction": self.cfg.ascent_fraction,
                "calibrated_ascent_fraction": frac}

    def _cap_ascent(self, batch: dict) -> dict:
        """Trim the ascent sub-batch to the calibrated b' (never grow it).

        Batches without an "ascent" key get one sliced here at the capped
        fraction — otherwise the inner executor would slice by the
        *configured* fraction and calibration would silently not apply.
        """
        if self.calibrated_fraction is None:
            return batch
        descent, ascent = split_batch(batch)
        if ascent is None:
            frac = min(self.cfg.ascent_fraction, self.calibrated_fraction)
            return {**descent, "ascent": slice_ascent_batch(descent, frac)}
        b = jax.tree.leaves(descent)[0].shape[0]
        target = max(1, int(round(b * self.calibrated_fraction)))
        if jax.tree.leaves(ascent)[0].shape[0] <= target:
            return batch
        return {**descent, "ascent": jax.tree.map(lambda x: x[:target], ascent)}

    def step(self, state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        # the inner executor already emits the full metric contract
        # (loss/grad_norm via _finish, tau/perturbed from the ledger)
        return self._inner.step(state, self._cap_ascent(batch))

    def on_restore(self, state: TrainState) -> None:
        """Checkpoint rollback: drop held/in-flight ascent gradients, which
        were computed against params from the discarded timeline."""
        self._inner.reset()

    # numerics-guard lane hooks: the guard ladder (runtime.guard) drives the
    # inner executor's rho scaling / stale-ascent drop through the wrapper
    def set_rho_scale(self, scale: float) -> None:
        self._inner.set_rho_scale(scale)

    def drop_ascent(self) -> None:
        self._inner.drop_ascent()

    def resize(self, state: TrainState, new_mesh) -> TrainState:
        """Descent-mesh resize: the descent lane is meshless (per-host), so
        the state stays put — but the ascent lane must not keep serving
        gradients computed against the pre-resize timeline. `reset()` bumps
        the generation fence and resets the lane; a remote lane's client
        invalidates its `JobEncoder` shadow there, so the next JOB is a full
        snapshot under a fresh sync id (the existing RESYNC path) and the
        ascent pool keeps serving across the resize — no server restart, no
        new wire format. The gap shows up as tau growth on the staleness
        ledger and, past max_staleness, SGD fallback; training never stalls.
        """
        self._inner.reset()
        return state

    def close(self) -> None:
        self._inner.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
