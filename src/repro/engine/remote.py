"""RemoteExecutor — the hetero schedule with the ascent lane on another host.

The paper's "fully utilize heterogeneous system resources" headline, taken
literally: descent runs here, the ascent gradient arrives over the wire from
a `repro.service.ascent_server` process (another host, another device, or —
loopback mode — a subprocess on this machine). Everything above the lane is
shared with `HeteroExecutor`: the same `AsyncSamExecutor` step, staleness
ledger, calibration pre-fit hook and `StepExecutor` surface, so `Engine.fit`
drives it unchanged and a loopback run matches `--executor hetero`
step for step under `ExecutorConfig(lockstep=True)`.

Wiring (ExecutorConfig fields):

    ascent_addr    "host:port" / "unix:/path" of a running server
    serve_ascent   loopback: spawn the server subprocess here; `loss_spec`
                   ("module:attr" | "arch:NAME[:reduced]") tells it what loss
                   to hold. With `ascent_addr` unset the kernel picks a port.
    max_server_respawns  loopback resilience: a server that dies mid-fit is
                   respawned (the client reconnects, in-flight gradients are
                   dropped, tau records the gap); past the budget the run
                   degrades to SGD-past-max-staleness and still completes.

Step metrics additionally carry `wire_bytes` (measured bytes of the last
JOB+GRAD exchange), its per-direction split `job_bytes`/`grad_bytes`, and
`rtt_s`, which `StalenessTelemetry(jsonl_path=...)` streams per step. The
JOB direction is delta-encoded against the server's shadow of the
last-synced params when `ExecutorConfig.job_compress` is "int8"/"topk"
(`service.delta`); `--job-compress none` keeps full fp32 snapshots and the
pinned lockstep remote==hetero parity.
"""
from __future__ import annotations

import threading
from typing import Optional

from repro.core import MethodConfig, TrainState
from repro.core.api import LossFn
from repro.core.ascent import Compressor
from repro.engine.hetero import HeteroExecutor
from repro.optim import GradientTransform
from repro.runtime.async_executor import ExecutorConfig
from repro.runtime.fault_tolerance import RestartBudget
from repro.runtime.health import ServerWatchdog
from repro.service.ascent_server import ServerHandle, spawn_server
from repro.service.client import RemoteAscentClient


class RemoteExecutor(HeteroExecutor):
    """Two-host executor: descent here, ascent behind `service.protocol`."""

    name = "remote"

    def __init__(self, loss_fn: LossFn, method_cfg: Optional[MethodConfig] = None,
                 optimizer: Optional[GradientTransform] = None, *,
                 exec_cfg: Optional[ExecutorConfig] = None,
                 calibrate: bool = False, calibration_probes: int = 3,
                 loss_spec: str = ""):
        xcfg = exec_cfg or ExecutorConfig()
        method_cfg = method_cfg or MethodConfig()
        self._loss_spec = loss_spec or xcfg.loss_spec
        self.server: Optional[ServerHandle] = None
        self.server_respawns = 0
        addr = xcfg.ascent_addr
        if xcfg.serve_ascent:
            if not self._loss_spec:
                raise ValueError(
                    "serve_ascent=True needs a loss_spec "
                    "('module:attr' or 'arch:NAME[:reduced]') so the spawned "
                    "server knows which loss function to hold")
            self.server = spawn_server(self._loss_spec,
                                       bind=addr or "127.0.0.1:0",
                                       delay_s=xcfg.ascent_delay_s,
                                       pool_workers=xcfg.pool_workers,
                                       auth_token=xcfg.auth_token)
            addr = self.server.addr
        if not addr:
            raise ValueError("RemoteExecutor needs ExecutorConfig.ascent_addr "
                             "(a running ascent server) or serve_ascent=True")
        self.client = RemoteAscentClient(
            addr,
            Compressor(kind=method_cfg.compressor,
                       topk_fraction=method_cfg.topk_fraction),
            connect_timeout_s=xcfg.connect_timeout_s,
            reconnect_backoff_s=xcfg.reconnect_backoff_s,
            # JOB-direction encoding (ExecutorConfig.job_compress/job_delta):
            # delta+quantized params out when the server supports it, full
            # snapshots otherwise. Lockstep runs retry an interrupted
            # exchange as a snapshot of the encoder's shadow, so a mid-fit
            # server kill stays bitwise transparent to the schedule.
            job_encoding=xcfg.job_compress,
            job_delta=xcfg.job_delta,
            retry_inflight=xcfg.lockstep,
            # pool identity: a stable client_id keys this client's canonical
            # shadow and telemetry; sync_group opts into the pool's shared
            # (LSAM-smoothed) group gradient; auth_token for non-loopback
            client_id=xcfg.client_id,
            sync_group=xcfg.sync_group,
            auth_token=xcfg.auth_token)
        try:
            super().__init__(loss_fn, method_cfg, optimizer, exec_cfg=xcfg,
                             calibrate=calibrate,
                             calibration_probes=calibration_probes,
                             ascent_lane=self.client)
        except BaseException:
            self.client.close()
            if self.server is not None:
                self.server.kill()
            raise
        self.xcfg = xcfg
        # --- server watchdog (runtime.health): STATS-scraping classifier
        # that tells a WEDGED loopback server (alive to TCP, counters
        # frozen with work queued) from a dead one; both are restarted
        # under a bounded budget, sharing the step-loop respawn lock
        self._server_lock = threading.Lock()
        self.watchdog: Optional[ServerWatchdog] = None
        if xcfg.watchdog and self.server is not None:
            self.watchdog = ServerWatchdog(
                addr_fn=lambda: self.client.address,
                restart_fn=self._watchdog_restart,
                budget=RestartBudget(xcfg.watchdog_max_restarts,
                                     what="server restart"),
                interval_s=xcfg.watchdog_interval_s,
                wedge_scrapes=xcfg.watchdog_wedge_scrapes,
                auth_token=xcfg.auth_token)
            self.watchdog.start()

    # --- loopback resilience ----------------------------------------------------
    def _maybe_respawn_server(self) -> None:
        """A died loopback server is replaced (within budget); the client is
        pointed at the new address and reconnects. The exchange that was in
        flight is gone — the staleness ledger records the gap as tau growth
        and, past max_staleness, SGD fallback — but training never stalls:
        a respawn that itself fails (the server dies again before listening,
        e.g. persistent OOM) burns one attempt and the run continues on the
        ledger instead of crashing Engine.fit. The successful-spawn wait is
        synchronous with the step (bounded by spawn_server's startup
        timeout) — acceptable for the loopback/smoke path this serves."""
        with self._server_lock:
            if self.server is None or self.server.alive():
                return
            if self.server_respawns >= self.xcfg.max_server_respawns:
                return
            self.server_respawns += 1
            try:
                self.server = spawn_server(
                    self._loss_spec, bind="127.0.0.1:0",
                    delay_s=self.xcfg.ascent_delay_s,
                    pool_workers=self.xcfg.pool_workers,
                    auth_token=self.xcfg.auth_token)
            except RuntimeError as e:
                self.client._note_error(f"server respawn failed: {e}")
                return
            self.client.set_address(self.server.addr)

    def _watchdog_restart(self, verdict: str) -> None:
        """Watchdog verdict (dead/wedged): replace the loopback server. A
        wedged server is still alive to the OS, so it is killed first; the
        client is pointed at the replacement and reconnects."""
        with self._server_lock:
            if self.server is None:
                return
            self.client._note_error(f"watchdog: server {verdict}; restarting")
            self.server.kill()
            try:
                self.server = spawn_server(
                    self._loss_spec, bind="127.0.0.1:0",
                    delay_s=self.xcfg.ascent_delay_s,
                    pool_workers=self.xcfg.pool_workers,
                    auth_token=self.xcfg.auth_token)
            except RuntimeError as e:
                self.client._note_error(f"watchdog respawn failed: {e}")
                return
            self.client.set_address(self.server.addr)

    def step(self, state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        self._maybe_respawn_server()
        return super().step(state, batch)

    def close(self) -> None:
        if self.watchdog is not None:
            self.watchdog.close()    # stop scraping before the server dies
        super().close()              # inner executor -> client (lane) close
        if self.server is not None:
            self.server.kill()
