"""ElasticExecutor — preemption-surviving, mesh-resizing training.

Wraps any inner `StepExecutor` (fused / hetero / remote) and re-enters the
step loop on a resized mesh when a device-loss or capacity event fires:

  * graceful shrink/grow ("resize" MeshEvents): the live state is re-placed
    onto the new mesh in-band — no rollback, no lost steps. The fused family
    re-lowers its jitted step with donation aliasing intact
    (`FusedExecutor.resize`); the hetero/remote family resets its ascent
    lane (`HeteroExecutor.resize`), which for a remote lane invalidates the
    client's `JobEncoder` shadow so the next JOB resyncs via the existing
    RESYNC/snapshot path while the ascent pool keeps serving.
  * hard preemption ("crash" MeshEvents, or a real device failure raising
    out of the inner step): the step dies, `run_resilient` restores the last
    checkpoint, and this executor's `on_restore` re-places the restored
    state onto the survivor mesh before training resumes — restore-onto-
    survivors. Requires a `CheckpointCallback` on the Engine.

The global batch is preserved across resizes (the data pipeline is
mesh-agnostic; only the per-device slice changes), so the loss trajectory of
a shrink->grow->shrink run tracks an uninterrupted one — pinned by
tests/test_elastic.py. Resizes are bounded by a rolling-window budget
(`resize_budget` events per `resize_window_s`; lifetime when the window is
None), the same accounting `run_resilient` applies to restarts.

Telemetry: every step's metrics carry `mesh_devices` (current capacity); the
step right after a resize additionally carries `resize_events` (cumulative)
and `resize_time_s` (what the re-place + re-lower cost), all within
`ENGINE_OPTIONAL_METRIC_KEYS` so `StalenessTelemetry(jsonl_path=...)`
streams them into benchmark artifacts.
"""
from __future__ import annotations

import logging
import time
from typing import Any, Optional

import jax

from repro.core import TrainState
from repro.obs import current_tracker, trace_now
from repro.runtime.chaos import DeviceLoss, MeshEvent
from repro.runtime.elastic import make_sized_mesh, reshard_state
from repro.runtime.fault_tolerance import RestartBudget

log = logging.getLogger("repro.elastic")

Pytree = Any


class ElasticExecutor:
    """StepExecutor wrapper that survives mesh resizes mid-fit.

    Args:
      inner: the wrapped executor. If it implements
        `resize(state, new_mesh) -> state` (FusedExecutor, HeteroExecutor and
        subclasses do), resizes delegate to it; otherwise the generic path
        reshards via `runtime.elastic.reshard_state` (which needs
        `model_cfg`) and calls the inner `on_restore` hook if present.
      model_cfg: ModelConfig for the sharding rules; required for the
        generic reshard path, optional when the inner executor resizes
        itself. Defaults to the inner executor's own `model_cfg`.
      events: a MeshEvent source — anything with `poll(step) -> MeshEvent |
        None` (e.g. `runtime.chaos.ChaosSchedule`, or a production watcher
        fed by the cluster scheduler). May also be attached later via
        `attach_events` / `Engine.fit(events=...)`.
      model_axis: model-parallel axis size of meshes built for resize
        targets (devices must divide it).
      resize_budget / resize_window_s: rolling-window bound on resizes, the
        `RestartBudget` accounting (lifetime when window is None).
      meshless: force symbolic resizes (never build a mesh) even for inner
        executors that carry one. Defaults to True exactly when the inner
        executor has no current mesh — the hetero/remote descent lane is
        per-host, so a "resize" there re-syncs lanes without re-placing.
    """

    name = "elastic"

    def __init__(self, inner, *, model_cfg=None, events=None,
                 model_axis: int = 1, resize_budget: int = 8,
                 resize_window_s: Optional[float] = None,
                 meshless: Optional[bool] = None):
        self.inner = inner
        self.model_cfg = (model_cfg if model_cfg is not None
                          else getattr(inner, "model_cfg", None))
        self.events = events
        self.model_axis = model_axis
        self._budget = RestartBudget(resize_budget, resize_window_s,
                                     what="resize")
        mesh = getattr(inner, "mesh", None)
        self.meshless = (mesh is None) if meshless is None else meshless
        self.devices = int(mesh.size) if mesh is not None \
            else jax.local_device_count()
        self.resize_events = 0
        self.last_resize_s = 0.0
        self._announce_resize = False
        self._pending: Optional[MeshEvent] = None

    # --- event plumbing -------------------------------------------------------
    def attach_events(self, events) -> None:
        """Plug in a MeshEvent source (Engine.fit(events=...) calls this)."""
        self.events = events

    @property
    def mesh(self):
        return getattr(self.inner, "mesh", None)

    def _resize(self, state: TrainState, event: MeshEvent) -> TrainState:
        try:
            new_mesh = None if self.meshless \
                else make_sized_mesh(event.devices, self.model_axis)
        except ValueError as e:
            # unsatisfiable graceful resize (capacity vanished again, or a
            # target that never existed): keep training on the current mesh
            # — a healthy fit must not die, and no budget is spent
            log.warning("resize to %d device(s) at step %d skipped: %s",
                        event.devices, event.step, e)
            return state
        self._budget.spend()   # raises past the rolling-window budget
        t0 = time.perf_counter()
        resize = getattr(self.inner, "resize", None)
        if resize is not None:
            state = resize(state, new_mesh)
        else:
            if not self.meshless:
                if self.model_cfg is None:
                    raise ValueError(
                        "generic elastic resize needs model_cfg for the "
                        "sharding rules (or an inner executor implementing "
                        "resize(state, new_mesh))")
                state = reshard_state(state, self.model_cfg, new_mesh)
            hook = getattr(self.inner, "on_restore", None)
            if hook is not None:
                hook(state)
        self.devices = event.devices
        self.resize_events += 1
        self.last_resize_s = time.perf_counter() - t0
        current_tracker().span_at(
            "mesh_resize", lane="elastic", t0=trace_now() - self.last_resize_s,
            t1=trace_now(), step=event.step, devices=event.devices,
            kind=event.kind)
        self._announce_resize = True
        log.info("mesh %s at step %d -> %d device(s) in %.3fs (%s kind)",
                 "resized", event.step, event.devices, self.last_resize_s,
                 event.kind)
        return state

    # --- StepExecutor ---------------------------------------------------------
    def init_state(self, params: Pytree, rng: jax.Array) -> TrainState:
        return self.inner.init_state(params, rng)

    @property
    def wants_pre_fit(self) -> bool:
        return getattr(self.inner, "wants_pre_fit",
                       hasattr(self.inner, "pre_fit"))

    def pre_fit(self, state: TrainState, batch: dict) -> Optional[dict]:
        hook = getattr(self.inner, "pre_fit", None)
        return hook(state, batch) if hook is not None else None

    def step(self, state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        if self.events is not None:
            while (ev := self.events.poll(int(state.step))) is not None:
                if ev.kind == "crash":
                    # the step dies; run_resilient restores and our
                    # on_restore re-places onto the survivor mesh
                    self._pending = ev
                    current_tracker().event("device_loss", lane="elastic",
                                            step=ev.step, devices=ev.devices)
                    raise DeviceLoss(ev)
                state = self._resize(state, ev)
        state, metrics = self.inner.step(state, batch)
        metrics = dict(metrics)
        metrics["mesh_devices"] = float(self.devices)
        if self._announce_resize:
            metrics["resize_events"] = float(self.resize_events)
            metrics["resize_time_s"] = float(self.last_resize_s)
            self._announce_resize = False
        return state, metrics

    def on_restore(self, state: TrainState) -> Optional[TrainState]:
        """Rollback hook (run_resilient): reset the inner executor's lanes,
        then — if a device loss is pending — re-place the restored state
        onto the survivor mesh and hand it back for adoption."""
        hook = getattr(self.inner, "on_restore", None)
        if hook is not None:
            hook(state)
        if self._pending is not None:
            ev, self._pending = self._pending, None
            current_tracker().event("restore_onto_survivors", lane="elastic",
                                    step=ev.step, devices=ev.devices)
            return self._resize(state, ev)
        return None

    def close(self) -> None:
        self.inner.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
