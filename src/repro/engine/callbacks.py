"""Engine callbacks: logging, throughput, eval curves, checkpoints, telemetry.

A callback observes the fit loop; it never owns it. The hooks are

    on_fit_start(engine, state)
    on_step(engine, state, metrics, step_time_s)
    on_fit_end(engine, report)

All hooks default to no-ops, so a callback implements only what it needs.
`CheckpointCallback` is the one callback the Engine inspects: its presence
routes the loop through `runtime.run_resilient` (periodic async saves +
checkpoint-restart on failure) with its manager and resilience policy.
"""
from __future__ import annotations

import dataclasses
import pathlib
import time
from typing import Callable, Optional, Union

from repro.checkpoint import CheckpointManager
from repro.core import TrainState
from repro.engine.api import ENGINE_OPTIONAL_METRIC_KEYS
from repro.obs import JsonlSink, scalar_metrics
from repro.runtime import ResilienceConfig


class Callback:
    def on_fit_start(self, engine, state: TrainState) -> None:  # noqa: D401
        pass

    def on_step(self, engine, state: TrainState, metrics: dict,
                step_time_s: float) -> None:
        pass

    def on_fit_end(self, engine, report) -> None:
        pass


class LoggingCallback(Callback):
    """Print scalar metrics every `every` steps (and at the final step)."""

    def __init__(self, every: int = 10, total_steps: Optional[int] = None):
        self.every = max(1, every)
        self.total_steps = total_steps

    def on_step(self, engine, state, metrics, step_time_s):
        step = int(state.step)
        if step % self.every == 0 or step == self.total_steps:
            scal = {k: f"{v:.4f}" for k, v in scalar_metrics(metrics).items()}
            print(f"step {step:5d}  {scal}")


class ThroughputMeter(Callback):
    """Collect per-step wall times; summarize tokens/s (or samples/s).

    The first recorded step is dropped from the steady-state mean (it may
    still carry compile/warmup cost when the Engine ran with warmup=0).
    """

    def __init__(self, tokens_per_batch: Optional[int] = None):
        self.tokens_per_batch = tokens_per_batch
        self.step_times: list[float] = []

    def on_step(self, engine, state, metrics, step_time_s):
        self.step_times.append(step_time_s)

    @property
    def steady_times(self) -> list[float]:
        return self.step_times[1:] or self.step_times

    def summary(self) -> dict:
        if not self.step_times:
            return {}
        steady = self.steady_times
        mean = sum(steady) / len(steady)
        out = {"mean_step_s": mean, "steps_timed": len(self.step_times)}
        if self.tokens_per_batch:
            out["tokens_per_s"] = self.tokens_per_batch / mean
        return out


class EvalCallback(Callback):
    """Run `eval_fn(state) -> float` every `every` steps; keep a (t, value) curve."""

    def __init__(self, eval_fn: Callable[[TrainState], float], every: int = 50,
                 total_steps: Optional[int] = None):
        self.eval_fn = eval_fn
        self.every = max(1, every)
        self.total_steps = total_steps
        self.curve: list[tuple[float, float]] = []
        self._t0 = None

    def on_fit_start(self, engine, state):
        self._t0 = time.perf_counter()

    def on_step(self, engine, state, metrics, step_time_s):
        step = int(state.step)
        if step % self.every == 0 or step == self.total_steps:
            self.curve.append((time.perf_counter() - (self._t0 or 0.0),
                               float(self.eval_fn(state))))


@dataclasses.dataclass
class CheckpointCallback(Callback):
    """Periodic save/restore via CheckpointManager.

    The Engine detects this callback and runs its loop under
    `run_resilient`, which owns the save cadence, the step-0 baseline
    checkpoint, and restore-and-continue on failure; `shardings` (if set)
    lets a restore re-place state on the current mesh (elastic restart).
    """
    manager: CheckpointManager
    resilience: ResilienceConfig = dataclasses.field(
        default_factory=ResilienceConfig)
    shardings: Optional[object] = None


class StalenessTelemetry(Callback):
    """Aggregate the hetero lane's τ ledger: histogram + SGD-fallback count.

    Works against the metric contract (tau/perturbed), so it is attachable to
    the fused executor too, where it simply records the constant τ=1 regime.

    With `jsonl_path` set, every step additionally appends one JSON record
    `{step, tau, perturbed, step_time_s, loss}` to that file (streamed
    through `repro.obs.JsonlSink`, which owns the record schema, so a
    crashed run keeps its trace) — the input `benchmarks/fig3_throughput.py`
    and `benchmarks/table_4_2_hetero.py` use to plot straggler-degradation
    curves. When the remote ascent lane is active (`RemoteExecutor`), the
    step metrics also carry the `ENGINE_OPTIONAL_METRIC_KEYS` wire telemetry
    — `wire_bytes` (measured bytes of the JOB+GRAD exchange), its
    per-direction split `job_bytes`/`grad_bytes`, and `rtt_s` — and each
    record gains those fields, so the JOB-direction win of delta-encoded
    payloads is visible per step while `wire_bytes` stays the sum for
    backward compatibility. Against a multi-client ascent pool the records
    additionally carry `pool_depth`/`pool_wait_s` (scheduler pressure seen
    by this exchange) and `client_id` (numeric identity), so one merged
    fleet trace can be split back per descent client. Under an
    `ElasticExecutor` every record carries `mesh_devices` (capacity over
    time) and the step right after a shrink/grow adds
    `resize_events`/`resize_time_s`, so benchmark artifacts show exactly
    when a run resized and what it cost.
    """

    #: metric keys recorded per step when the executor emits them (remote lane)
    OPTIONAL_KEYS = ENGINE_OPTIONAL_METRIC_KEYS

    def __init__(self, print_summary: bool = True,
                 jsonl_path: Union[str, pathlib.Path, None] = None):
        self.print_summary = print_summary
        self.jsonl_path = pathlib.Path(jsonl_path) if jsonl_path else None
        self._sink = None
        self.tau_hist: dict[int, int] = {}
        self.sgd_fallbacks = 0
        self.perturbed_steps = 0

    def on_step(self, engine, state, metrics, step_time_s):
        tau = int(metrics.get("tau", 0))
        perturbed = float(metrics.get("perturbed", 0.0))
        self.tau_hist[tau] = self.tau_hist.get(tau, 0) + 1
        if perturbed:
            self.perturbed_steps += 1
        else:
            self.sgd_fallbacks += 1
        if self.jsonl_path is not None:
            if self._sink is None:
                self._sink = JsonlSink(self.jsonl_path)
            self._sink.log({**metrics, "step_time_s": step_time_s},
                           step=int(state.step))

    def summary(self) -> dict:
        return {"tau_hist": dict(sorted(self.tau_hist.items())),
                "perturbed_steps": self.perturbed_steps,
                "sgd_fallbacks": self.sgd_fallbacks}

    def on_fit_end(self, engine, report):
        if self._sink is not None:
            self._sink.close()
            self._sink = None
        if self.print_summary:
            print(f"staleness: {self.summary()}")
