"""repro.engine — one execution API over every training schedule.

Executor matrix:

    FusedExecutor   Form A  one SPMD program; mesh/sharding/jit/donation
    HeteroExecutor  Form B  two lanes (slow ascent thread + fast descent),
                            staleness ledger, system-aware calibration
    RemoteExecutor  Form B  same two lanes, but the ascent lane lives in
                            another process/host behind repro.service
                            (TCP/Unix sockets; loopback mode for one host)
    ElasticExecutor wrapper preemption-surviving mesh resizes around any of
                            the above (shrink onto survivors / grow with
                            capacity, driven by runtime.chaos MeshEvents)
    GuardedExecutor wrapper numerics guard around any of the above (outermost):
                            in-step skip, rho de-escalation ladder, PoisonBatch
                            rollback (runtime.guard; --guard in the launcher)

All satisfy the `StepExecutor` protocol and the `ENGINE_METRIC_KEYS`
contract; `Engine.fit` drives any of them with the same callbacks.
"""
from repro.engine.api import (  # noqa: F401
    ENGINE_METRIC_KEYS,
    ENGINE_OPTIONAL_METRIC_KEYS,
    FitReport,
    StepExecutor,
    cost_analysis_dict,
    ensure_metric_contract,
    mesh_context,
)
from repro.engine.callbacks import (  # noqa: F401
    Callback,
    CheckpointCallback,
    EvalCallback,
    LoggingCallback,
    StalenessTelemetry,
    ThroughputMeter,
)
from repro.engine.elastic import ElasticExecutor  # noqa: F401
from repro.engine.engine import Engine  # noqa: F401
from repro.engine.fused import FusedExecutor  # noqa: F401
from repro.engine.hetero import HeteroExecutor  # noqa: F401
from repro.engine.remote import RemoteExecutor  # noqa: F401
from repro.runtime.guard import GuardConfig, GuardedExecutor  # noqa: F401
