"""Engine — the single fit loop every entrypoint drives.

    executor = FusedExecutor(loss_fn, mcfg, opt)            # or HeteroExecutor
    state = executor.init_state(params, rng)
    with Engine(executor, pipeline, callbacks=[LoggingCallback()]) as eng:
        report = eng.fit(state, steps=1000)

The Engine owns iteration, timing, callback dispatch, and the optional
pre-fit hook (hetero calibration); a `CheckpointCallback` routes the loop
through `runtime.run_resilient` so checkpoint-restart fault tolerance is the
same code path with or without the Engine. `data` is any iterable of batches;
the resilient path additionally needs the pipeline `state()/restore()`
protocol (see repro.data.pipeline).
"""
from __future__ import annotations

import time
from typing import Any, Iterable, Optional, Sequence

from repro.core import TrainState
from repro.engine.api import FitReport, StepExecutor
from repro.engine.callbacks import Callback, CheckpointCallback
from repro.obs import Tracker, current_tracker, scalar_metrics, use_tracker
from repro.runtime import run_resilient

Pytree = Any


class Engine:
    def __init__(self, executor: StepExecutor, data: Iterable[dict],
                 callbacks: Sequence[Callback] = ()):
        self.executor = executor
        self.data = data
        self.callbacks = list(callbacks)
        self.pre_fit_report: Optional[dict] = None

    # --- plumbing -------------------------------------------------------------
    def _probe_batch(self) -> dict:
        """A batch for calibration probes, without advancing the cursor when
        the pipeline supports peek() (lists/tuples are naturally re-iterable;
        a bare generator loses the probe batch — give it peek() if that
        matters for restart determinism)."""
        peek = getattr(self.data, "peek", None)
        if peek is not None:
            return peek()
        it = iter(self.data)
        try:
            return next(it)
        finally:
            if hasattr(it, "close"):
                it.close()

    def _wrapped_step(self):
        def step(state: TrainState, batch: dict):
            trk = current_tracker()
            t0 = time.perf_counter()
            with trk.span("train_step", lane="descent",
                          step=int(state.step)):
                state, metrics = self.executor.step(state, batch)
            dt = time.perf_counter() - t0
            trk.log({**scalar_metrics(metrics), "step_time_s": dt},
                    step=int(state.step))
            trk.histogram("step_time_s", dt)
            for cb in self.callbacks:
                cb.on_step(self, state, metrics, dt)
            return state, metrics

        return step

    # --- the loop -------------------------------------------------------------
    def fit(self, state: TrainState, steps: int, *, warmup: int = 0,
            failure_injector=None, events=None,
            tracker: Optional[Tracker] = None) -> FitReport:
        """Train until `state.step == steps`; returns a FitReport.

        warmup: steps executed before the clock starts and before
        `on_fit_start` fires (benchmarks exclude compile time this way).

        events: a MeshEvent source (`runtime.chaos.ChaosSchedule` or a
        production capacity watcher). With an `ElasticExecutor` it is
        attached to the executor, which drains it before each step (graceful
        resizes in-band; crash events through the restore path — those need
        a `CheckpointCallback`). With any other executor a *callable* source
        degrades to the failure-injector surface: its crash events raise,
        its resizes are skipped — the generalization of `failure_injector`.

        tracker: a `repro.obs.Tracker`; installed as the process-global
        current tracker for the duration of the fit, so executor internals
        (ascent lanes, pool workers, elastic resizes) report spans to it
        from their own threads. Without one, whatever tracker is already
        current (by default the no-op null tracker) stays in effect.
        """
        if tracker is not None:
            with use_tracker(tracker):
                return self._fit(state, steps, warmup=warmup,
                                 failure_injector=failure_injector,
                                 events=events)
        return self._fit(state, steps, warmup=warmup,
                         failure_injector=failure_injector, events=events)

    def _fit(self, state: TrainState, steps: int, *, warmup: int,
             failure_injector, events) -> FitReport:
        if events is not None:
            attach = getattr(self.executor, "attach_events", None)
            if attach is not None:
                attach(events)
            elif callable(events):
                if failure_injector is not None:
                    raise ValueError("pass either events or failure_injector "
                                     "to a non-elastic executor, not both")
                failure_injector = events
            else:
                raise ValueError(
                    f"{type(self.executor).__name__} cannot consume a "
                    "MeshEvent source; wrap it in ElasticExecutor or pass a "
                    "callable failure injector")
        hook = getattr(self.executor, "pre_fit", None)
        if hook is not None and getattr(self.executor, "wants_pre_fit", True):
            self.pre_fit_report = hook(state, self._probe_batch())

        ckpt = next((c for c in self.callbacks
                     if isinstance(c, CheckpointCallback)), None)
        if warmup and ckpt is not None:
            # run_resilient re-iterates the pipeline from its cursor; a
            # separate warmup iterator would replay (list data) or orphan a
            # prefetch worker (pipeline data)
            raise ValueError("warmup is not supported with CheckpointCallback")

        it = None
        if warmup:
            it = iter(self.data)
            try:
                for _ in range(warmup):
                    state, _ = self.executor.step(state, next(it))
            except BaseException:
                if hasattr(it, "close"):
                    it.close()   # don't leak the prefetch worker on a
                raise            # failing warmup step

        try:
            for cb in self.callbacks:
                cb.on_fit_start(self, state)
        except BaseException:
            if it is not None and hasattr(it, "close"):
                it.close()   # a raising callback must not orphan the
            raise            # warmup iterator's prefetch worker
        wrapped = self._wrapped_step()
        if ckpt is not None:
            rep = run_resilient(wrapped, state, self.data, ckpt.manager, steps,
                                ckpt.resilience, failure_injector,
                                shardings=ckpt.shardings,
                                on_restore=getattr(self.executor,
                                                   "on_restore", None))
            report = FitReport(final_state=rep.final_state,
                               steps_done=rep.steps_done,
                               restarts=rep.restarts,
                               metrics_history=rep.metrics_history,
                               wall_time_s=rep.wall_time_s,
                               pre_fit=self.pre_fit_report,
                               poison_rollbacks=rep.poison_rollbacks)
        else:
            t0 = time.time()
            history: list = []
            it = it if it is not None else iter(self.data)
            try:
                while int(state.step) < steps:
                    try:
                        batch = next(it)
                    except StopIteration:
                        break
                    state, metrics = wrapped(state, batch)
                    history.append(scalar_metrics(metrics))
            finally:
                if hasattr(it, "close"):
                    it.close()   # stop a prefetching pipeline's worker now
            report = FitReport(final_state=state, steps_done=int(state.step),
                               restarts=0, metrics_history=history,
                               wall_time_s=time.time() - t0,
                               pre_fit=self.pre_fit_report)

        for cb in self.callbacks:
            cb.on_fit_end(self, report)
        return report

    # --- lifecycle ------------------------------------------------------------
    def close(self) -> None:
        self.executor.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
