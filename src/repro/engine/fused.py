"""FusedExecutor — Form A: one jitted SPMD step per training iteration.

Wraps `Method.make_step` together with the mesh/sharding/jit/donation plumbing
that used to be inlined in `launch/train.py`: with a mesh it enters the
ambient-mesh + activation-sharding contexts, shards the TrainState by
`launch.sharding.state_spec_tree`, and jits with donated input state and
explicit out_shardings; without a mesh it is a plain single-device jit, which
is what the CPU benchmarks and unit tests use. Either way the caller sees only
the `StepExecutor` surface.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import Method, MethodConfig, TrainState, init_train_state, make_method
from repro.core.api import LossFn
from repro.core.async_sam import AsyncSamState
from repro.engine.api import ensure_metric_contract, mesh_context
from repro.optim import GradientTransform, configure_fused
from repro.utils import buckets

Pytree = Any

# Methods whose steps are pure weight-space + value_and_grad compositions —
# safe to run on bucket-resident state. The others (esam's per-leaf masks via
# tree_paths, mesa's EMA distill, ...) keep the pytree representation.
RESIDENT_METHODS = ("sgd", "sam", "gsam", "async_sam")


class FusedExecutor:
    """Single-resource executor: the whole step is one XLA program.

    Args:
      loss_fn: framework loss callback `(params, batch, rng) -> (loss, aux)`.
      method: a `MethodConfig` (name-dispatched) or an already-built `Method`.
      optimizer: inner gradient transform.
      mesh: when given, run under this mesh with sharded state + donation
        (the pod/production path); when None, plain jit (CPU smoke path).
      model_cfg: ModelConfig used by the sharding rules; required with `mesh`.
      donate: donate the input TrainState buffers to the step (in-place
        update at scale; safe because callers rebind `state` every step).
      block: block on the updated params each step so host-side timing and
        callbacks see real step latency (all previous loops did this).
      fused_update: flat-buffer fused weight-space path (perturb + optimizer
        epilogue on dtype-bucketed buffers via single-pass kernels). None
        resolves to the platform default — on for TPU when the step runs
        unsharded (mesh None or 1 device; flattening a model-sharded leaf
        would force an all-gather under pjit), off elsewhere. The resolved
        flag is pinned into both the MethodConfig and the optimizer's
        FusedSpec before the step is built, so it is trace-time static.
      resident: bucket-RESIDENT training state — params / optimizer moments /
        ascent state live as persistent dtype buckets (buckets.BucketedState)
        and the step is buffer -> buffer, with donate=True aliasing input
        buffers to output buffers so no per-step gather/scatter copies
        remain (the realized counterpart of the fused path's modeled HBM
        win). None follows the resolved fused_update whenever the whole
        chain qualifies: meshless (or 1-device-mesh) step, a
        RESIDENT_METHODS method with an uncompressed ascent exchange, and a
        FusedSpec-recognized optimizer.
        Checkpoints stay pytree-shaped at the boundary (run_resilient
        converts at the edge), so resident and per-leaf runs interoperate.
    """

    name = "fused"

    def __init__(self, loss_fn: LossFn,
                 method: Union[Method, MethodConfig, None] = None,
                 optimizer: Optional[GradientTransform] = None, *,
                 mesh=None, model_cfg=None, donate: bool = True,
                 block: bool = True, fused_update: Optional[bool] = None,
                 resident: Optional[bool] = None):
        assert optimizer is not None, "FusedExecutor needs an optimizer"
        if fused_update is None:
            fused_update = (jax.default_backend() == "tpu"
                            and (mesh is None or mesh.size == 1))
        self.fused_update = fused_update
        optimizer = configure_fused(optimizer, fused_update)
        if isinstance(method, Method):
            # pre-built Method: rebuild from its attached config so the step's
            # perturb/refresh call sites see the RESOLVED flag (a None in the
            # closure would re-resolve to the bare platform default — fusing
            # sharded-mesh perturbs on TPU that this executor just declined).
            # A hand-constructed Method without cfg is taken as-is.
            if (method.cfg is not None
                    and method.cfg.fused_update != fused_update):
                self.method = make_method(dataclasses.replace(
                    method.cfg, fused_update=fused_update))
            else:
                self.method = method
        else:
            mcfg = dataclasses.replace(method or MethodConfig(),
                                       fused_update=fused_update)
            self.method = make_method(mcfg)
        if resident is None:
            mcfg = self.method.cfg
            # mesh.size == 1 qualifies like fused_update's own auto rule does
            # (the launcher always passes a host mesh, 1-device on one chip)
            resident = (fused_update and (mesh is None or mesh.size == 1)
                        and self.method.name in RESIDENT_METHODS
                        and getattr(optimizer, "fused_spec", None) is not None
                        and (mcfg is None or mcfg.compressor == "none"))
        if resident and mesh is not None and mesh.size > 1:
            # flattening a model-sharded leaf into a global bucket would force
            # an all-gather under pjit; per-shard bucketing is the ROADMAP
            # follow-on, so a sharded mesh keeps the pytree representation
            raise ValueError("bucket-resident state needs an unsharded step "
                             f"(mesh size {mesh.size}); use resident=False or "
                             "drop the mesh")
        self.resident = bool(resident)
        self.optimizer = optimizer
        self.mesh = mesh
        self.model_cfg = model_cfg
        self.donate = donate
        self.block = block
        self._step_raw = self.method.make_step(loss_fn, optimizer)
        self._jitted = None
        self._closed = False
        if mesh is not None:
            assert model_cfg is not None, "mesh sharding needs the ModelConfig"

    def _scope(self) -> contextlib.AbstractContextManager:
        """Ambient mesh + activation-sharding rules, entered per call.

        Scoping each init/step call (instead of holding the process-global
        contexts from __init__ to close) means an error before the Engine
        takes ownership can never leak a stale mesh into later jax work, and
        two live executors never interleave their context frames.
        """
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.models.partitioning import activation_sharding
        stack = contextlib.ExitStack()
        stack.enter_context(mesh_context(self.mesh))
        stack.enter_context(activation_sharding(self.mesh))
        return stack

    def _residentize_params(self, params: Pytree) -> Pytree:
        """Gather params into persistent buckets (once, at state birth);
        optimizer.init / method.init then produce congruent resident moments
        and ascent state by mapping over the buffers."""
        if self.resident and not buckets.is_bucketed(params):
            return buckets.BucketedState.from_tree(params)
        return params

    # --- StepExecutor ---------------------------------------------------------
    def init_state(self, params: Pytree, rng: jax.Array) -> TrainState:
        donate = (0,) if self.donate else ()
        with self._scope():
            params = self._residentize_params(params)
            state = init_train_state(params, self.optimizer, self.method, rng)
            if self.mesh is None:
                self._jitted = jax.jit(self._step_raw, donate_argnums=donate)
                return state
            from repro.launch.sharding import state_spec_tree, to_named
            state_sh = to_named(state_spec_tree(jax.eval_shape(lambda: state),
                                                self.model_cfg, self.mesh),
                                self.mesh)
            state = jax.device_put(state, state_sh)
            self._jitted = jax.jit(self._step_raw, donate_argnums=donate,
                                   out_shardings=(state_sh, None))
            return state

    def abstract_state(self, params_fn, rng: jax.Array) -> TrainState:
        """ShapeDtypeStruct TrainState — no device allocation (dry-run entry).

        `params_fn` builds the parameter pytree; it only ever runs under
        `jax.eval_shape`, so a full-size production config costs nothing.
        With `resident`, the abstract state carries BucketedState nodes, so
        `lower` pins the same buffer-shaped signature (and donation aliasing)
        the live step runs with.
        """
        with self._scope():
            return jax.eval_shape(lambda: init_train_state(
                self._residentize_params(params_fn()), self.optimizer,
                self.method, rng))

    def lower(self, state_sds, batch_sds):
        """Jit-lower the step with explicit in/out shardings (compile
        analysis / multi-pod dry-run — the same plumbing init_state uses,
        but against abstract operands and with pinned input shardings)."""
        donate = (0,) if self.donate else ()
        with self._scope():
            if self.mesh is None:
                return jax.jit(self._step_raw, donate_argnums=donate
                               ).lower(state_sds, batch_sds)
            from repro.launch.sharding import (batch_spec_tree,
                                               state_spec_tree, to_named)
            state_sh = to_named(state_spec_tree(state_sds, self.model_cfg,
                                                self.mesh), self.mesh)
            batch_sh = to_named(batch_spec_tree(batch_sds, self.mesh),
                                self.mesh)
            return jax.jit(self._step_raw, in_shardings=(state_sh, batch_sh),
                           out_shardings=(state_sh, None),
                           donate_argnums=donate).lower(state_sds, batch_sds)

    def resize(self, state: TrainState, new_mesh) -> TrainState:
        """Elastic re-entry: re-place the live `state` onto `new_mesh` and
        re-lower the jitted step against it.

        Donation aliasing survives the resize: the fresh jit keeps the same
        `donate_argnums`, and its out_shardings are recomputed for the new
        mesh, so the first post-resize step already aliases input buffers to
        output buffers. Bucket-resident state stays resident — the bucket
        layout is mesh-independent (`buckets.rebucket` is an identity
        re-group here) and the target must be unsharded, same constraint as
        construction (per-shard bucketing is the ROADMAP follow-on); the
        placement of the whole buffers is a single replicated device_put.
        Non-resident state re-places leaf-by-sharding-rule exactly like
        `init_state`, device-to-device (the survivors already hold their
        shards — no host round-trip).
        """
        assert not self._closed, "executor is closed"
        donate = (0,) if self.donate else ()
        if self.resident:
            if new_mesh is not None and new_mesh.size > 1:
                raise ValueError(
                    "bucket-resident step cannot resize onto a sharded mesh "
                    f"(size {new_mesh.size}); per-shard bucketing is the "
                    "ROADMAP follow-on — rebuild with resident=False to "
                    "resize across sharded meshes")
            # layout is mesh-independent: rebucket is the identity re-group,
            # re-asserted here so a layout-changing source (per-shard
            # buckets, someday) flows through the same edge
            state = jax.tree.map(
                lambda n: (buckets.rebucket(n, n.layout)
                           if buckets.is_bucketed(n) else n),
                state, is_leaf=buckets.is_bucketed)
            self.mesh = None   # a 1-device mesh adds nothing over meshless
            self._jitted = jax.jit(self._step_raw, donate_argnums=donate)
            return state
        if new_mesh is not None and self.model_cfg is None:
            raise ValueError("resize onto a mesh needs the ModelConfig "
                             "(construct the executor with model_cfg=...)")
        self.mesh = new_mesh
        with self._scope():
            if new_mesh is None:
                state = jax.device_put(state)
                self._jitted = jax.jit(self._step_raw, donate_argnums=donate)
                return state
            from repro.launch.sharding import state_spec_tree, to_named
            state_sh = to_named(state_spec_tree(jax.eval_shape(lambda: state),
                                                self.model_cfg, new_mesh),
                                new_mesh)
            state = jax.device_put(state, state_sh)
            self._jitted = jax.jit(self._step_raw, donate_argnums=donate,
                                   out_shardings=(state_sh, None))
            return state

    def step(self, state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        assert self._jitted is not None, "call init_state before step"
        assert not self._closed, "executor is closed"
        with self._scope():
            state, metrics = self._jitted(state, batch)
        if self.block:
            jax.block_until_ready(state.params)
        ms = state.method_state
        tau = (ms.staleness if isinstance(ms, AsyncSamState)
               else jnp.zeros((), jnp.int32))
        return state, ensure_metric_contract(
            metrics, tau=tau,
            perturbed=0.0 if self.method.name == "sgd" else 1.0)

    def close(self) -> None:
        # nothing held between calls (scopes are per-call); closing only
        # fences off further step() calls
        self._closed = True

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
