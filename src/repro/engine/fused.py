"""FusedExecutor — Form A: one jitted SPMD step per training iteration.

Wraps `Method.make_step` together with the mesh/sharding/jit/donation plumbing
that used to be inlined in `launch/train.py`: with a mesh it enters the
ambient-mesh + activation-sharding contexts, shards the TrainState by
`launch.sharding.state_spec_tree`, and jits with donated input state and
explicit out_shardings; without a mesh it is a plain single-device jit, which
is what the CPU benchmarks and unit tests use. Either way the caller sees only
the `StepExecutor` surface.
"""
from __future__ import annotations

import contextlib
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import Method, MethodConfig, TrainState, init_train_state, make_method
from repro.core.api import LossFn
from repro.core.async_sam import AsyncSamState
from repro.engine.api import ensure_metric_contract, mesh_context
from repro.optim import GradientTransform

Pytree = Any


class FusedExecutor:
    """Single-resource executor: the whole step is one XLA program.

    Args:
      loss_fn: framework loss callback `(params, batch, rng) -> (loss, aux)`.
      method: a `MethodConfig` (name-dispatched) or an already-built `Method`.
      optimizer: inner gradient transform.
      mesh: when given, run under this mesh with sharded state + donation
        (the pod/production path); when None, plain jit (CPU smoke path).
      model_cfg: ModelConfig used by the sharding rules; required with `mesh`.
      donate: donate the input TrainState buffers to the step (in-place
        update at scale; safe because callers rebind `state` every step).
      block: block on the updated params each step so host-side timing and
        callbacks see real step latency (all previous loops did this).
    """

    name = "fused"

    def __init__(self, loss_fn: LossFn,
                 method: Union[Method, MethodConfig, None] = None,
                 optimizer: Optional[GradientTransform] = None, *,
                 mesh=None, model_cfg=None, donate: bool = True,
                 block: bool = True):
        if isinstance(method, Method):
            self.method = method
        else:
            self.method = make_method(method or MethodConfig())
        assert optimizer is not None, "FusedExecutor needs an optimizer"
        self.optimizer = optimizer
        self.mesh = mesh
        self.model_cfg = model_cfg
        self.donate = donate
        self.block = block
        self._step_raw = self.method.make_step(loss_fn, optimizer)
        self._jitted = None
        self._closed = False
        if mesh is not None:
            assert model_cfg is not None, "mesh sharding needs the ModelConfig"

    def _scope(self) -> contextlib.AbstractContextManager:
        """Ambient mesh + activation-sharding rules, entered per call.

        Scoping each init/step call (instead of holding the process-global
        contexts from __init__ to close) means an error before the Engine
        takes ownership can never leak a stale mesh into later jax work, and
        two live executors never interleave their context frames.
        """
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.models.partitioning import activation_sharding
        stack = contextlib.ExitStack()
        stack.enter_context(mesh_context(self.mesh))
        stack.enter_context(activation_sharding(self.mesh))
        return stack

    # --- StepExecutor ---------------------------------------------------------
    def init_state(self, params: Pytree, rng: jax.Array) -> TrainState:
        donate = (0,) if self.donate else ()
        with self._scope():
            state = init_train_state(params, self.optimizer, self.method, rng)
            if self.mesh is None:
                self._jitted = jax.jit(self._step_raw, donate_argnums=donate)
                return state
            from repro.launch.sharding import state_spec_tree, to_named
            state_sh = to_named(state_spec_tree(jax.eval_shape(lambda: state),
                                                self.model_cfg, self.mesh),
                                self.mesh)
            state = jax.device_put(state, state_sh)
            self._jitted = jax.jit(self._step_raw, donate_argnums=donate,
                                   out_shardings=(state_sh, None))
            return state

    def step(self, state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        assert self._jitted is not None, "call init_state before step"
        assert not self._closed, "executor is closed"
        with self._scope():
            state, metrics = self._jitted(state, batch)
        if self.block:
            jax.block_until_ready(state.params)
        ms = state.method_state
        tau = (ms.staleness if isinstance(ms, AsyncSamState)
               else jnp.zeros((), jnp.int32))
        return state, ensure_metric_contract(
            metrics, tau=tau,
            perturbed=0.0 if self.method.name == "sgd" else 1.0)

    def close(self) -> None:
        # nothing held between calls (scopes are per-call); closing only
        # fences off further step() calls
        self._closed = True

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
