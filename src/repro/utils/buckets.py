"""Flat-buffer (dtype-bucketed) views of parameter pytrees.

The weight-space epilogue of a training step — perturb, global norm, clip,
weight decay, momentum/Adam, lr scale, apply — is HBM-bound: every pass
re-streams all parameter elements. The fused kernels in `repro.kernels`
operate on *flat* vectors, so this module provides the bridge: a pytree is
viewed as one contiguous buffer per leaf dtype (fp32 optimizer state and
bf16/fp32 params stay in their native dtypes, unlike
`trees.tree_flatten_to_vector` which casts everything to fp32), with the
leaf -> (bucket, offset) layout computed once per (treedef, shapes, dtypes)
signature and cached.

Grouping is by the layout tree's leaf dtype; a congruent tree (grads,
momentum, Adam moments, the AsyncSAM ascent gradient) is bucketed by the SAME
grouping using its own leaf dtypes, so a bf16 param bucket can pair with an
fp32 gradient bucket inside one single-pass kernel.

Beyond per-call bucketing, `BucketedState` makes the flat buffers the
*persistent* representation: a registered pytree whose leaves ARE the dtype
buckets, so params / optimizer moments / the AsyncSAM ascent gradient can live
buffer-shaped across steps (jit donation then aliases buffer to buffer and the
per-call gather/scatter copies disappear). Model code that needs the pytree
shape gets it from `.to_tree()` — contiguous slices of the buffer that XLA
treats as aliasing views, reconstructed from the cached `BucketLayout`
offsets. `to_portable` / `residentize` convert whole training states at the
checkpoint/wire boundary, where the pytree shape stays the on-disk contract.

`fused_path_enabled` is the one switch every fused-weight-space call site
consults: explicit override > process default (`set_fused_default`, the test
hook) > platform (on for TPU, off elsewhere — the `ops._resolve` convention).
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops

Pytree = Any


@dataclasses.dataclass(frozen=True)
class BucketGroup:
    """One dtype bucket: which leaves it holds and where they live."""
    dtype: str                      # layout-tree dtype name (grouping key)
    leaf_indices: tuple[int, ...]   # indices into the flattened leaf list
    offsets: tuple[int, ...]        # element offset of each leaf in the buffer
    sizes: tuple[int, ...]          # element count of each leaf
    size: int                       # total elements in the buffer


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    treedef: Any
    shapes: tuple[tuple[int, ...], ...]   # per-leaf shapes (flatten order)
    groups: tuple[BucketGroup, ...]       # sorted by dtype name
    n_leaves: int


_LAYOUT_CACHE: dict = {}


def bucket_layout(tree: Pytree) -> BucketLayout:
    """Layout for `tree`, cached on (treedef, shapes, dtypes). Trace-safe."""
    leaves, treedef = jax.tree.flatten(tree)
    key = (treedef, tuple((tuple(x.shape), jnp.dtype(x.dtype).name)
                          for x in leaves))
    hit = _LAYOUT_CACHE.get(key)
    if hit is not None:
        return hit
    by_dtype: dict[str, list[int]] = {}
    for i, x in enumerate(leaves):
        by_dtype.setdefault(jnp.dtype(x.dtype).name, []).append(i)
    groups = []
    for dname in sorted(by_dtype):
        idx = by_dtype[dname]
        sizes = tuple(math.prod(leaves[i].shape) for i in idx)
        offsets, off = [], 0
        for s in sizes:
            offsets.append(off)
            off += s
        groups.append(BucketGroup(dtype=dname, leaf_indices=tuple(idx),
                                  offsets=tuple(offsets), sizes=sizes, size=off))
    layout = BucketLayout(treedef=treedef,
                          shapes=tuple(tuple(x.shape) for x in leaves),
                          groups=tuple(groups), n_leaves=len(leaves))
    _LAYOUT_CACHE[key] = layout
    return layout


# ---------------------------------------------------------------------------
# Gather/scatter copy accounting (the realized-traffic counterpart of
# optim.fused.epilogue_hbm_bytes's model)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CopyStats:
    """Bytes moved by explicit representation conversions.

    A gather (`tree_to_buckets`) or scatter (`buckets_to_tree`) of N payload
    bytes costs 2N HBM bytes (read source + write destination); single-leaf
    groups are skipped (reshape of one leaf is a view, not a copy).
    `BucketedState.to_tree()` views are NOT counted: they are contiguous
    slices of the buffer that XLA aliases rather than materializes.

    Conversions run at trace time, so tracing a step function under
    `track_copies()` (e.g. with `jax.eval_shape`) tallies exactly the copies
    that would be baked into the compiled program.
    """
    gather_bytes: int = 0    # HBM bytes of tree -> buffer concatenations
    scatter_bytes: int = 0   # HBM bytes of buffer -> tree slice-backs
    gathers: int = 0
    scatters: int = 0

    @property
    def total_bytes(self) -> int:
        return self.gather_bytes + self.scatter_bytes


_COPY_STATS: Optional[CopyStats] = None


@contextlib.contextmanager
def track_copies():
    """Context manager: count gather/scatter conversion traffic within."""
    global _COPY_STATS
    prev, _COPY_STATS = _COPY_STATS, CopyStats()
    try:
        yield _COPY_STATS
    finally:
        _COPY_STATS = prev


def tree_to_buckets(tree: Pytree, layout: BucketLayout) -> list[jax.Array]:
    """Concatenate `tree`'s leaves into one flat buffer per layout group.

    `tree` must be congruent with the layout tree (same structure/shapes);
    its dtypes may differ as long as they are uniform within each group.
    """
    leaves = jax.tree.leaves(tree)
    assert len(leaves) == layout.n_leaves, (len(leaves), layout.n_leaves)
    out = []
    for grp in layout.groups:
        parts = [leaves[i].reshape(-1) for i in grp.leaf_indices]
        dt = parts[0].dtype
        assert all(p.dtype == dt for p in parts), \
            f"mixed dtypes within bucket {grp.dtype}: {[p.dtype for p in parts]}"
        if len(parts) > 1 and _COPY_STATS is not None:
            _COPY_STATS.gathers += 1
            _COPY_STATS.gather_bytes += 2 * grp.size * jnp.dtype(dt).itemsize
        out.append(parts[0] if len(parts) == 1 else jnp.concatenate(parts))
    return out


def buckets_to_tree(bufs: list[jax.Array], layout: BucketLayout,
                    like: Pytree) -> Pytree:
    """Inverse of tree_to_buckets; output shapes/dtypes come from `like`."""
    leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == layout.n_leaves
    new = list(leaves)
    for buf, grp in zip(bufs, layout.groups):
        if len(grp.leaf_indices) > 1 and _COPY_STATS is not None:
            _COPY_STATS.scatters += 1
            _COPY_STATS.scatter_bytes += 2 * grp.size * jnp.dtype(buf.dtype).itemsize
        for i, off, size in zip(grp.leaf_indices, grp.offsets, grp.sizes):
            new[i] = (buf[off:off + size]
                      .reshape(layout.shapes[i]).astype(leaves[i].dtype))
    return jax.tree.unflatten(treedef, new)


# ---------------------------------------------------------------------------
# BucketedState — flat buffers as the persistent training-state representation
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BucketedState:
    """A pytree whose *leaves* are the dtype buckets themselves.

    Where a plain parameter tree has one leaf per tensor, a BucketedState has
    one leaf per dtype bucket — so `jax.jit` donation aliases buffer to buffer
    across steps, `jax.grad` through `.to_tree()` delivers gradients already
    bucket-shaped, and generic pytree arithmetic (`jax.tree.map`,
    `trees.global_norm`, optimizer `init`) operates on the buffers directly.
    The layout (treedef + shapes + offsets) rides along as static aux data;
    a `jax.tree.map` over a BucketedState therefore yields a congruent
    BucketedState (e.g. `tree_zeros_like(params, f32)` -> fp32 moment buckets
    with the same grouping). The view dtype of each leaf is its bucket's
    buffer dtype — exact for params (buffers keep native dtypes) and for
    congruent fp32 state trees alike.
    """
    buffers: tuple
    layout: BucketLayout

    def tree_flatten(self):
        return tuple(self.buffers), self.layout

    @classmethod
    def tree_unflatten(cls, layout, children):
        return cls(buffers=tuple(children), layout=layout)

    @classmethod
    def from_tree(cls, tree: Pytree,
                  layout: Optional[BucketLayout] = None) -> "BucketedState":
        """Gather `tree` into resident buckets (one copy, at the boundary)."""
        layout = layout or bucket_layout(tree)
        return cls(buffers=tuple(tree_to_buckets(tree, layout)), layout=layout)

    def to_tree(self) -> Pytree:
        """Zero-copy pytree view: contiguous slices at the cached offsets.

        Not counted by `track_copies` — XLA aliases a contiguous slice into
        its consumer instead of materializing it, and differentiating through
        this view transposes to cotangent accumulation directly into the
        buffer, so neither direction adds a gather/scatter pass.
        """
        leaves: list = [None] * self.layout.n_leaves
        for buf, grp in zip(self.buffers, self.layout.groups):
            for i, off, size in zip(grp.leaf_indices, grp.offsets, grp.sizes):
                leaves[i] = buf[off:off + size].reshape(self.layout.shapes[i])
        return jax.tree.unflatten(self.layout.treedef, leaves)


def is_bucketed(x) -> bool:
    return isinstance(x, BucketedState)


def tree_view(x):
    """The pytree view of `x`: `.to_tree()` for a BucketedState, else `x`."""
    return x.to_tree() if is_bucketed(x) else x


def to_portable(tree: Pytree) -> Pytree:
    """Replace every BucketedState node with its pytree view.

    The result has the exact leaf structure a never-resident state would have
    — the checkpoint / wire / serve boundary contract (PR 1-3 interop).
    """
    return jax.tree.map(tree_view, tree, is_leaf=is_bucketed)


def host_portable(tree: Pytree) -> Pytree:
    """`jax.device_get(to_portable(tree))` without the device-side view pass.

    A resident node's buckets transfer as whole contiguous buffers (one D2H
    per dtype bucket instead of one per leaf), then the pytree shape is cut
    as numpy views on the host — zero device compute, zero host copies. This
    is the hot-path form for per-step host hand-offs (the hetero/remote
    ascent lane ships a params snapshot every exchange).
    """
    import numpy as np

    def f(n):
        if not is_bucketed(n):
            return jax.device_get(n)
        bufs = [np.asarray(jax.device_get(b)) for b in n.buffers]
        leaves: list = [None] * n.layout.n_leaves
        for buf, grp in zip(bufs, n.layout.groups):
            for i, off, size in zip(grp.leaf_indices, grp.offsets, grp.sizes):
                leaves[i] = buf[off:off + size].reshape(n.layout.shapes[i])
        return jax.tree.unflatten(n.layout.treedef, leaves)

    return jax.tree.map(f, tree, is_leaf=is_bucketed)


def host_tree_to_buckets(tree: Pytree, layout: BucketLayout,
                         dtype=None) -> list:
    """Numpy-side `tree_to_buckets`: concatenate host leaves per layout group.

    Pure numpy (no device round trip) — the form the ascent server uses to
    install its params shadow from a decoded JOB snapshot, and the client's
    resync path uses on host pytrees. `dtype` (e.g. float32) casts every
    bucket; None keeps each group's native dtype.
    """
    import numpy as np

    leaves = jax.tree.leaves(tree)
    assert len(leaves) == layout.n_leaves, (len(leaves), layout.n_leaves)
    out = []
    for grp in layout.groups:
        parts = [np.asarray(leaves[i]).reshape(-1) for i in grp.leaf_indices]
        buf = parts[0] if len(parts) == 1 else np.concatenate(parts)
        if dtype is not None:
            buf = buf.astype(dtype, copy=False)
        out.append(np.ascontiguousarray(buf))
    return out


def host_buckets_to_tree(bufs: list, layout: BucketLayout,
                         leaf_dtypes=None) -> Pytree:
    """Numpy-side inverse of `host_tree_to_buckets`: cut the flat host
    buffers into the layout's pytree shape (views where the dtype already
    matches). `leaf_dtypes` (flatten order) casts each leaf back to its
    original dtype — how an fp32 shadow re-enters a bf16 params tree."""
    import numpy as np

    leaves: list = [None] * layout.n_leaves
    for buf, grp in zip(bufs, layout.groups):
        buf = np.asarray(buf)
        for i, off, size in zip(grp.leaf_indices, grp.offsets, grp.sizes):
            leaf = buf[off:off + size].reshape(layout.shapes[i])
            if leaf_dtypes is not None:
                leaf = leaf.astype(leaf_dtypes[i], copy=False)
            leaves[i] = leaf
    return jax.tree.unflatten(layout.treedef, leaves)


def rebucket(state: "BucketedState", new_layout: BucketLayout
             ) -> "BucketedState":
    """Re-group a BucketedState's buffers directly into `new_layout`.

    The `to_portable` -> `residentize` round-trip cuts one view per leaf and
    re-concatenates N small arrays; this edge moves data at the *buffer*
    level instead: leaves that stay adjacent in their source buffer travel as
    one coalesced slice, an unchanged layout passes the buffers through
    untouched (the common elastic-resize case — the layout depends only on
    (treedef, shapes, dtypes), not the mesh), and a whole target group that
    maps to one contiguous span of one source buffer is a zero-copy slice.
    This is also the seam per-shard bucketing will re-group through when a
    resize changes the shard-local layout (ROADMAP follow-on).

    `new_layout` must describe the same flatten order (leaf i of the old
    layout is leaf i of the new); spans are cast to the target group's dtype
    when the regrouping changed a leaf's bucket dtype.
    """
    if not is_bucketed(state):
        raise TypeError(f"rebucket expects a BucketedState, got {type(state)}; "
                        "use BucketedState.from_tree for plain pytrees")
    old = state.layout
    if new_layout.n_leaves != old.n_leaves or new_layout.shapes != old.shapes:
        raise ValueError(
            "rebucket needs congruent layouts (same leaves/shapes): "
            f"{old.n_leaves} leaves {old.shapes[:3]}... vs "
            f"{new_layout.n_leaves} leaves {new_layout.shapes[:3]}...")
    if new_layout.groups == old.groups:
        return BucketedState(buffers=state.buffers, layout=new_layout)
    # source location of each leaf: (source group index, offset, size)
    src: list = [None] * old.n_leaves
    for gi, grp in enumerate(old.groups):
        for i, off, size in zip(grp.leaf_indices, grp.offsets, grp.sizes):
            src[i] = (gi, off, size)
    bufs = []
    for grp in new_layout.groups:
        spans: list[tuple[int, int, int]] = []
        for i in grp.leaf_indices:
            gi, off, size = src[i]
            if spans and spans[-1][0] == gi \
                    and spans[-1][1] + spans[-1][2] == off:
                g0, o0, s0 = spans[-1]
                spans[-1] = (g0, o0, s0 + size)   # coalesce adjacent run
            else:
                spans.append((gi, off, size))
        dt = jnp.dtype(grp.dtype)
        parts = [state.buffers[gi][o:o + s].astype(dt)
                 for gi, o, s in spans]
        bufs.append(parts[0] if len(parts) == 1 else jnp.concatenate(parts))
    return BucketedState(buffers=tuple(bufs), layout=new_layout)


def residentize(tree: Pytree, like: Pytree) -> Pytree:
    """Match `like`'s residency: bucket each subtree of `tree` wherever `like`
    holds a BucketedState (same layout), pass everything else through.

    The inverse of `to_portable` against a live template — how a
    pytree-shaped checkpoint re-enters a bucket-resident executor. A node
    that is *already* bucketed (state handed back from another resident run)
    is re-grouped in place via `rebucket` instead of being viewed out and
    re-gathered.
    """
    def f(n_like, n):
        if is_bucketed(n_like):
            if is_bucketed(n):
                return rebucket(n, n_like.layout)
            return BucketedState.from_tree(n, layout=n_like.layout)
        return n
    return jax.tree.map(f, like, tree, is_leaf=is_bucketed)


def is_resident(tree: Pytree) -> bool:
    """True when any node of `tree` is a BucketedState."""
    return any(is_bucketed(n)
               for n in jax.tree.leaves(tree, is_leaf=is_bucketed))


def layout_stamp(tree: Pytree) -> list[dict]:
    """JSON-able provenance record of every resident node's bucket layout
    (checkpoint manifests stamp this next to the pytree-shaped arrays)."""
    out = []
    for n in jax.tree.leaves(tree, is_leaf=is_bucketed):
        if is_bucketed(n):
            out.append({"n_leaves": n.layout.n_leaves,
                        "groups": [{"dtype": g.dtype, "size": g.size}
                                   for g in n.layout.groups]})
    return out


# ---------------------------------------------------------------------------
# Fused-path switch
# ---------------------------------------------------------------------------

_FUSED_DEFAULT: Optional[bool] = None


def set_fused_default(enabled: Optional[bool]) -> None:
    """Process-wide override for the fused weight-space path (test hook)."""
    global _FUSED_DEFAULT
    _FUSED_DEFAULT = enabled


def fused_path_enabled(override: Optional[bool] = None) -> bool:
    """Resolve the fused-path switch: override > process default > platform."""
    if override is not None:
        return bool(override)
    if _FUSED_DEFAULT is not None:
        return _FUSED_DEFAULT
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Bucketed weight-space primitives (thin sums over the per-bucket kernels)
# ---------------------------------------------------------------------------

def group_buffers(tree: Pytree, layout: Optional[BucketLayout] = None
                  ) -> tuple[list[jax.Array], BucketLayout]:
    """`tree` as per-group flat buffers: free for a BucketedState (they ARE
    its leaves), one gather for a plain pytree. Callers thread `layout` so a
    hot path never rebuilds it per call (it is only consulted for plain
    trees; a BucketedState carries its own)."""
    if is_bucketed(tree):
        return list(tree.buffers), tree.layout
    layout = layout or bucket_layout(tree)
    return tree_to_buckets(tree, layout), layout


def bucketed_sq_norm(tree: Pytree, layout: Optional[BucketLayout] = None,
                     *, impl: Optional[str] = None) -> jax.Array:
    """Global squared L2 norm via one single-pass kernel per bucket."""
    bufs, _ = group_buffers(tree, layout)
    parts = [ops.sq_norm(b, impl=impl) for b in bufs]
    return jnp.sum(jnp.stack(parts)) if parts else jnp.float32(0.0)


def bucketed_axpy(alpha, x: Pytree, y: Pytree, *,
                  layout: Optional[BucketLayout] = None,
                  impl: Optional[str] = None) -> Pytree:
    """alpha * x + y on buckets (the perturbation axpy), dtypes of `y` kept.

    Resident in, resident out: when `y` is a BucketedState the result stays
    bucket-shaped (no scatter); a plain `y` keeps the gather/scatter-per-call
    behavior with its layout threaded by the caller.
    """
    yb, layout = group_buffers(y, layout)
    xb, _ = group_buffers(x, layout)
    assert len(xb) == len(yb), (len(xb), len(yb))
    out = [ops.fused_axpy(alpha, xi, yi, impl=impl) for xi, yi in zip(xb, yb)]
    if is_bucketed(y):
        return BucketedState(buffers=tuple(out), layout=layout)
    return buckets_to_tree(out, layout, y)


def bucketed_dot_norms(a: Pytree, b: Pytree, *,
                       layout: Optional[BucketLayout] = None,
                       impl: Optional[str] = None
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(<a,b>, ||a||^2, ||b||^2) in one HBM pass over (a, b) per bucket.

    The AsyncSAM ascent-state refresh needs all three (cosine metric + the
    carried ascent norm); the per-leaf composition streams both trees three
    times. Resident operands use their own buffers; plain trees use the
    caller-threaded `layout` (no per-call layout rebuild).
    """
    ab, layout = group_buffers(a, layout)
    bb, _ = group_buffers(b, layout)
    assert len(ab) == len(bb), (len(ab), len(bb))
    parts = [ops.fused_dot_norms(ai, bi, impl=impl) for ai, bi in zip(ab, bb)]
    if not parts:
        z = jnp.float32(0.0)
        return z, z, z
    dot = jnp.sum(jnp.stack([p[0] for p in parts]))
    sq_a = jnp.sum(jnp.stack([p[1] for p in parts]))
    sq_b = jnp.sum(jnp.stack([p[2] for p in parts]))
    return dot, sq_a, sq_b
