"""Flat-buffer (dtype-bucketed) views of parameter pytrees.

The weight-space epilogue of a training step — perturb, global norm, clip,
weight decay, momentum/Adam, lr scale, apply — is HBM-bound: every pass
re-streams all parameter elements. The fused kernels in `repro.kernels`
operate on *flat* vectors, so this module provides the bridge: a pytree is
viewed as one contiguous buffer per leaf dtype (fp32 optimizer state and
bf16/fp32 params stay in their native dtypes, unlike
`trees.tree_flatten_to_vector` which casts everything to fp32), with the
leaf -> (bucket, offset) layout computed once per (treedef, shapes, dtypes)
signature and cached.

Grouping is by the layout tree's leaf dtype; a congruent tree (grads,
momentum, Adam moments, the AsyncSAM ascent gradient) is bucketed by the SAME
grouping using its own leaf dtypes, so a bf16 param bucket can pair with an
fp32 gradient bucket inside one single-pass kernel.

`fused_path_enabled` is the one switch every fused-weight-space call site
consults: explicit override > process default (`set_fused_default`, the test
hook) > platform (on for TPU, off elsewhere — the `ops._resolve` convention).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops

Pytree = Any


@dataclasses.dataclass(frozen=True)
class BucketGroup:
    """One dtype bucket: which leaves it holds and where they live."""
    dtype: str                      # layout-tree dtype name (grouping key)
    leaf_indices: tuple[int, ...]   # indices into the flattened leaf list
    offsets: tuple[int, ...]        # element offset of each leaf in the buffer
    sizes: tuple[int, ...]          # element count of each leaf
    size: int                       # total elements in the buffer


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    treedef: Any
    shapes: tuple[tuple[int, ...], ...]   # per-leaf shapes (flatten order)
    groups: tuple[BucketGroup, ...]       # sorted by dtype name
    n_leaves: int


_LAYOUT_CACHE: dict = {}


def bucket_layout(tree: Pytree) -> BucketLayout:
    """Layout for `tree`, cached on (treedef, shapes, dtypes). Trace-safe."""
    leaves, treedef = jax.tree.flatten(tree)
    key = (treedef, tuple((tuple(x.shape), jnp.dtype(x.dtype).name)
                          for x in leaves))
    hit = _LAYOUT_CACHE.get(key)
    if hit is not None:
        return hit
    by_dtype: dict[str, list[int]] = {}
    for i, x in enumerate(leaves):
        by_dtype.setdefault(jnp.dtype(x.dtype).name, []).append(i)
    groups = []
    for dname in sorted(by_dtype):
        idx = by_dtype[dname]
        sizes = tuple(math.prod(leaves[i].shape) for i in idx)
        offsets, off = [], 0
        for s in sizes:
            offsets.append(off)
            off += s
        groups.append(BucketGroup(dtype=dname, leaf_indices=tuple(idx),
                                  offsets=tuple(offsets), sizes=sizes, size=off))
    layout = BucketLayout(treedef=treedef,
                          shapes=tuple(tuple(x.shape) for x in leaves),
                          groups=tuple(groups), n_leaves=len(leaves))
    _LAYOUT_CACHE[key] = layout
    return layout


def tree_to_buckets(tree: Pytree, layout: BucketLayout) -> list[jax.Array]:
    """Concatenate `tree`'s leaves into one flat buffer per layout group.

    `tree` must be congruent with the layout tree (same structure/shapes);
    its dtypes may differ as long as they are uniform within each group.
    """
    leaves = jax.tree.leaves(tree)
    assert len(leaves) == layout.n_leaves, (len(leaves), layout.n_leaves)
    out = []
    for grp in layout.groups:
        parts = [leaves[i].reshape(-1) for i in grp.leaf_indices]
        dt = parts[0].dtype
        assert all(p.dtype == dt for p in parts), \
            f"mixed dtypes within bucket {grp.dtype}: {[p.dtype for p in parts]}"
        out.append(parts[0] if len(parts) == 1 else jnp.concatenate(parts))
    return out


def buckets_to_tree(bufs: list[jax.Array], layout: BucketLayout,
                    like: Pytree) -> Pytree:
    """Inverse of tree_to_buckets; output shapes/dtypes come from `like`."""
    leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == layout.n_leaves
    new = list(leaves)
    for buf, grp in zip(bufs, layout.groups):
        for i, off, size in zip(grp.leaf_indices, grp.offsets, grp.sizes):
            new[i] = (buf[off:off + size]
                      .reshape(layout.shapes[i]).astype(leaves[i].dtype))
    return jax.tree.unflatten(treedef, new)


# ---------------------------------------------------------------------------
# Fused-path switch
# ---------------------------------------------------------------------------

_FUSED_DEFAULT: Optional[bool] = None


def set_fused_default(enabled: Optional[bool]) -> None:
    """Process-wide override for the fused weight-space path (test hook)."""
    global _FUSED_DEFAULT
    _FUSED_DEFAULT = enabled


def fused_path_enabled(override: Optional[bool] = None) -> bool:
    """Resolve the fused-path switch: override > process default > platform."""
    if override is not None:
        return bool(override)
    if _FUSED_DEFAULT is not None:
        return _FUSED_DEFAULT
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Bucketed weight-space primitives (thin sums over the per-bucket kernels)
# ---------------------------------------------------------------------------

def bucketed_sq_norm(tree: Pytree, layout: Optional[BucketLayout] = None,
                     *, impl: Optional[str] = None) -> jax.Array:
    """Global squared L2 norm via one single-pass kernel per bucket."""
    layout = layout or bucket_layout(tree)
    bufs = tree_to_buckets(tree, layout)
    parts = [ops.sq_norm(b, impl=impl) for b in bufs]
    return jnp.sum(jnp.stack(parts)) if parts else jnp.float32(0.0)


def bucketed_axpy(alpha, x: Pytree, y: Pytree, *,
                  impl: Optional[str] = None) -> Pytree:
    """alpha * x + y on buckets (the perturbation axpy), dtypes of `y` kept."""
    layout = bucket_layout(y)
    xb = tree_to_buckets(x, layout)
    yb = tree_to_buckets(y, layout)
    out = [ops.fused_axpy(alpha, xi, yi, impl=impl) for xi, yi in zip(xb, yb)]
    return buckets_to_tree(out, layout, y)


def bucketed_dot_norms(a: Pytree, b: Pytree, *, impl: Optional[str] = None
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(<a,b>, ||a||^2, ||b||^2) in one HBM pass over (a, b) per bucket.

    The AsyncSAM ascent-state refresh needs all three (cosine metric + the
    carried ascent norm); the per-leaf composition streams both trees three
    times.
    """
    layout = bucket_layout(a)
    ab = tree_to_buckets(a, layout)
    bb = tree_to_buckets(b, layout)
    parts = [ops.fused_dot_norms(ai, bi, impl=impl) for ai, bi in zip(ab, bb)]
    if not parts:
        z = jnp.float32(0.0)
        return z, z, z
    dot = jnp.sum(jnp.stack([p[0] for p in parts]))
    sq_a = jnp.sum(jnp.stack([p[1] for p in parts]))
    sq_b = jnp.sum(jnp.stack([p[2] for p in parts]))
    return dot, sq_a, sq_b
