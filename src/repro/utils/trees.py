"""Pytree utilities shared across the framework.

Everything here is pure-JAX and jit-safe. These helpers are the substrate for
the SAM family (repro.core), the optimizers (repro.optim) and the gradient
compression / checkpoint layers, so they are deliberately small and heavily
tested (tests/test_trees.py, property-based).
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

Pytree = Any


def tree_map(f: Callable, *trees: Pytree) -> Pytree:
    return jax.tree.map(f, *trees)


def tree_zeros_like(tree: Pytree, dtype=None) -> Pytree:
    return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=dtype), tree)


def tree_ones_like(tree: Pytree) -> Pytree:
    return jax.tree.map(jnp.ones_like, tree)


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a: Pytree, s) -> Pytree:
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x: Pytree, y: Pytree) -> Pytree:
    """alpha * x + y, leafwise (the SAM perturbation primitive)."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_dot(a: Pytree, b: Pytree) -> jax.Array:
    """Global inner product <a, b> in fp32.

    Elementwise multiply + sum (NOT jnp.vdot): vdot reshapes each leaf flat,
    and flattening a 2-axis-sharded parameter forces a full all-gather under
    pjit (observed 480GB/device on qwen2.5-32b). The elementwise form keeps
    the operand sharding and lowers to partial sums + a scalar reduce.
    """
    leaves = jax.tree.leaves(jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b))
    return jnp.sum(jnp.stack(leaves)) if leaves else jnp.float32(0.0)


def tree_sq_norm(tree: Pytree) -> jax.Array:
    """Global squared L2 norm, accumulated in fp32."""
    leaves = jax.tree.leaves(jax.tree.map(
        lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), tree))
    return jnp.sum(jnp.stack(leaves)) if leaves else jnp.float32(0.0)


def global_norm(tree: Pytree) -> jax.Array:
    return jnp.sqrt(tree_sq_norm(tree))


def tree_cosine_similarity(a: Pytree, b: Pytree, eps: float = 1e-12) -> jax.Array:
    """Cosine similarity between two gradient pytrees (paper Fig. 1 metric)."""
    return tree_dot(a, b) / (global_norm(a) * global_norm(b) + eps)


def tree_cast(tree: Pytree, dtype) -> Pytree:
    if dtype is None:
        return tree
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_size(tree: Pytree) -> int:
    """Total number of elements (python int; trace-safe on shapes)."""
    return sum(math.prod(x.shape) for x in jax.tree.leaves(tree))


def tree_bytes(tree: Pytree) -> int:
    return sum(math.prod(x.shape) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_where(pred, a: Pytree, b: Pytree) -> Pytree:
    """Leafwise select; `pred` is a scalar boolean (trace-safe)."""
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def tree_random_like(key: jax.Array, tree: Pytree, std: float = 1.0) -> Pytree:
    """Gaussian pytree matching `tree` structure/shapes (ESAM masks, tests)."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    new = [jax.random.normal(k, x.shape, jnp.float32).astype(x.dtype) * std
           for k, x in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, new)


def tree_flatten_to_vector(tree: Pytree) -> jax.Array:
    """Concatenate all leaves into one fp32 vector (compression, landscape viz)."""
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([x.astype(jnp.float32).reshape(-1) for x in leaves])


def tree_unflatten_from_vector(vec: jax.Array, like: Pytree) -> Pytree:
    """Inverse of tree_flatten_to_vector against a template tree."""
    leaves, treedef = jax.tree.flatten(like)
    out, off = [], 0
    for x in leaves:
        n = math.prod(x.shape)
        out.append(vec[off:off + n].reshape(x.shape).astype(x.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def tree_paths(tree: Pytree) -> list[str]:
    """Slash-joined string path for every leaf (checkpoint naming, sharding rules)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return ["/".join(_path_str(k) for k in path) for path, _ in flat]


def _path_str(entry) -> str:
    if isinstance(entry, jax.tree_util.DictKey):
        return str(entry.key)
    if isinstance(entry, jax.tree_util.SequenceKey):
        return str(entry.idx)
    if isinstance(entry, jax.tree_util.GetAttrKey):
        return str(entry.name)
    return str(entry)


def tree_map_with_path(f: Callable[[str, jax.Array], Any], tree: Pytree) -> Pytree:
    """Map with the slash-joined leaf path as first argument."""
    def g(path, leaf):
        return f("/".join(_path_str(k) for k in path), leaf)
    return jax.tree_util.tree_map_with_path(g, tree)
