from repro.utils import buckets  # noqa: F401
from repro.utils.metrics import scalar_metrics  # noqa: F401
from repro.utils.trees import (  # noqa: F401
    global_norm,
    tree_add,
    tree_axpy,
    tree_bytes,
    tree_cast,
    tree_cosine_similarity,
    tree_dot,
    tree_flatten_to_vector,
    tree_map_with_path,
    tree_paths,
    tree_random_like,
    tree_scale,
    tree_size,
    tree_sq_norm,
    tree_sub,
    tree_unflatten_from_vector,
    tree_where,
    tree_zeros_like,
)
