"""Shared metric plumbing for training loops and callbacks."""
from __future__ import annotations


def scalar_metrics(metrics: dict) -> dict:
    """The float()-able subset of a step's metrics, as host floats.

    The one filter every history/logging consumer applies (Engine, the
    resilient loop, LoggingCallback), kept in one place so metrics_history
    has the same shape on every execution path.
    """
    return {k: float(v) for k, v in metrics.items()
            if hasattr(v, "__float__")}
