"""Serving launcher: batched prefill + decode loop with request queueing.

A minimal continuous-batching server core, CPU-runnable on reduced configs:
requests accumulate in a queue, are admitted into fixed prefill batches, and
decode proceeds for the whole in-flight batch one token per step (greedy or
temperature sampling). The same prefill/decode step functions are what the
dry-run lowers at the production shapes (prefill_32k / decode_32k /
long_500k).

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
      --requests 8 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.data.synthetic import TokenTask
from repro.models import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(args.seed))

    task = TokenTask(vocab_size=cfg.vocab_size, seed=args.seed)
    prompts = task.sample(args.requests, args.prompt_len, stream=0)
    total_len = args.prompt_len + args.max_new

    prefill = jax.jit(lambda p, b: bundle.prefill(p, b, pad_to=total_len))
    decode = jax.jit(bundle.decode)

    batch = {"tokens": jnp.asarray(prompts)}
    if cfg.vision is not None:
        batch["patch_embeds"] = jnp.zeros(
            (args.requests, cfg.vision.n_image_tokens, cfg.vision.clip_dim),
            jnp.dtype(cfg.compute_dtype))
    if cfg.family == "audio":
        from repro.models.registry import whisper_enc_len
        batch["enc_frames"] = jnp.zeros(
            (args.requests, whisper_enc_len(cfg, args.prompt_len), cfg.d_model),
            jnp.dtype(cfg.compute_dtype))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    key = jax.random.PRNGKey(args.seed + 1)
    tok = _pick(logits[:, -1], args.temperature, key)
    generated = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.max_new - 1):
        logits, cache = decode(params, cache, {"tokens": tok})
        key = jax.random.fold_in(key, i)
        tok = _pick(logits[:, -1], args.temperature, key)
        generated.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    out = np.stack(generated, axis=1)
    print(f"prefill: {args.requests}x{args.prompt_len} tok in {t_prefill:.3f}s "
          f"({args.requests * args.prompt_len / max(t_prefill, 1e-9):.0f} tok/s)")
    print(f"decode : {args.max_new - 1} steps in {t_decode:.3f}s "
          f"({args.requests * (args.max_new - 1) / max(t_decode, 1e-9):.0f} tok/s)")
    print("sample continuation (request 0):", out[0][:12].tolist())


def _pick(last_logits: jax.Array, temperature: float, key) -> jax.Array:
    if temperature <= 0:
        return jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
    return jax.random.categorical(
        key, last_logits / temperature, axis=-1)[:, None].astype(jnp.int32)


if __name__ == "__main__":
    main()
