import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. constructs abstract TrainState / cache / batch stand-ins
     (ShapeDtypeStruct; no device allocation),
  3. jit-lowers the AsyncSAM train_step (train shapes) or the serve step
     (prefill/decode shapes) with explicit in/out shardings,
  4. compiles, prints memory_analysis() and cost_analysis(),
  5. extracts the collective-op inventory from the optimized HLO, and
  6. writes a JSON artifact consumed by benchmarks/roofline.py and
     EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--quick]
"""
import argparse
import dataclasses
import json
import pathlib
import re
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.core import MethodConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (batch_spec_tree, cache_spec_tree,
                                   state_spec_tree, to_named)
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import (SHAPES, batch_spec, build_model, decode_batch_spec,
                          shape_applicable)
from repro.models.config import ModelConfig, ShapeSpec
from repro.optim import make_optimizer
from repro.utils import trees

ARTIFACT_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# hardware constants (TPU v5e-class target; see EXPERIMENTS.md §Roofline)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


def input_specs(arch: str, shape_name: str = "train_4k",
                method_cfg: Optional[MethodConfig] = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the given cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    method_cfg = method_cfg or MethodConfig()
    if shape.kind == "train":
        return batch_spec(cfg, shape, ascent_fraction=method_cfg.ascent_fraction)
    if shape.kind == "prefill":
        return batch_spec(cfg, shape)
    return decode_batch_spec(cfg, shape)


def _abstract_cache(cfg: ModelConfig, shape: ShapeSpec):
    from repro.models.registry import build_model as _bm

    bundle = _bm(cfg)
    return jax.eval_shape(
        lambda: bundle.init_cache(shape.global_batch, shape.seq_len,
                                  pos=shape.seq_len - 1))


# ---------------------------------------------------------------------------
# HLO collective inventory
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64)\[([\d,]*)\]")
_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1}
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s*(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")
_GROUP_RE = re.compile(r"replica_groups=\{([^}]*(?:\},\{[^}]*)*)\}|"
                       r"replica_groups=\[(\d+),(\d+)\]")


def _result_bytes(line: str) -> int:
    """Sum byte sizes of all result shapes on an HLO instruction line."""
    lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split("(", 1)[0]
    total = 0
    for m in _SHAPE_RE.finditer(lhs):
        dims = m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


def _group_size(line: str) -> int:
    m = _GROUP_RE.search(line)
    if not m:
        return 1
    if m.group(2) is not None:          # iota format [g,n]<=[...]
        return int(m.group(3))
    first = m.group(1).split("}", 1)[0]
    return max(1, first.count(",") + 1)


def collective_inventory(hlo_text: str) -> list[dict]:
    """One record per collective op: kind, result bytes, group size."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done(" in line:    # count start ops once
            continue
        out.append({"kind": m.group(1), "bytes": _result_bytes(line),
                    "group": _group_size(line)})
    return out


def collective_cost_bytes(inventory: list[dict]) -> float:
    """Per-chip bytes-on-the-wire estimate (ring algorithms; DESIGN.md §5)."""
    total = 0.0
    for rec in inventory:
        b, n = rec["bytes"], max(2, rec["group"])
        ring = (n - 1) / n
        if rec["kind"] == "all-reduce":
            total += 2 * b * ring
        elif rec["kind"] == "all-gather":
            total += b * ring                      # result-sized, gathered in
        elif rec["kind"] == "reduce-scatter":
            total += b * (n - 1)                   # operand = result * n
        elif rec["kind"] == "all-to-all":
            total += b * ring
        else:                                      # collective-permute
            total += b
    return total


# ---------------------------------------------------------------------------
# One-cell dry-run
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    status: str                  # ok | skipped | failed
    note: str = ""
    lower_s: float = 0.0
    compile_s: float = 0.0
    flops: float = 0.0           # per-device HLO flops
    bytes_accessed: float = 0.0  # per-device HLO bytes
    collective_bytes: float = 0.0
    peak_memory_per_device: float = 0.0
    n_collectives: int = 0
    output_bytes: float = 0.0
    argument_bytes: float = 0.0
    param_count: int = 0         # parameter elements (train cells)
    param_bytes: int = 0         # parameter tree bytes (train cells)
    inventory: list = dataclasses.field(default_factory=list)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             method: str = "async_sam", method_cfg: Optional[MethodConfig] = None,
             save: bool = True, verbose: bool = True,
             cfg_override: Optional[ModelConfig] = None,
             tag: str = "") -> CellResult:
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    result = CellResult(arch=arch, shape=shape_name, mesh=mesh_name, status="ok",
                        note=tag)

    ok, why = shape_applicable(cfg, shape)
    if not ok:
        result.status, result.note = "skipped", why
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: SKIP ({why})")
        if save:
            _save(result, tag)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    bundle = build_model(cfg)
    # default execution profile: AsyncSAM with b'/b=25% and 4 microbatches
    mcfg = method_cfg or MethodConfig(name=method, n_microbatches=4)

    from repro.engine import mesh_context
    from repro.models.partitioning import activation_sharding

    t0 = time.time()
    try:
        with mesh_context(mesh), activation_sharding(mesh):
            if shape.kind == "train":
                # the Engine's executor owns the jit/sharding plumbing here
                # (the same path launch/train.py drives), not a local shim
                from repro.engine import FusedExecutor
                executor = FusedExecutor(bundle.loss_fn, mcfg,
                                         make_optimizer("adamw", 1e-3,
                                                        clip_norm=1.0),
                                         mesh=mesh, model_cfg=cfg)
                state_sds = executor.abstract_state(
                    lambda: bundle.init(jax.random.PRNGKey(0)),
                    jax.random.PRNGKey(1))
                batch_sds = batch_spec(cfg, shape,
                                       ascent_fraction=mcfg.ascent_fraction)
                result.param_count = trees.tree_size(state_sds.params)
                result.param_bytes = trees.tree_bytes(state_sds.params)
                lowered = executor.lower(state_sds, batch_sds)
            elif shape.kind == "prefill":
                step = make_prefill_step(bundle)
                params_sds = jax.eval_shape(
                    lambda: bundle.init(jax.random.PRNGKey(0)))
                batch_sds = batch_spec(cfg, shape)
                params_sh = to_named(state_spec_tree(params_sds, cfg, mesh), mesh)
                batch_sh = to_named(batch_spec_tree(batch_sds, mesh), mesh)
                cache_sds = jax.eval_shape(step, params_sds, batch_sds)[1]
                cache_sh = to_named(cache_spec_tree(cache_sds, cfg, mesh), mesh)
                jitted = jax.jit(step, in_shardings=(params_sh, batch_sh),
                                 out_shardings=(None, cache_sh))
                lowered = jitted.lower(params_sds, batch_sds)
            else:  # decode
                step = make_decode_step(bundle)
                params_sds = jax.eval_shape(
                    lambda: bundle.init(jax.random.PRNGKey(0)))
                cache_sds = _abstract_cache(cfg, shape)
                batch_sds = decode_batch_spec(cfg, shape)
                params_sh = to_named(state_spec_tree(params_sds, cfg, mesh), mesh)
                cache_sh = to_named(cache_spec_tree(cache_sds, cfg, mesh), mesh)
                batch_sh = to_named(batch_spec_tree(batch_sds, mesh), mesh)
                jitted = jax.jit(step, in_shardings=(params_sh, cache_sh, batch_sh),
                                 out_shardings=(None, cache_sh),
                                 donate_argnums=(1,))
                lowered = jitted.lower(params_sds, cache_sds, batch_sds)
            result.lower_s = time.time() - t0

            t1 = time.time()
            compiled = lowered.compile()
            result.compile_s = time.time() - t1

            mem = compiled.memory_analysis()
            from repro.engine import cost_analysis_dict
            cost = cost_analysis_dict(compiled)
            result.flops = float(cost.get("flops", 0.0))
            result.bytes_accessed = float(cost.get("bytes accessed", 0.0))
            if mem is not None:
                result.peak_memory_per_device = float(
                    getattr(mem, "temp_size_in_bytes", 0)
                    + getattr(mem, "argument_size_in_bytes", 0)
                    + getattr(mem, "output_size_in_bytes", 0)
                    - getattr(mem, "alias_size_in_bytes", 0))
                result.argument_bytes = float(getattr(mem, "argument_size_in_bytes", 0))
                result.output_bytes = float(getattr(mem, "output_size_in_bytes", 0))
            hlo = compiled.as_text()
            inv = collective_inventory(hlo)
            result.n_collectives = len(inv)
            result.collective_bytes = collective_cost_bytes(inv)
            # keep a compact inventory (top ops by bytes)
            agg: dict[str, list[float]] = {}
            for rec in inv:
                a = agg.setdefault(rec["kind"], [0, 0.0])
                a[0] += 1
                a[1] += rec["bytes"]
            result.inventory = [
                {"kind": k, "count": v[0], "result_bytes": v[1]}
                for k, v in sorted(agg.items())]

            if verbose:
                print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
                      f"(lower {result.lower_s:.1f}s, compile {result.compile_s:.1f}s)")
                print("  memory_analysis:", mem)
                print(f"  cost_analysis: flops={result.flops:.3e} "
                      f"bytes={result.bytes_accessed:.3e}")
                print(f"  collectives: n={result.n_collectives} "
                      f"wire_bytes/chip={result.collective_bytes:.3e}")
    except Exception as e:  # noqa: BLE001 — a failing cell is a recorded bug
        result.status = "failed"
        result.note = f"{type(e).__name__}: {e}"[:500]
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: FAILED {result.note}")

    if save:
        _save(result, tag)
    return result


def _save(result: CellResult, tag: str = "") -> None:
    ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    path = ARTIFACT_DIR / f"{result.arch}_{result.shape}_{result.mesh}{suffix}.json"
    path.write_text(json.dumps(result.to_json(), indent=1))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--method", default="async_sam")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            r = run_cell(arch, shape, multi_pod=mp, method=args.method,
                         tag=args.tag)
            failures += r.status == "failed"
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
