"""Step builders: train_step (SAM-family) and serve steps (prefill/decode).

DEPRECATED for training: new code should drive training through
`repro.engine` (`FusedExecutor` / `HeteroExecutor` + `Engine.fit`), which owns
the mesh/sharding/jit/donation plumbing that callers of `make_train_setup`
had to hand-roll. The 512-device dry-run now lowers its train cells through
`FusedExecutor.abstract_state` / `FusedExecutor.lower` too, so this module
remains only as the serve-path shim (prefill/decode steps) and as a thin
deprecation alias for the train-setup surface, kept so existing callers and
tests keep passing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import Method, MethodConfig, TrainState, init_train_state, make_method
from repro.models.registry import ModelBundle
from repro.optim import GradientTransform, make_optimizer

Pytree = Any


@dataclasses.dataclass(frozen=True)
class TrainSetup:
    bundle: ModelBundle
    method: Method
    method_cfg: MethodConfig
    optimizer: GradientTransform
    step_fn: Callable[[TrainState, dict], tuple[TrainState, dict]]

    def init_state(self, params: Pytree, rng: jax.Array) -> TrainState:
        return init_train_state(params, self.optimizer, self.method, rng)

    def fused_executor(self, *, mesh=None, model_cfg=None, donate: bool = True):
        """Bridge to the Engine API: the same pieces as a `StepExecutor`."""
        from repro.engine import FusedExecutor
        return FusedExecutor(self.bundle.loss_fn, self.method, self.optimizer,
                             mesh=mesh, model_cfg=model_cfg, donate=donate)


def make_train_setup(bundle: ModelBundle,
                     method_cfg: Optional[MethodConfig] = None,
                     optimizer: Optional[GradientTransform] = None,
                     lr: float = 1e-3) -> TrainSetup:
    method_cfg = method_cfg or MethodConfig()
    method = make_method(method_cfg)
    optimizer = optimizer or make_optimizer("adamw", lr)
    step_fn = method.make_step(bundle.loss_fn, optimizer)
    return TrainSetup(bundle=bundle, method=method, method_cfg=method_cfg,
                      optimizer=optimizer, step_fn=step_fn)


def make_prefill_step(bundle: ModelBundle) -> Callable:
    def prefill_step(params: Pytree, batch: dict):
        return bundle.prefill(params, batch)

    return prefill_step


def make_decode_step(bundle: ModelBundle) -> Callable:
    def decode_step(params: Pytree, cache: Pytree, batch: dict):
        return bundle.decode(params, cache, batch)

    return decode_step
