"""Step builders: train_step (SAM-family) and serve steps (prefill/decode).

These close over a ModelBundle + method + optimizer and return pure functions
ready for jax.jit with the shardings from launch.sharding. The same builders
serve the CPU smoke tests, the benchmarks, and the 512-device dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import Method, MethodConfig, TrainState, init_train_state, make_method
from repro.models.registry import ModelBundle
from repro.optim import GradientTransform, make_optimizer

Pytree = Any


@dataclasses.dataclass(frozen=True)
class TrainSetup:
    bundle: ModelBundle
    method: Method
    method_cfg: MethodConfig
    optimizer: GradientTransform
    step_fn: Callable[[TrainState, dict], tuple[TrainState, dict]]

    def init_state(self, params: Pytree, rng: jax.Array) -> TrainState:
        return init_train_state(params, self.optimizer, self.method, rng)


def make_train_setup(bundle: ModelBundle,
                     method_cfg: Optional[MethodConfig] = None,
                     optimizer: Optional[GradientTransform] = None,
                     lr: float = 1e-3) -> TrainSetup:
    method_cfg = method_cfg or MethodConfig()
    method = make_method(method_cfg)
    optimizer = optimizer or make_optimizer("adamw", lr)
    step_fn = method.make_step(bundle.loss_fn, optimizer)
    return TrainSetup(bundle=bundle, method=method, method_cfg=method_cfg,
                      optimizer=optimizer, step_fn=step_fn)


def make_prefill_step(bundle: ModelBundle) -> Callable:
    def prefill_step(params: Pytree, batch: dict):
        return bundle.prefill(params, batch)

    return prefill_step


def make_decode_step(bundle: ModelBundle) -> Callable:
    def decode_step(params: Pytree, cache: Pytree, batch: dict):
        return bundle.decode(params, cache, batch)

    return decode_step
