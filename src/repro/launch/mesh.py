"""Production mesh builders.

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The assigned production mesh: 16x16 chips per pod; 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Single-host debug mesh over the locally visible devices."""
    n = jax.device_count()
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """Mesh axes that jointly form the data-parallel dimension."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def axis_size(mesh, axes) -> int:
    size = 1
    for a in (axes if isinstance(axes, (tuple, list)) else (axes,)):
        size *= mesh.shape[a]
    return size
