"""Training launcher: --arch x --method x mesh -> fault-tolerant run.

CPU-runnable end-to-end (reduced configs); the same launcher drives pod runs —
mesh construction, sharding, checkpointing and the resilient loop are the
production code paths exercised by the dry-run at full scale.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --method async_sam --steps 100 --batch 8 --seq 64
  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --reduced \
      --method sam --steps 50 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import ARCH_IDS, get_config
from repro.core import MethodConfig, make_method
from repro.checkpoint import CheckpointManager
from repro.data import PipelineConfig, TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import batch_spec_tree, state_spec_tree, to_named
from repro.launch.steps import make_train_setup
from repro.models import build_model
from repro.models.partitioning import activation_sharding
from repro.optim import cosine_schedule, make_optimizer
from repro.runtime import ResilienceConfig, run_resilient


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-trainable)")
    ap.add_argument("--method", default="async_sam")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--rho", type=float, default=0.05)
    ap.add_argument("--ascent-fraction", type=float, default=0.25)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1,
                    help="TP width of the host mesh")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    bundle = build_model(cfg)
    mcfg = MethodConfig(name=args.method, rho=args.rho,
                        ascent_fraction=args.ascent_fraction,
                        n_microbatches=args.n_micro)
    optimizer = make_optimizer(args.optimizer,
                               cosine_schedule(args.lr, args.steps,
                                               warmup_steps=args.steps // 20))
    setup = make_train_setup(bundle, mcfg, optimizer)
    mesh = make_host_mesh(model_axis=args.model_axis)

    pipe = TokenPipeline(cfg, PipelineConfig(
        global_batch=args.batch, seq_len=args.seq, seed=args.seed,
        ascent_fraction=(args.ascent_fraction
                         if args.method in ("async_sam",) else 0.0)))

    with jax.set_mesh(mesh), activation_sharding(mesh):
        params = bundle.init(jax.random.PRNGKey(args.seed))
        state = setup.init_state(params, jax.random.PRNGKey(args.seed + 1))
        state_sh = to_named(state_spec_tree(jax.eval_shape(lambda: state),
                                            cfg, mesh), mesh)
        state = jax.device_put(state, state_sh)
        jitted = jax.jit(setup.step_fn, donate_argnums=(0,),
                         out_shardings=(state_sh, None))

        t0 = time.time()
        times = []

        def logged_step(st, batch):
            t = time.time()
            st, metrics = jitted(st, batch)
            jax.block_until_ready(st.params)
            times.append(time.time() - t)
            step = int(st.step)
            if step % args.log_every == 0 or step == args.steps:
                scal = {k: f"{float(v):.4f}" for k, v in metrics.items()
                        if hasattr(v, "__float__")}
                print(f"step {step:5d}  {scal}")
            return st, metrics

        if args.ckpt_dir:
            manager = CheckpointManager(args.ckpt_dir, keep=3)
            report = run_resilient(
                logged_step, state, pipe, manager, args.steps,
                ResilienceConfig(save_every=args.save_every))
            state = report.final_state
            print(f"done: {report.steps_done} steps, {report.restarts} restarts, "
                  f"{report.wall_time_s:.1f}s")
        else:
            it = iter(pipe)
            while int(state.step) < args.steps:
                state, _ = logged_step(state, next(it))

        if times:
            steady = times[1:] or times
            tok_s = args.batch * args.seq / (sum(steady) / len(steady))
            print(json.dumps({"arch": cfg.name, "method": args.method,
                              "steps": int(state.step),
                              "mean_step_s": sum(steady) / len(steady),
                              "tokens_per_s": tok_s}))


if __name__ == "__main__":
    main()
