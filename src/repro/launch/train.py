"""Training launcher: --arch x --method x mesh -> fault-tolerant Engine run.

CPU-runnable end-to-end (reduced configs); the same launcher drives pod runs —
mesh construction, sharding, checkpointing and the resilient loop are the
production code paths exercised by the dry-run at full scale. Both executors
go through the same `Engine.fit`:

  --executor fused   one jitted SPMD step (Form A, pod-scale default)
  --executor hetero  two-lane heterogeneous executor (Form B, paper §3.3/§3.4);
                     add --calibrate for the system-aware b' pre-fit probe
  --executor remote  the hetero lanes across processes/hosts: ascent runs in a
                     `repro.service.ascent_server`; point --ascent-addr at a
                     running server, or pass --serve-ascent to spawn one as a
                     localhost subprocess (loopback smoke mode)

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --method async_sam --steps 100 --batch 8 --seq 64
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --method async_sam --steps 20 --executor hetero --calibrate
  # multi-host: on the helper host
  PYTHONPATH=src python -m repro.service.ascent_server \
      --loss arch:olmo-1b:reduced --bind 0.0.0.0:7431
  # ... and on the descent host
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --method async_sam --steps 20 --executor remote --ascent-addr helper:7431
  # single-host loopback (server spawned as a subprocess)
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --method async_sam --steps 20 --executor remote --serve-ascent
  # delta-encoded JOB payloads: ship int8 deltas against the server's params
  # shadow instead of full fp32 snapshots (~4x less wire out)
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --method async_sam --steps 20 --executor remote --serve-ascent \
      --job-compress int8
  # elastic chaos run: shrink the mesh to 4 devices at step 40, grow back to
  # 8 at step 80, hard-preempt down to 2 at step 120 (restores from --ckpt-dir)
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --method async_sam --steps 200 --elastic --chaos 40:4,80:8,120:2:crash \
      --ckpt-dir /tmp/ckpt --telemetry-jsonl /tmp/elastic.jsonl
  # fleet mode: several descent hosts sharing one multi-client ascent pool,
  # perturbing coherently via a `global` sync group (run per descent host)
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --method async_sam --steps 20 --executor remote \
      --ascent-addr pool-host:7431 --sync-group dp0 --auth-token "$TOKEN"
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import ARCH_IDS, get_config
from repro.core import MethodConfig
from repro.checkpoint import CheckpointManager
from repro.data import PipelineConfig, TokenPipeline
from repro.engine import (CheckpointCallback, Engine, FusedExecutor,
                          HeteroExecutor, LoggingCallback, RemoteExecutor,
                          StalenessTelemetry, ThroughputMeter)
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.optim import cosine_schedule, make_optimizer
from repro.runtime import ExecutorConfig, ResilienceConfig


def _parse_device(spec: str):
    """'cpu', 'cpu:1', 'tpu:0' ... -> the jax.Device (None for '')."""
    if not spec:
        return None
    platform, _, idx = spec.partition(":")
    devices = jax.devices(platform)
    return devices[int(idx) if idx else 0]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-trainable)")
    ap.add_argument("--method", default="async_sam")
    ap.add_argument("--executor", choices=("fused", "hetero", "remote"),
                    default="fused",
                    help="fused: one SPMD step; hetero: two-lane async_sam; "
                         "remote: ascent lane behind repro.service")
    ap.add_argument("--calibrate", action="store_true",
                    help="hetero/remote: measure the system-aware b'/b pre-fit")
    ap.add_argument("--ascent-addr", default="",
                    help="remote only: address of a running ascent server "
                         "('host:port' or 'unix:/path')")
    ap.add_argument("--serve-ascent", action="store_true",
                    help="remote only: spawn the ascent server as a localhost "
                         "subprocess (loopback mode; --ascent-addr optional)")
    ap.add_argument("--job-compress", choices=("none", "int8", "topk"),
                    default="none",
                    help="remote only: JOB-direction (params out) encoding. "
                         "'none' ships full fp32 snapshots (bitwise parity "
                         "with --executor hetero under lockstep); int8/topk "
                         "quantize the delta against the server's shadow of "
                         "the last-synced params (~4x less wire for int8)")
    ap.add_argument("--job-delta", choices=("on", "off"), default="on",
                    help="remote only: delta-encode JOB payloads against the "
                         "server's params shadow (off: every exchange ships "
                         "a full snapshot even with --job-compress set)")
    ap.add_argument("--pool-workers", type=int, default=0,
                    help="remote + --serve-ascent only: ascent workers in the "
                         "spawned pool server (0 = server default; a shared "
                         "pool serving several descent hosts wants >= 2)")
    ap.add_argument("--sync-group", default="",
                    help="remote only: `global` ascent-sync group name — "
                         "clients declaring the same group receive the "
                         "pool's shared LSAM-smoothed ascent gradient per "
                         "(generation, step), so data-parallel replicas "
                         "perturb coherently")
    ap.add_argument("--auth-token", default="",
                    help="remote only: shared secret presented in HELLO "
                         "(must match the pool server's --auth-token; "
                         "required for non-loopback deployments)")
    ap.add_argument("--netchaos", default="",
                    help="remote only: interpose service.netchaos.ChaosProxy "
                         "between the client and the ascent server and drive "
                         "it with this fault schedule — comma-separated "
                         "'action[:FRAME][:key=val...]', e.g. "
                         "'corrupt:GRAD:every=5,drop:JOB_DELTA:nth=7,"
                         "blackhole:GRAD:nth=9:duration_s=0.5' (actions: "
                         "corrupt, truncate, drop, delay, stall, blackhole, "
                         "duplicate). Local soak harness for the wire "
                         "hardening + the --lane-ladder response")
    ap.add_argument("--lane-ladder", action="store_true",
                    help="hetero/remote: health-driven degradation ladder — "
                         "an unhealthy/stalled ascent lane fails over one "
                         "rung (remote -> in-process thread -> ledger-only) "
                         "and recovers back up after a probationary cooldown; "
                         "transitions land in lane_state/lane_failovers/"
                         "lane_recoveries telemetry")
    ap.add_argument("--guard", action="store_true",
                    help="numerics guard (runtime.guard): in-step skip of "
                         "non-finite updates, loss-spike + stale-ascent "
                         "detection, a rho de-escalation ladder (halve rho "
                         "rung by rung down to plain descent, recover after "
                         "a probationary cooldown), and — with --ckpt-dir — "
                         "diverge-proof PoisonBatch rollback that restores "
                         "the model but advances the data cursor past the "
                         "poison window; telemetry lands in guard_state/"
                         "rho_scale/steps_skipped/poison_rollbacks")
    ap.add_argument("--numchaos", default="",
                    help="deterministic numerics-chaos injector over the "
                         "data stream: comma-separated 'kind[:key=val...]' "
                         "rules keyed on the batch cursor, e.g. "
                         "'nan_grad:nth=40:span=8,spike:prob=0.01:scale=1e4' "
                         "(kinds: nan_grad, inf_grad, spike). Poisons FLOAT "
                         "batch leaves only — token-only batches pass "
                         "through untouched. Soak harness for --guard")
    ap.add_argument("--watchdog", action="store_true",
                    help="remote + --serve-ascent only: STATS-scraping "
                         "server watchdog — restarts the loopback server "
                         "when it is dead or wedged (counters frozen with "
                         "work queued), under a bounded restart budget")
    ap.add_argument("--ascent-device", default="",
                    help="hetero only: device for the slow ascent lane, e.g. "
                         "'cpu:0' (paper's CPU helper on a CPU+accelerator host)")
    ap.add_argument("--descent-device", default="",
                    help="hetero only: device for the fast descent lane, e.g. "
                         "'tpu:0' or 'gpu:0'")
    ap.add_argument("--fused-update", choices=("auto", "on", "off"),
                    default="auto",
                    help="flat-buffer fused perturb + optimizer epilogue "
                         "(auto: on for TPU, off for CPU)")
    ap.add_argument("--resident", choices=("auto", "on", "off"),
                    default="auto",
                    help="bucket-resident training state: params/opt-state "
                         "persist as dtype buckets, the step runs buffer->"
                         "buffer (auto: follows the resolved fused path; "
                         "checkpoints stay pytree-shaped either way)")
    ap.add_argument("--elastic", action="store_true",
                    help="wrap the executor in ElasticExecutor: survive "
                         "mesh shrink/grow events mid-fit (graceful resizes "
                         "reshard the live state; crash events restore the "
                         "last checkpoint onto the survivors — those need "
                         "--ckpt-dir)")
    ap.add_argument("--chaos", default="",
                    help="elastic only: scripted MeshEvent schedule "
                         "'STEP:DEVICES[:crash],...' e.g. '40:4,80:8,"
                         "120:2:crash' (deterministic chaos harness; in "
                         "production a capacity watcher replaces this)")
    ap.add_argument("--resize-budget", type=int, default=8,
                    help="elastic only: resizes tolerated per window")
    ap.add_argument("--resize-window-s", type=float, default=0.0,
                    help="elastic only: rolling window for --resize-budget "
                         "(0 = lifetime)")
    ap.add_argument("--restart-window-s", type=float, default=0.0,
                    help="rolling window for the checkpoint-restart budget: "
                         "tolerate --max-restarts within this many seconds "
                         "instead of over the whole run (0 = lifetime; a "
                         "spot job wants e.g. 3600)")
    ap.add_argument("--max-restarts", type=int, default=5,
                    help="checkpoint-restart budget (per --restart-window-s "
                         "window when set)")
    ap.add_argument("--telemetry-jsonl", default="",
                    help="write per-step tau/perturbed/step-time records here")
    ap.add_argument("--trace", default="",
                    help="write a Chrome/Perfetto trace-event JSON here: "
                         "descent, ascent lane, pool workers, and elastic "
                         "resizes as named tracks (load at ui.perfetto.dev)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--rho", type=float, default=0.05)
    ap.add_argument("--ascent-fraction", type=float, default=0.25)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1,
                    help="TP width of the host mesh")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()
    lanes = args.executor in ("hetero", "remote")
    if lanes and args.model_axis != 1:
        ap.error("--model-axis applies to --executor fused only "
                 "(the hetero/remote lanes run meshless)")
    if args.calibrate and not lanes:
        ap.error("--calibrate requires --executor hetero or remote")
    if lanes and args.method != "async_sam":
        ap.error(f"--executor {args.executor} realizes async_sam only "
                 f"(got --method {args.method})")
    if (args.ascent_device or args.descent_device) and args.executor != "hetero":
        ap.error("--ascent-device/--descent-device apply to --executor hetero "
                 "only (the remote ascent device is the server's --device)")
    if (args.ascent_addr or args.serve_ascent) and args.executor != "remote":
        ap.error("--ascent-addr/--serve-ascent apply to --executor remote only")
    if ((args.job_compress != "none" or args.job_delta != "on")
            and args.executor != "remote"):
        ap.error("--job-compress/--job-delta apply to --executor remote only "
                 "(the JOB direction exists only on the wire)")
    if ((args.sync_group or args.auth_token or args.pool_workers)
            and args.executor != "remote"):
        ap.error("--pool-workers/--sync-group/--auth-token apply to "
                 "--executor remote only (they configure the ascent pool)")
    if args.pool_workers and not args.serve_ascent:
        ap.error("--pool-workers configures the spawned loopback server; "
                 "with --ascent-addr the pool size is the server's "
                 "--pool-workers")
    if args.executor == "remote" and not (args.ascent_addr or args.serve_ascent):
        ap.error("--executor remote needs --ascent-addr (a running "
                 "ascent server) or --serve-ascent (loopback subprocess)")
    if args.netchaos and args.executor != "remote":
        ap.error("--netchaos applies to --executor remote only (it attacks "
                 "the ascent wire)")
    if args.lane_ladder and args.executor not in ("hetero", "remote"):
        ap.error("--lane-ladder applies to --executor hetero or remote "
                 "(the fused executor has no ascent lane to degrade)")
    if args.watchdog and not args.serve_ascent:
        ap.error("--watchdog restarts the spawned loopback server; it needs "
                 "--serve-ascent (an external server is restarted by its "
                 "own supervisor)")
    if args.watchdog and args.netchaos:
        ap.error("--watchdog and --netchaos are mutually exclusive: under "
                 "--netchaos the launcher owns the server (behind the "
                 "proxy), so the executor's watchdog could not restart it")
    if args.chaos and not args.elastic:
        ap.error("--chaos needs --elastic (a non-elastic executor cannot "
                 "act on mesh resize events)")
    if args.elastic and args.chaos and not args.ckpt_dir:
        from repro.runtime import parse_schedule as _parse
        if any(e.kind == "crash" for e in _parse(args.chaos).pending):
            ap.error("crash-kind chaos events recover via checkpoint-restart "
                     "— add --ckpt-dir")

    cfg = get_config(args.arch, reduced=args.reduced)
    bundle = build_model(cfg)
    mcfg = MethodConfig(name=args.method, rho=args.rho,
                        ascent_fraction=args.ascent_fraction,
                        n_microbatches=args.n_micro,
                        guard_update=args.guard)
    optimizer = make_optimizer(args.optimizer,
                               cosine_schedule(args.lr, args.steps,
                                               warmup_steps=args.steps // 20))

    pipe = TokenPipeline(cfg, PipelineConfig(
        global_batch=args.batch, seq_len=args.seq, seed=args.seed,
        ascent_fraction=(args.ascent_fraction
                         if args.method in ("async_sam",) else 0.0)))
    numchaos = None
    if args.numchaos:
        from repro.runtime import NumericChaosPipeline, parse_numchaos
        numchaos = parse_numchaos(args.numchaos, seed=args.seed)
        pipe = NumericChaosPipeline(pipe, numchaos)
        print(f"numchaos: {len(numchaos.rules)} rules over the batch stream")

    fused_update = {"auto": None, "on": True, "off": False}[args.fused_update]
    resident = {"auto": None, "on": True, "off": False}[args.resident]
    netchaos_proxy = netchaos_server = None
    if args.executor == "hetero":
        # two host lanes; hand-offs are host arrays, no mesh required.
        # --ascent-device/--descent-device place the lanes on real devices
        # (paper §3.3's CPU helper + accelerator on a two-device host).
        exec_cfg = ExecutorConfig(
            ascent_device=_parse_device(args.ascent_device),
            descent_device=_parse_device(args.descent_device),
            fused_update=fused_update, resident=resident,
            lane_ladder=args.lane_ladder)
        executor = HeteroExecutor(bundle.loss_fn, mcfg, optimizer,
                                  exec_cfg=exec_cfg,
                                  calibrate=args.calibrate)
    elif args.executor == "remote":
        # ascent lane behind repro.service: either a server the operator
        # already runs on another host, or a spawned loopback subprocess
        # holding the same arch/config (the wire carries params + b' batches
        # out and compressed ascent gradients back)
        loss_spec = f"arch:{args.arch}" + (":reduced" if args.reduced else "")
        upstream, serve = args.ascent_addr, args.serve_ascent
        if args.netchaos:
            # chaos soak: the client talks to the proxy, the proxy to the
            # real server — spawned here (not by RemoteExecutor) so the
            # proxy can interpose on the loopback path too
            from repro.service.ascent_server import spawn_server
            from repro.service.netchaos import ChaosProxy, parse_faults
            if serve:
                netchaos_server = spawn_server(
                    loss_spec, pool_workers=args.pool_workers,
                    auth_token=args.auth_token)
                upstream, serve = netchaos_server.addr, False
            netchaos_proxy = ChaosProxy(upstream,
                                        parse_faults(args.netchaos))
            upstream = netchaos_proxy.addr
            print(f"netchaos: proxy {netchaos_proxy.addr} -> "
                  f"{netchaos_proxy.upstream} "
                  f"({len(netchaos_proxy.schedule.rules)} fault rules)")
        exec_cfg = ExecutorConfig(ascent_addr=upstream,
                                  serve_ascent=serve,
                                  loss_spec=loss_spec,
                                  fused_update=fused_update,
                                  resident=resident,
                                  job_compress=args.job_compress,
                                  job_delta=(args.job_delta == "on"),
                                  pool_workers=args.pool_workers,
                                  sync_group=args.sync_group,
                                  auth_token=args.auth_token,
                                  lane_ladder=args.lane_ladder,
                                  watchdog=args.watchdog)
        executor = RemoteExecutor(bundle.loss_fn, mcfg, optimizer,
                                  exec_cfg=exec_cfg,
                                  calibrate=args.calibrate)
    else:
        mesh = make_host_mesh(model_axis=args.model_axis)
        executor = FusedExecutor(bundle.loss_fn, mcfg, optimizer,
                                 mesh=mesh, model_cfg=cfg,
                                 fused_update=fused_update,
                                 resident=resident)

    events = None
    if args.elastic:
        from repro.engine import ElasticExecutor
        from repro.runtime import parse_schedule
        executor = ElasticExecutor(
            executor, model_cfg=cfg, model_axis=args.model_axis,
            resize_budget=args.resize_budget,
            resize_window_s=args.resize_window_s or None)
        if args.chaos:
            events = parse_schedule(args.chaos)

    guard = None
    if args.guard:
        # outermost wrapper: the guard's verdict must cover everything below
        # (elastic resizes included); PoisonBatch rollback needs the
        # checkpoint-restart loop, so it arms only with --ckpt-dir
        from repro.engine import GuardConfig, GuardedExecutor
        guard = GuardedExecutor(executor,
                                GuardConfig(rollback=bool(args.ckpt_dir)))
        executor = guard

    # init_state shards/jits inside the executor's mesh scope (fused) so the
    # launcher never touches jit/sharding plumbing itself
    params = bundle.init(jax.random.PRNGKey(args.seed))
    state = executor.init_state(params, jax.random.PRNGKey(args.seed + 1))

    meter = ThroughputMeter(tokens_per_batch=args.batch * args.seq)
    callbacks = [LoggingCallback(every=args.log_every,
                                 total_steps=args.steps), meter]
    if args.executor in ("hetero", "remote") or args.telemetry_jsonl:
        callbacks.append(StalenessTelemetry(
            jsonl_path=args.telemetry_jsonl or None))
    if args.ckpt_dir:
        callbacks.append(CheckpointCallback(
            CheckpointManager(args.ckpt_dir, keep=3),
            ResilienceConfig(save_every=args.save_every,
                             max_restarts=args.max_restarts,
                             restart_window_s=args.restart_window_s or None,
                             require_finite_restore=args.guard)))

    tracker = None
    if args.trace:
        from repro.obs import TraceEventSink, Tracker
        tracker = Tracker([TraceEventSink(args.trace)])
    try:
        with Engine(executor, pipe, callbacks) as eng:
            report = eng.fit(state, args.steps, events=events,
                             tracker=tracker)
    finally:
        # launcher-owned netchaos plumbing (the executor tears down only
        # what it spawned itself)
        if netchaos_proxy is not None:
            netchaos_proxy.close()
        if netchaos_server is not None:
            netchaos_server.kill()
    if netchaos_proxy is not None:
        print(f"netchaos: {netchaos_proxy.connections} connections, "
              f"{netchaos_proxy.fault_count()} faults fired "
              f"{netchaos_proxy.schedule.fired_actions()}")
    if tracker is not None:
        tracker.close()
        print(f"trace written to {args.trace} (load at ui.perfetto.dev)")

    if report.pre_fit:
        pf = report.pre_fit
        print(f"calibration: configured b'/b="
              f"{pf['configured_ascent_fraction']:.3f}  system-aware b'/b="
              f"{pf['calibrated_ascent_fraction']:.3f}")
    if args.ckpt_dir:
        print(f"done: {report.steps_done} steps, {report.restarts} restarts, "
              f"{report.wall_time_s:.1f}s")
    if numchaos is not None:
        print(f"numchaos: fired {dict(numchaos.fired)}"
              + (f", {numchaos.skipped_no_float} no-float-leaf skips"
                 if numchaos.skipped_no_float else ""))
    if guard is not None:
        print(f"guard: rung {guard.ladder.level} "
              f"(rho_scale {guard.cfg.rho_scales[guard.ladder.level]}), "
              f"{guard.steps_skipped} updates skipped, "
              f"{guard.poison_rollbacks} poison rollbacks")
    summary = meter.summary()
    if summary:
        print(json.dumps({"arch": cfg.name, "method": args.method,
                          "executor": args.executor,
                          "steps": report.steps_done,
                          "mean_step_s": summary["mean_step_s"],
                          "tokens_per_s": summary.get("tokens_per_s")}))


if __name__ == "__main__":
    main()
