"""Sharding rules: parameter/optimizer/batch/cache PartitionSpecs per arch.

Strategy (DESIGN.md §5): 2-axis FSDP x TP.
  * matmul weights (..., d_in, d_out): d_in -> dp (FSDP), d_out -> "model" (TP);
    output-projection weights (wo / wo_mlp / w_out / wv_c) transpose the rule so
    the contraction stays sharded.
  * embed (V, D): vocab -> "model", d -> dp. unembed follows the generic rule
    (vocab -> "model").
  * expert stacks (L, E, d_in, d_out): experts -> "model" when E divides the
    model axis (EP; deepseek 64e), else TP over d_out (mixtral 8e < 16).
  * biases / per-head vectors: last dim -> "model" when it is a sharded output
    dim; norm scales replicate.
  * any rule whose dim does not divide its mesh axes is dropped (replicated on
    that dim) — e.g. whisper's vocab 51865.

"dp" is ("pod","data") on the multi-pod mesh, ("data",) single-pod, so FSDP
spans pods while TP stays intra-pod (ICI-local).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_size, dp_axes
from repro.models.config import ModelConfig

Pytree = Any

def param_spec(path: str, shape: tuple, mesh, cfg: ModelConfig):
    """PartitionSpec for one parameter leaf (delegates to the shared table in
    repro.models.partitioning so scan-body re-constraints stay consistent)."""
    from repro.models.partitioning import make_rules, param_partition_spec
    return param_partition_spec(path, shape, make_rules(mesh))


def _fits(dim: int, mesh, axes) -> bool:
    return dim % axis_size(mesh, axes) == 0


def _maybe(spec_axes, dim, mesh):
    """Return spec entry if divisible else None (replicate)."""
    if spec_axes is None:
        return None
    return spec_axes if _fits(dim, mesh, spec_axes) else None


def batch_spec_tree(batch_shapes: Pytree, mesh) -> Pytree:
    """Shard every batch leaf's leading (batch) dim over dp when divisible."""
    dp = dp_axes(mesh)

    def f(leaf):
        b = leaf.shape[0] if leaf.ndim else 1
        lead = _maybe(dp, b, mesh)
        return P(lead, *((None,) * (leaf.ndim - 1)))

    return jax.tree.map(f, batch_shapes)


def cache_spec_tree(cache_shapes: Pytree, cfg: ModelConfig, mesh) -> Pytree:
    """Decode/prefill cache sharding (DESIGN.md §5).

    Attention K/V (L, B, S, K, hd) and MLA latents (L, B, S, R): batch -> dp
    when divisible; the *sequence* dim -> "model" (flash-decode LSE-combine
    emerges from pjit's partial reductions); for batch=1 long-context cells the
    seq dim additionally takes the idle dp axes.
    States (ssm/wkv/conv/shift): heads/channels -> "model", batch -> dp.
    """
    dp = dp_axes(mesh)

    def f(path, leaf):
        name = path.split("/")[-1]
        if leaf.ndim == 0:
            return P()
        if name in ("k", "v", "cross_k", "cross_v", "c_kv", "k_rope"):
            # stacked (L,B,S,...) vs per-dense-layer (B,S,...)
            if name in ("k", "v", "cross_k", "cross_v"):
                off = 1 if leaf.ndim == 5 else 0
            else:  # MLA latents: (L,B,S,R) stacked, (B,S,R) unstacked
                off = 1 if leaf.ndim == 4 else 0
            b, s = leaf.shape[off], leaf.shape[off + 1]
            b_ax = _maybe(dp, b, mesh)
            if b_ax is None:
                seq_axes = dp + ("model",)
                s_ax = _maybe(seq_axes, s, mesh) or _maybe("model", s, mesh)
            else:
                s_ax = _maybe("model", s, mesh)
            spec = [None] * leaf.ndim
            spec[off], spec[off + 1] = b_ax, s_ax
            return P(*spec)
        if name in ("ssm", "wkv"):
            # (L, B, H, P, N)
            spec = [None] * leaf.ndim
            spec[1] = _maybe(dp, leaf.shape[1], mesh)
            spec[2] = _maybe("model", leaf.shape[2], mesh)
            return P(*spec)
        if name in ("conv_x", "conv_bc", "tm_shift", "cm_shift"):
            # (L, B, W-1|1, C)
            spec = [None] * leaf.ndim
            spec[1] = _maybe(dp, leaf.shape[1], mesh)
            spec[-1] = _maybe("model", leaf.shape[-1], mesh)
            return P(*spec)
        return P(*([None] * leaf.ndim))

    from repro.utils.trees import tree_map_with_path
    return tree_map_with_path(f, cache_shapes)


def state_spec_tree(state_shapes: Pytree, cfg: ModelConfig, mesh) -> Pytree:
    """TrainState sharding: params/grad-like trees via param rules (matched by
    path suffix, so optimizer mirrors inherit), scalars replicated."""
    from repro.utils.trees import tree_map_with_path

    def f(path, leaf):
        if leaf.ndim == 0:
            return P()
        return param_spec(path, leaf.shape, mesh, cfg)

    return tree_map_with_path(f, state_shapes)


def to_named(spec_tree: Pytree, mesh) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
