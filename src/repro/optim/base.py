"""Minimal optax-style gradient-transform substrate.

optax is not available in this environment, so the framework carries its own
composable transform layer. The interface is deliberately optax-compatible
(init/update pairs, chain) so the SAM family in `repro.core` composes with any
inner optimizer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.utils import trees

Pytree = Any
Schedule = Callable[[jax.Array], jax.Array]


class GradientTransform(NamedTuple):
    init: Callable[[Pytree], Pytree]
    update: Callable[..., tuple[Pytree, Pytree]]  # (grads, state, params) -> (updates, state)
    # Recognition record for the fused flat-buffer fast path (repro.optim.fused):
    # set by the canonical sgd()/adamw() factories, None for hand-built chains.
    # The per-leaf init/update pair above stays authoritative either way — the
    # fused path consumes and produces the exact same state tuple structure.
    fused_spec: Optional["FusedSpec"] = None


def chain(*transforms: GradientTransform) -> GradientTransform:
    """Compose transforms left-to-right (optax.chain semantics)."""

    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return GradientTransform(init, update)


def identity() -> GradientTransform:
    return GradientTransform(lambda p: (), lambda g, s, p=None: (g, s))


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def constant_schedule(value: float) -> Schedule:
    return lambda step: jnp.asarray(value, jnp.float32)


def cosine_schedule(peak: float, total_steps: int, warmup_steps: int = 0,
                    final_fraction: float = 0.0) -> Schedule:
    """Linear warmup then cosine decay to `final_fraction * peak`."""

    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(1.0, warmup_steps)
        decay_steps = jnp.maximum(1.0, total_steps - warmup_steps)
        frac = jnp.clip((step - warmup_steps) / decay_steps, 0.0, 1.0)
        cos = final_fraction + (1.0 - final_fraction) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, peak * cos)

    return sched


def step_decay_schedule(peak: float, boundaries: Sequence[int],
                        factor: float = 0.1) -> Schedule:
    """Piecewise-constant decay (the paper's CIFAR recipes use this shape)."""
    bounds = jnp.asarray(list(boundaries), jnp.float32)

    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        n = jnp.sum(step >= bounds)
        return peak * factor ** n

    return sched


def as_schedule(lr) -> Schedule:
    return lr if callable(lr) else constant_schedule(float(lr))


# ---------------------------------------------------------------------------
# Core transforms
# ---------------------------------------------------------------------------

class ScaleByScheduleState(NamedTuple):
    step: jax.Array


def scale_by_learning_rate(lr) -> GradientTransform:
    sched = as_schedule(lr)

    def init(params):
        return ScaleByScheduleState(step=jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        eta = sched(state.step)
        updates = trees.tree_scale(grads, -eta)
        return updates, ScaleByScheduleState(step=state.step + 1)

    return GradientTransform(init, update)


class TraceState(NamedTuple):
    momentum: Pytree


def trace(decay: float, nesterov: bool = False) -> GradientTransform:
    """Heavy-ball / Nesterov momentum."""

    def init(params):
        return TraceState(momentum=trees.tree_zeros_like(params, jnp.float32))

    def update(grads, state, params=None):
        m = jax.tree.map(lambda mi, gi: decay * mi + gi.astype(jnp.float32),
                         state.momentum, grads)
        if nesterov:
            out = jax.tree.map(lambda mi, gi: decay * mi + gi.astype(jnp.float32), m, grads)
        else:
            out = m
        out = jax.tree.map(lambda o, g: o.astype(g.dtype), out, grads)
        return out, TraceState(momentum=m)

    return GradientTransform(init, update)


class AdamState(NamedTuple):
    step: jax.Array
    mu: Pytree
    nu: Pytree


def scale_by_adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> GradientTransform:
    def init(params):
        return AdamState(step=jnp.zeros((), jnp.int32),
                         mu=trees.tree_zeros_like(params, jnp.float32),
                         nu=trees.tree_zeros_like(params, jnp.float32))

    def update(grads, state, params=None):
        step = state.step + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        updates = jax.tree.map(
            lambda m, v, g: ((m / c1) / (jnp.sqrt(v / c2) + eps)).astype(g.dtype),
            mu, nu, grads)
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return GradientTransform(init, update)


def add_decayed_weights(weight_decay: float,
                        mask_fn: Optional[Callable[[str], bool]] = None) -> GradientTransform:
    """Decoupled weight decay; `mask_fn(path)` selects decayed leaves (skip norms/bias)."""

    def init(params):
        return ()

    def update(grads, state, params=None):
        if weight_decay == 0.0 or params is None:
            return grads, state
        if mask_fn is None:
            out = jax.tree.map(lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
        else:
            paths = trees.tree_paths(grads)
            flat, treedef = jax.tree.flatten(grads)
            flat_p = jax.tree.leaves(params)
            new = [g + weight_decay * p.astype(g.dtype) if mask_fn(path) else g
                   for path, g, p in zip(paths, flat, flat_p)]
            out = jax.tree.unflatten(treedef, new)
        return out, state

    return GradientTransform(init, update)


class ClipState(NamedTuple):
    last_norm: jax.Array


def clip_by_global_norm(max_norm: float) -> GradientTransform:
    def init(params):
        return ClipState(last_norm=jnp.zeros((), jnp.float32))

    def update(grads, state, params=None):
        gnorm = trees.global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
        out = trees.tree_scale(grads, scale)
        out = jax.tree.map(lambda o, g: o.astype(g.dtype), out, grads)
        return out, ClipState(last_norm=gnorm)

    return GradientTransform(init, update)


# ---------------------------------------------------------------------------
# User-facing optimizers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FusedSpec:
    """Metadata describing a canonical sgd/adamw chain for the fused path.

    `repro.optim.fused.fused_apply` executes exactly this chain (same transform
    order, same state tuple layout as the per-leaf factories below) on
    dtype-bucketed flat buffers via single-pass kernels. `enabled=None` defers
    to the platform default (`utils.buckets.fused_path_enabled`): on for TPU,
    off for CPU, the `kernels.ops._resolve` convention.
    """
    family: str                       # "sgd" | "adamw"
    lr: Schedule
    clip_norm: Optional[float] = None
    weight_decay: float = 0.0
    momentum: float = 0.0
    nesterov: bool = False
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    enabled: Optional[bool] = None


def sgd(lr, momentum: float = 0.0, nesterov: bool = False,
        weight_decay: float = 0.0, clip_norm: Optional[float] = None) -> GradientTransform:
    parts = []
    if clip_norm is not None:
        parts.append(clip_by_global_norm(clip_norm))
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    if momentum:
        parts.append(trace(momentum, nesterov=nesterov))
    parts.append(scale_by_learning_rate(lr))
    spec = FusedSpec(family="sgd", lr=as_schedule(lr), clip_norm=clip_norm,
                     weight_decay=weight_decay, momentum=momentum,
                     nesterov=nesterov)
    return chain(*parts)._replace(fused_spec=spec)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01, clip_norm: Optional[float] = None,
          decay_mask: Optional[Callable[[str], bool]] = None) -> GradientTransform:
    parts = []
    if clip_norm is not None:
        parts.append(clip_by_global_norm(clip_norm))
    parts.append(scale_by_adam(b1, b2, eps))
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay, decay_mask))
    parts.append(scale_by_learning_rate(lr))
    # a decay mask needs per-leaf path selection, which the flat-buffer
    # kernels don't model — such chains simply keep the per-leaf path
    spec = None if decay_mask is not None else FusedSpec(
        family="adamw", lr=as_schedule(lr), clip_norm=clip_norm,
        weight_decay=weight_decay, b1=b1, b2=b2, eps=eps)
    return chain(*parts)._replace(fused_spec=spec)


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32)
                                      + u.astype(jnp.float32)).astype(p.dtype),
                        params, updates)


def make_optimizer(name: str, lr, **kw) -> GradientTransform:
    name = name.lower()
    if name == "sgd":
        return sgd(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
