"""FusedUpdate — the canonical sgd/adamw chains on dtype-bucketed flat buffers.

`fused_apply` recognizes a chain built by `optim.base.sgd` / `optim.base.adamw`
(via the `FusedSpec` the factories attach) and executes the whole optimizer
tail — clip, weight decay, momentum/Adam, lr scale, apply — as ONE single-pass
kernel per dtype bucket (`kernels.fused_update`), instead of the ~6-10
per-leaf `jax.tree.map` passes of `GradientTransform.update` +
`apply_updates`, each of which re-streams every parameter element through HBM.

The fast path is a drop-in: it consumes and produces the exact same
`opt_state` tuple layout as the per-leaf chain (checkpoints interoperate, a
run can flip between paths), and it is numerically the same computation with
fp32 accumulation throughout — bit-identical for fp32 parameters up to the
reduction order of the global grad norm, and within fp32-accumulation
tolerance for bf16 parameters (the per-leaf path round-trips intermediates
through bf16 between transforms; the kernel does not).

With bucket-RESIDENT state (`utils.buckets.BucketedState` params/moments) the
kernels additionally skip the per-call bucket gather/scatter entirely: the
buffers come in as the state representation and go out as the next step's —
under jit donation that is buffer-aliased in-place update, the regime
`epilogue_hbm_bytes(resident=True)` models and `benchmarks/perf_cell.py`
verifies by trace-counting conversions.

Hand-built chains, masked weight decay, and every non-sgd/adamw optimizer
return None here and keep the per-leaf path — `core.api._finish` falls back
transparently.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.optim.base import (AdamState, ClipState, FusedSpec,
                              GradientTransform, ScaleByScheduleState,
                              TraceState)
from repro.utils import buckets

Pytree = Any


def configure(optimizer: GradientTransform,
              enabled: Optional[bool]) -> GradientTransform:
    """Pin the fused-path switch on a recognized chain (no-op otherwise)."""
    spec = getattr(optimizer, "fused_spec", None)
    if spec is None:
        return optimizer
    return optimizer._replace(
        fused_spec=dataclasses.replace(spec, enabled=enabled))


def _chain_fields(spec: FusedSpec) -> list[str]:
    """The transform sequence base.sgd/base.adamw built (state tuple layout)."""
    parts = []
    if spec.clip_norm is not None:
        parts.append("clip")
    if spec.family == "adamw":
        parts.append("adam")
        if spec.weight_decay:
            parts.append("wd")
    else:
        if spec.weight_decay:
            parts.append("wd")
        if spec.momentum:
            parts.append("trace")
    parts.append("lr")
    return parts


def fused_apply(optimizer: GradientTransform, grads: Pytree, opt_state: Pytree,
                params: Pytree, *, impl: Optional[str] = None
                ) -> Optional[tuple[Pytree, Pytree, jax.Array]]:
    """Run the whole update+apply on buckets, or None to keep the per-leaf path.

    Returns (new_params, new_opt_state, grad_norm); grad_norm is the global
    fp32 gradient norm (computed for clipping anyway, reused by the step's
    metric contract so the fused path adds no extra pass).

    Bucket-resident operands (`buckets.BucketedState` params / moments /
    grads) are consumed and produced AS buffers: no per-call
    `tree_to_buckets`/`buckets_to_tree`, so under jit donation the kernels
    alias input buffer to output buffer and the epilogue's realized HBM
    traffic equals the `epilogue_hbm_bytes(resident=True)` model. Plain
    pytrees keep the gather/scatter-per-call behavior (`resident=False`).
    Bucket-resident params always run fused — the buffers ARE the
    representation — regardless of the platform switch.
    """
    spec = getattr(optimizer, "fused_spec", None)
    resident = buckets.is_bucketed(params)
    if spec is None or not (resident or buckets.fused_path_enabled(spec.enabled)):
        return None

    from repro.kernels import ops

    fields = _chain_fields(spec)
    layout = (params.layout if resident else buckets.bucket_layout(params))

    def _bufs(tree):
        return buckets.group_buffers(tree, layout)[0]

    def _rebuild(bufs, like):
        if buckets.is_bucketed(like):
            return buckets.BucketedState(buffers=tuple(bufs),
                                         layout=like.layout)
        return buckets.buckets_to_tree(bufs, layout, like)

    wb = _bufs(params)
    gb = _bufs(grads)

    sq = jnp.sum(jnp.stack([ops.sq_norm(g, impl=impl) for g in gb]))
    gnorm = jnp.sqrt(sq)
    if spec.clip_norm is not None:
        clip_scale = jnp.minimum(1.0, spec.clip_norm / (gnorm + 1e-12))
    else:
        clip_scale = jnp.float32(1.0)

    sched_state: ScaleByScheduleState = opt_state[-1]
    eta = spec.lr(sched_state.step)

    if spec.family == "sgd":
        has_m = bool(spec.momentum)
        old_m = opt_state[fields.index("trace")].momentum if has_m else None
        mb = _bufs(old_m) if has_m else [None] * len(wb)
        w_new, m_new = [], []
        for w, g, m in zip(wb, gb, mb):
            wn, mn = ops.sgd_epilogue(w, g, m, clip_scale, eta,
                                      momentum=spec.momentum,
                                      nesterov=spec.nesterov,
                                      weight_decay=spec.weight_decay,
                                      impl=impl)
            w_new.append(wn)
            m_new.append(mn)
        params_new = _rebuild(w_new, params)
        new_state = []
        for f in fields:
            if f == "clip":
                new_state.append(ClipState(last_norm=gnorm))
            elif f == "wd":
                new_state.append(())
            elif f == "trace":
                new_state.append(TraceState(momentum=_rebuild(m_new, old_m)))
            else:
                new_state.append(ScaleByScheduleState(step=sched_state.step + 1))
        return params_new, tuple(new_state), gnorm

    # adamw
    adam_state: AdamState = opt_state[fields.index("adam")]
    step = adam_state.step + 1
    c1 = 1.0 - spec.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - spec.b2 ** step.astype(jnp.float32)
    mub = _bufs(adam_state.mu)
    nub = _bufs(adam_state.nu)
    w_new, mu_new, nu_new = [], [], []
    for w, g, mu, nu in zip(wb, gb, mub, nub):
        wn, mn, vn = ops.adamw_epilogue(w, g, mu, nu, clip_scale, eta, c1, c2,
                                        b1=spec.b1, b2=spec.b2, eps=spec.eps,
                                        weight_decay=spec.weight_decay,
                                        impl=impl)
        w_new.append(wn)
        mu_new.append(mn)
        nu_new.append(vn)
    params_new = _rebuild(w_new, params)
    new_state = []
    for f in fields:
        if f == "clip":
            new_state.append(ClipState(last_norm=gnorm))
        elif f == "adam":
            new_state.append(AdamState(
                step=step,
                mu=_rebuild(mu_new, adam_state.mu),
                nu=_rebuild(nu_new, adam_state.nu)))
        elif f == "wd":
            new_state.append(())
        else:
            new_state.append(ScaleByScheduleState(step=sched_state.step + 1))
    return params_new, tuple(new_state), gnorm


# ---------------------------------------------------------------------------
# Modeled epilogue HBM traffic (benchmarks/perf_cell.py artifact)
# ---------------------------------------------------------------------------

def epilogue_hbm_bytes(param_count: int, param_bytes: int, *,
                       family: str = "adamw", clip: bool = True,
                       weight_decay: bool = True, momentum: bool = True,
                       carried_norm: bool = True, fused: bool,
                       resident: bool = True) -> int:
    """Modeled HBM bytes of one step's weight-space epilogue (perturb + tail).

    Enumerates the HBM passes of the actual code path: every
    `jax.tree.map` in the per-leaf chain streams its operands and result
    (fp32 intermediates included), while the fused path reads and writes each
    tensor once per kernel. `param_bytes` is the total byte size of the
    parameter tree (grads assumed the same dtype); optimizer state is fp32.
    `carried_norm=True` models AsyncSAM, where the perturbation norm is
    carried state rather than a fresh reduction over the ascent gradient.

    The fused side models BOTH residency regimes. `resident=True` counts
    kernel-streamed bytes only — training state lives as persistent dtype
    buckets (`buckets.BucketedState`) that the kernels consume and donate
    directly, so no conversion copies exist; this is the number
    `benchmarks/perf_cell.py`'s trace-counted realized traffic must match.
    `resident=False` models the gather/scatter-per-call regime: each kernel
    call re-gathers its operand buckets from the pytree (concatenate) and
    scatters results back (slice), each conversion costing read + write of
    its payload — which is why the fused kernels alone never realized their
    reduction before bucketed state persisted across steps. (The ascent-grad
    gather is approximated at param dtype, matching the perturb terms.)
    """
    P = param_bytes               # one full pass over params/grads
    F = 4 * param_count           # one full pass over an fp32 state tree
    total = 0
    if fused:
        if not carried_norm:
            total += P                      # sq_norm kernel: read g
        total += 3 * P                      # perturb axpy: read w,g / write w_hat
        if clip:
            total += P                      # clip sq_norm kernel: read g
        if family == "adamw":
            total += 2 * P + 2 * F          # epilogue read: w, g, mu, nu
            total += P + 2 * F              # epilogue write: w', mu', nu'
        else:
            total += 2 * P                  # epilogue read: w, g
            total += P                      # epilogue write: w'
            if momentum:
                total += 2 * F              # read m / write m'
        if not resident:
            # per-call bucket conversions: gather = read tree + write buffer,
            # scatter = read buffer + write tree (2x payload each)
            total += 2 * 3 * P              # perturb: gather g,w / scatter w_hat
            if not carried_norm:
                total += 2 * P              # fresh-norm sq_norm: gather g
            else:
                total += 2 * 2 * F          # ascent refresh dot_norms: gather
                                            # a_t, a_{t-1} (fp32 carried state)
            total += 2 * 3 * P              # apply: gather w,g / scatter w'
            if family == "adamw":
                total += 2 * 4 * F          # gather mu,nu / scatter mu',nu'
            elif momentum:
                total += 2 * 2 * F          # gather m / scatter m'
        return total
    # per-leaf path, pass by pass
    if not carried_norm:
        total += P                          # global_norm: read g
    total += 3 * P                          # perturb map: read w,g / write w_hat
    if clip:
        total += P                          # global_norm: read g
        total += P + F                      # scale map: read g / write f32
        total += F + P                      # cast-back map: read f32 / write g
    if family == "adamw":
        total += F + P + F                  # mu map: read mu,g / write mu'
        total += F + P + F                  # nu map: read nu,g / write nu'
        total += 2 * F + P                  # update map: read mu',nu' / write u
        if weight_decay:
            total += 3 * P                  # wd map: read u,w / write u'
    else:
        if weight_decay:
            total += 3 * P                  # wd map: read g,w / write g'
        if momentum:
            total += P + F + F + P          # trace map: read g,m / write m',out
    total += P + F                          # lr map: read u / write f32
    total += P + F + P                      # apply map: read w,u / write w'
    return total
