"""FusedUpdate — the canonical sgd/adamw chains on dtype-bucketed flat buffers.

`fused_apply` recognizes a chain built by `optim.base.sgd` / `optim.base.adamw`
(via the `FusedSpec` the factories attach) and executes the whole optimizer
tail — clip, weight decay, momentum/Adam, lr scale, apply — as ONE single-pass
kernel per dtype bucket (`kernels.fused_update`), instead of the ~6-10
per-leaf `jax.tree.map` passes of `GradientTransform.update` +
`apply_updates`, each of which re-streams every parameter element through HBM.

The fast path is a drop-in: it consumes and produces the exact same
`opt_state` tuple layout as the per-leaf chain (checkpoints interoperate, a
run can flip between paths), and it is numerically the same computation with
fp32 accumulation throughout — bit-identical for fp32 parameters up to the
reduction order of the global grad norm, and within fp32-accumulation
tolerance for bf16 parameters (the per-leaf path round-trips intermediates
through bf16 between transforms; the kernel does not).

Hand-built chains, masked weight decay, and every non-sgd/adamw optimizer
return None here and keep the per-leaf path — `core.api._finish` falls back
transparently.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.optim.base import (AdamState, ClipState, FusedSpec,
                              GradientTransform, ScaleByScheduleState,
                              TraceState)
from repro.utils import buckets

Pytree = Any


def configure(optimizer: GradientTransform,
              enabled: Optional[bool]) -> GradientTransform:
    """Pin the fused-path switch on a recognized chain (no-op otherwise)."""
    spec = getattr(optimizer, "fused_spec", None)
    if spec is None:
        return optimizer
    return optimizer._replace(
        fused_spec=dataclasses.replace(spec, enabled=enabled))


def _chain_fields(spec: FusedSpec) -> list[str]:
    """The transform sequence base.sgd/base.adamw built (state tuple layout)."""
    parts = []
    if spec.clip_norm is not None:
        parts.append("clip")
    if spec.family == "adamw":
        parts.append("adam")
        if spec.weight_decay:
            parts.append("wd")
    else:
        if spec.weight_decay:
            parts.append("wd")
        if spec.momentum:
            parts.append("trace")
    parts.append("lr")
    return parts


def fused_apply(optimizer: GradientTransform, grads: Pytree, opt_state: Pytree,
                params: Pytree, *, impl: Optional[str] = None
                ) -> Optional[tuple[Pytree, Pytree, jax.Array]]:
    """Run the whole update+apply on buckets, or None to keep the per-leaf path.

    Returns (new_params, new_opt_state, grad_norm); grad_norm is the global
    fp32 gradient norm (computed for clipping anyway, reused by the step's
    metric contract so the fused path adds no extra pass).
    """
    spec = getattr(optimizer, "fused_spec", None)
    if spec is None or not buckets.fused_path_enabled(spec.enabled):
        return None

    from repro.kernels import ops

    fields = _chain_fields(spec)
    layout = buckets.bucket_layout(params)
    wb = buckets.tree_to_buckets(params, layout)
    gb = buckets.tree_to_buckets(grads, layout)

    sq = jnp.sum(jnp.stack([ops.sq_norm(g, impl=impl) for g in gb]))
    gnorm = jnp.sqrt(sq)
    if spec.clip_norm is not None:
        clip_scale = jnp.minimum(1.0, spec.clip_norm / (gnorm + 1e-12))
    else:
        clip_scale = jnp.float32(1.0)

    sched_state: ScaleByScheduleState = opt_state[-1]
    eta = spec.lr(sched_state.step)

    if spec.family == "sgd":
        has_m = bool(spec.momentum)
        old_m = opt_state[fields.index("trace")].momentum if has_m else None
        mb = (buckets.tree_to_buckets(old_m, layout) if has_m
              else [None] * len(wb))
        w_new, m_new = [], []
        for w, g, m in zip(wb, gb, mb):
            wn, mn = ops.sgd_epilogue(w, g, m, clip_scale, eta,
                                      momentum=spec.momentum,
                                      nesterov=spec.nesterov,
                                      weight_decay=spec.weight_decay,
                                      impl=impl)
            w_new.append(wn)
            m_new.append(mn)
        params_new = buckets.buckets_to_tree(w_new, layout, params)
        new_state = []
        for f in fields:
            if f == "clip":
                new_state.append(ClipState(last_norm=gnorm))
            elif f == "wd":
                new_state.append(())
            elif f == "trace":
                new_state.append(TraceState(
                    momentum=buckets.buckets_to_tree(m_new, layout, old_m)))
            else:
                new_state.append(ScaleByScheduleState(step=sched_state.step + 1))
        return params_new, tuple(new_state), gnorm

    # adamw
    adam_state: AdamState = opt_state[fields.index("adam")]
    step = adam_state.step + 1
    c1 = 1.0 - spec.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - spec.b2 ** step.astype(jnp.float32)
    mub = buckets.tree_to_buckets(adam_state.mu, layout)
    nub = buckets.tree_to_buckets(adam_state.nu, layout)
    w_new, mu_new, nu_new = [], [], []
    for w, g, mu, nu in zip(wb, gb, mub, nub):
        wn, mn, vn = ops.adamw_epilogue(w, g, mu, nu, clip_scale, eta, c1, c2,
                                        b1=spec.b1, b2=spec.b2, eps=spec.eps,
                                        weight_decay=spec.weight_decay,
                                        impl=impl)
        w_new.append(wn)
        mu_new.append(mn)
        nu_new.append(vn)
    params_new = buckets.buckets_to_tree(w_new, layout, params)
    new_state = []
    for f in fields:
        if f == "clip":
            new_state.append(ClipState(last_norm=gnorm))
        elif f == "adam":
            new_state.append(AdamState(
                step=step,
                mu=buckets.buckets_to_tree(mu_new, layout, adam_state.mu),
                nu=buckets.buckets_to_tree(nu_new, layout, adam_state.nu)))
        elif f == "wd":
            new_state.append(())
        else:
            new_state.append(ScaleByScheduleState(step=sched_state.step + 1))
    return params_new, tuple(new_state), gnorm


# ---------------------------------------------------------------------------
# Modeled epilogue HBM traffic (benchmarks/perf_cell.py artifact)
# ---------------------------------------------------------------------------

def epilogue_hbm_bytes(param_count: int, param_bytes: int, *,
                       family: str = "adamw", clip: bool = True,
                       weight_decay: bool = True, momentum: bool = True,
                       carried_norm: bool = True, fused: bool) -> int:
    """Modeled HBM bytes of one step's weight-space epilogue (perturb + tail).

    Enumerates the HBM passes of the actual code path: every
    `jax.tree.map` in the per-leaf chain streams its operands and result
    (fp32 intermediates included), while the fused path reads and writes each
    tensor once per kernel. `param_bytes` is the total byte size of the
    parameter tree (grads assumed the same dtype); optimizer state is fp32.
    `carried_norm=True` models AsyncSAM, where the perturbation norm is
    carried state rather than a fresh reduction over the ascent gradient.

    Scope: the fused side counts KERNEL-STREAMED bytes only — it assumes each
    dtype bucket is already a contiguous buffer. Today's implementation
    re-gathers buckets from the pytree around every kernel call
    (`buckets.tree_to_buckets` concatenate + slice-back), and a Pallas
    custom-call materializes its operands, so per-step gather/scatter copies
    are extra traffic this model excludes; they disappear once bucketed
    state persists across steps (ROADMAP item). The reduction reported by
    perf_cell is therefore the steady-state ceiling of the fused path, not a
    measurement.
    """
    P = param_bytes               # one full pass over params/grads
    F = 4 * param_count           # one full pass over an fp32 state tree
    total = 0
    if fused:
        if not carried_norm:
            total += P                      # sq_norm kernel: read g
        total += 3 * P                      # perturb axpy: read w,g / write w_hat
        if clip:
            total += P                      # clip sq_norm kernel: read g
        if family == "adamw":
            total += 2 * P + 2 * F          # epilogue read: w, g, mu, nu
            total += P + 2 * F              # epilogue write: w', mu', nu'
        else:
            total += 2 * P                  # epilogue read: w, g
            total += P                      # epilogue write: w'
            if momentum:
                total += 2 * F              # read m / write m'
        return total
    # per-leaf path, pass by pass
    if not carried_norm:
        total += P                          # global_norm: read g
    total += 3 * P                          # perturb map: read w,g / write w_hat
    if clip:
        total += P                          # global_norm: read g
        total += P + F                      # scale map: read g / write f32
        total += F + P                      # cast-back map: read f32 / write g
    if family == "adamw":
        total += F + P + F                  # mu map: read mu,g / write mu'
        total += F + P + F                  # nu map: read nu,g / write nu'
        total += 2 * F + P                  # update map: read mu',nu' / write u
        if weight_decay:
            total += 3 * P                  # wd map: read u,w / write u'
    else:
        if weight_decay:
            total += 3 * P                  # wd map: read g,w / write g'
        if momentum:
            total += P + F + F + P          # trace map: read g,m / write m',out
    total += P + F                          # lr map: read u / write f32
    total += P + F + P                      # apply map: read w,u / write w'
    return total
