from repro.optim.base import (  # noqa: F401
    FusedSpec,
    GradientTransform,
    adamw,
    add_decayed_weights,
    apply_updates,
    as_schedule,
    chain,
    clip_by_global_norm,
    constant_schedule,
    cosine_schedule,
    identity,
    make_optimizer,
    scale_by_adam,
    scale_by_learning_rate,
    sgd,
    step_decay_schedule,
    trace,
)
from repro.optim.fused import (  # noqa: F401
    configure as configure_fused,
    epilogue_hbm_bytes,
    fused_apply,
)
