"""Typed metric-key registry — the single source of truth for step metrics.

Every scalar an executor, lane, pool, or callback emits per step is declared
here as a `MetricKey`; the bare `ENGINE_METRIC_KEYS` /
`ENGINE_OPTIONAL_METRIC_KEYS` tuples the engine contract used to hard-code
are now *derived* from this registry (`engine.api` re-exports them, so every
existing import keeps working). The registry is what makes the telemetry
surface auditable: the strict in-memory tracker sink rejects writes of
unregistered keys, and `scripts/lint_metric_registry.py` statically scans
the source tree for metric writes outside this table.

Key groups (the `source` field):

    core     emitted inside the jitted step (method metrics dicts / _finish)
    model    scalar aux terms a model's loss_fn returns (pass through _m)
    engine   derived by the Engine fit loop (step timing)
    lane     the hetero/async executor's staleness contract
    remote   the remote ascent lane's wire accounting, per harvested exchange
    pool     multi-client ascent-pool scheduler pressure
    elastic  mesh capacity + resize costs
    guard    the numerics guard's ladder/rollback telemetry (runtime.guard)

Ordering is load-bearing: the `required` keys render in the historical
`ENGINE_METRIC_KEYS` order and the `optional` keys in the historical
`ENGINE_OPTIONAL_METRIC_KEYS` order, which is also the field order of
`StalenessTelemetry`'s jsonl records — the jsonl sink's byte-compatibility
with pre-registry records depends on it.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class MetricKey:
    """One registered per-step scalar."""

    name: str
    description: str
    unit: str = ""            #: "", "s", "bytes", "devices", "count"
    required: bool = False    #: part of the ENGINE_METRIC_KEYS contract
    optional: bool = False    #: part of the ENGINE_OPTIONAL_METRIC_KEYS surface
    source: str = "core"      #: which layer emits it (see module doc)
    trace_counter: bool = False  #: render as a Perfetto counter track


#: The full registry, in contract order (see module doc on why order matters).
METRIC_KEYS: tuple = (
    # --- required contract (ENGINE_METRIC_KEYS order) -----------------------
    MetricKey("loss", "descent-lane loss at the (possibly perturbed) point",
              required=True, source="core", trace_counter=True),
    MetricKey("grad_norm", "global norm of the applied gradient",
              required=True, source="core"),
    MetricKey("tau", "age (steps) of the ascent gradient used for the "
              "perturbation (0 = none/synchronous, 1 = paper steady state)",
              unit="steps", required=True, source="lane", trace_counter=True),
    MetricKey("perturbed", "1.0 if the step used a SAM perturbation, 0.0 if "
              "it degraded to (or is) plain SGD",
              required=True, source="lane"),
    # --- optional wire/pool/elastic keys (ENGINE_OPTIONAL_METRIC_KEYS order)
    MetricKey("wire_bytes", "measured bytes of the harvested JOB+GRAD "
              "exchange (job + grad sum)", unit="bytes", optional=True,
              source="remote", trace_counter=True),
    MetricKey("job_bytes", "JOB frame bytes (params direction out: snapshot "
              "or delta-encoded bucket sections)", unit="bytes",
              optional=True, source="remote"),
    MetricKey("grad_bytes", "GRAD frame bytes (compressed ascent gradient "
              "back)", unit="bytes", optional=True, source="remote"),
    MetricKey("rtt_s", "round-trip seconds of the harvested exchange",
              unit="s", optional=True, source="remote"),
    MetricKey("pool_depth", "ascent-pool queue depth the exchange was "
              "admitted behind", optional=True, source="pool",
              trace_counter=True),
    MetricKey("pool_wait_s", "seconds the job waited before a pool worker "
              "took it", unit="s", optional=True, source="pool"),
    MetricKey("client_id", "numeric client identity (crc32 of the declared "
              "id) for joining fleet traces", optional=True, source="pool"),
    MetricKey("mesh_devices", "current mesh capacity in devices",
              unit="devices", optional=True, source="elastic",
              trace_counter=True),
    MetricKey("resize_events", "cumulative resize count, on the step right "
              "after a shrink/grow", unit="count", optional=True,
              source="elastic"),
    MetricKey("resize_time_s", "seconds the resize's re-place + re-lower "
              "cost", unit="s", optional=True, source="elastic"),
    MetricKey("lane_state", "ascent-lane degradation-ladder rung (0 = "
              "primary/remote, 1 = in-process thread lane, 2 = ledger-only "
              "descent); present when the ladder is enabled",
              optional=True, source="lane", trace_counter=True),
    MetricKey("lane_failovers", "cumulative ladder demotions, emitted on "
              "the step right after a failover", unit="count", optional=True,
              source="lane"),
    MetricKey("lane_recoveries", "cumulative ladder promotions, emitted on "
              "the step right after a recovery", unit="count", optional=True,
              source="lane"),
    MetricKey("guard_state", "numerics-guard de-escalation rung (0 = full "
              "rho ... last = plain descent); present when --guard is on",
              optional=True, source="guard", trace_counter=True),
    MetricKey("rho_scale", "effective-rho multiplier the guard rung applies "
              "(1.0 = undegraded, 0.0 = plain descent)", optional=True,
              source="guard"),
    MetricKey("steps_skipped", "cumulative updates the in-step guard "
              "discarded (non-finite loss/grad), emitted on skip steps",
              unit="count", optional=True, source="guard"),
    MetricKey("nonfinite_count", "non-finite elements in this step's "
              "gradient (0 on clean steps); emitted when guard_update is on",
              unit="count", optional=True, source="core"),
    MetricKey("poison_rollbacks", "cumulative PoisonBatch rollbacks (model "
              "restored, data cursor advanced), emitted on the step right "
              "after one", unit="count", optional=True, source="guard"),
    # --- method-level scalars (inside the jitted step) ----------------------
    MetricKey("loss_at_w", "loss at the unperturbed point w (SAM two-point "
              "methods)", source="core"),
    MetricKey("ascent_loss", "loss the ascent pass observed; a NaN SENTINEL "
              "on fused-form reuse steps — real iff ascent_reused is 0",
              source="core"),
    MetricKey("ascent_reused", "1.0 when the fused async form reused the "
              "held ascent gradient instead of refreshing (AsyncSAM-k) — "
              "the flag that disambiguates the ascent_loss NaN sentinel",
              source="core"),
    MetricKey("ascent_norm", "global norm of the held ascent gradient",
              source="core"),
    MetricKey("update_skipped", "1.0 when the in-step numerics guard "
              "discarded this update (params/opt state kept)", source="core"),
    MetricKey("ascent_cosine", "cosine(a_t, a_{t-1}) of consecutive ascent "
              "gradients — the paper's Fig-2 staleness argument",
              source="core"),
    MetricKey("fresh", "1.0 when LookSAM recomputed g_v this step",
              source="core"),
    MetricKey("sam_step", "1.0 when AE-SAM took the SAM branch",
              source="core"),
    MetricKey("gnorm_sq", "squared gradient norm AE-SAM thresholds on",
              source="core"),
    MetricKey("mesa_kl", "Mesa-SAM distillation KL term", source="core"),
    # --- model-loss aux scalars (models/registry.py loss_fn aux) ------------
    MetricKey("ce", "cross-entropy term of the model loss (before aux "
              "penalties)", source="model"),
    MetricKey("moe_aux", "MoE load-balancing auxiliary loss term",
              source="model"),
    # --- engine-derived -----------------------------------------------------
    MetricKey("step_time_s", "wall seconds of the whole executor step, "
              "measured by the Engine fit loop", unit="s", source="engine"),
)

REGISTRY: dict = {k.name: k for k in METRIC_KEYS}

#: Keys every executor guarantees in its step metrics (derived; the engine
#: contract — see the per-key descriptions in METRIC_KEYS).
ENGINE_METRIC_KEYS: tuple = tuple(k.name for k in METRIC_KEYS if k.required)

#: Optional keys an executor MAY emit, only on steps where they are real
#: measurements (callbacks must tolerate their absence) — derived.
ENGINE_OPTIONAL_METRIC_KEYS: tuple = tuple(k.name for k in METRIC_KEYS
                                           if k.optional)

#: Keys a Chrome-trace sink additionally renders as counter tracks.
TRACE_COUNTER_KEYS: tuple = tuple(k.name for k in METRIC_KEYS
                                  if k.trace_counter)


class UnknownMetricError(KeyError):
    """A metric write used a key outside the registry."""


def metric_key(name: str) -> MetricKey:
    """Registry lookup; raises UnknownMetricError for unregistered names."""
    try:
        return REGISTRY[name]
    except KeyError:
        raise UnknownMetricError(
            f"metric key {name!r} is not in the obs registry; declare it in "
            "repro.obs.registry.METRIC_KEYS") from None


def validate_keys(keys: Iterable[str]) -> None:
    """Raise UnknownMetricError naming every unregistered key in `keys`."""
    unknown = sorted(k for k in keys if k not in REGISTRY)
    if unknown:
        raise UnknownMetricError(
            f"unregistered metric key(s) {unknown}; declare them in "
            "repro.obs.registry.METRIC_KEYS")


def scalar_metrics(metrics: dict) -> dict:
    """The float()-able subset of a step's metrics, as host floats.

    The one filter every history/logging consumer applies (Engine, the
    resilient loop, LoggingCallback, the tracker route), kept in one place so
    metrics_history has the same shape on every execution path.
    """
    return {k: float(v) for k, v in metrics.items()
            if hasattr(v, "__float__") and getattr(v, "ndim", 0) == 0}


def registry_table() -> str:
    """The metric-key reference as a markdown table (README generator)."""
    rows = ["| key | source | unit | contract | description |",
            "|---|---|---|---|---|"]
    for k in METRIC_KEYS:
        contract = ("required" if k.required
                    else "optional" if k.optional else "")
        rows.append(f"| `{k.name}` | {k.source} | {k.unit or '—'} "
                    f"| {contract or '—'} | {k.description} |")
    return "\n".join(rows)
