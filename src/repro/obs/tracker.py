"""Tracker — one observability funnel for scalars, counters, and spans.

Levanter's tracker idiom (a process-global "current tracker" every layer
logs through) adapted to the two-lane AsyncSAM runtime: the Engine installs
a tracker for the duration of `fit`, and every lane — the descent loop, the
in-process ascent worker thread, the remote client's socket worker, the
ascent pool's workers, the elastic resize path — reports to
`current_tracker()` without any of them holding a reference. The global is a
plain module global (NOT a contextvar): lane workers are long-lived threads
spawned before `fit` runs, and they must observe the tracker the fit
installed.

A `Tracker` fans out to composable sinks:

    MemorySink      in-memory records; strict mode rejects unregistered keys
    JsonlSink       per-step records, byte-compatible with the historical
                    `StalenessTelemetry(jsonl_path=...)` schema
    TraceEventSink  Chrome/Perfetto trace-event JSON with one named track
                    per lane (repro.obs.trace)

Span timing uses `time.perf_counter()` everywhere (`trace_now`), so spans
recorded on different threads of one process share a clock and render with
true overlap in a trace viewer — the whole point: perturbation-hiding is
visible as ascent-lane spans literally under the descent lane's.
"""
from __future__ import annotations

import contextlib
import json
import pathlib
import threading
import time
from typing import Any, Iterator, Optional, Sequence, Union

from repro.obs.registry import (ENGINE_OPTIONAL_METRIC_KEYS, validate_keys)


def trace_now() -> float:
    """The tracker clock: monotonic seconds, shared across threads."""
    return time.perf_counter()


class Span:
    """One completed timed span on a named lane (t0/t1 in trace_now time)."""

    __slots__ = ("name", "lane", "t0", "t1", "args")

    def __init__(self, name: str, lane: str, t0: float, t1: float,
                 args: Optional[dict] = None):
        self.name = name
        self.lane = lane
        self.t0 = float(t0)
        self.t1 = float(t1)
        self.args = dict(args or {})

    @property
    def duration_s(self) -> float:
        return max(0.0, self.t1 - self.t0)

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, lane={self.lane!r}, "
                f"dur={self.duration_s * 1e3:.3f}ms, args={self.args})")


class Event:
    """One instantaneous marker on a named lane."""

    __slots__ = ("name", "lane", "ts", "args")

    def __init__(self, name: str, lane: str, ts: float,
                 args: Optional[dict] = None):
        self.name = name
        self.lane = lane
        self.ts = float(ts)
        self.args = dict(args or {})


class Sink:
    """Sink interface: every hook is a no-op; implement what you need."""

    def log(self, metrics: dict, *, step: int) -> None:
        pass

    def span(self, span: Span) -> None:
        pass

    def event(self, event: Event) -> None:
        pass

    def close(self) -> None:
        pass


class MemorySink(Sink):
    """In-memory sink for tests and programmatic inspection.

    `strict=True` (the default) validates every logged metric key against
    the obs registry and raises `UnknownMetricError` on a write outside it —
    the enforcement half of the typed-key registry.
    """

    def __init__(self, strict: bool = True):
        self.strict = strict
        self._lock = threading.Lock()
        self.steps: list = []     # (step, metrics dict) in log order
        self.spans: list = []     # Span
        self.events: list = []    # Event

    def log(self, metrics: dict, *, step: int) -> None:
        if self.strict:
            validate_keys(metrics.keys())
        with self._lock:
            self.steps.append((int(step), dict(metrics)))

    def span(self, span: Span) -> None:
        with self._lock:
            self.spans.append(span)

    def event(self, event: Event) -> None:
        with self._lock:
            self.events.append(event)

    def spans_on(self, lane_prefix: str) -> list:
        """Spans whose lane starts with `lane_prefix` (e.g. "ascent")."""
        with self._lock:
            return [s for s in self.spans if s.lane.startswith(lane_prefix)]


def jsonl_record(step: int, metrics: dict) -> dict:
    """One telemetry record, in the historical `StalenessTelemetry` shape.

    Field order is the contract: step, tau, perturbed, step_time_s, loss,
    then each ENGINE_OPTIONAL_METRIC_KEYS member present in `metrics`, in
    registry order. `StalenessTelemetry` and `JsonlSink` both build records
    here, so their output stays byte-identical.
    """
    loss = metrics.get("loss")
    rec = {"step": int(step),
           "tau": int(metrics.get("tau", 0)),
           "perturbed": float(metrics.get("perturbed", 0.0)),
           "step_time_s": metrics.get("step_time_s"),
           "loss": float(loss) if loss is not None else None}
    for key in ENGINE_OPTIONAL_METRIC_KEYS:
        if key in metrics:
            rec[key] = float(metrics[key])
    return rec


class JsonlSink(Sink):
    """Streamed per-step jsonl records (crash-safe: flushed per line).

    Byte-compatible with the records `StalenessTelemetry(jsonl_path=...)`
    wrote before the tracker existed, so `benchmarks/fig3_throughput.py` /
    `table_4_2_hetero.py` and any external consumer parse either vintage.
    """

    def __init__(self, path: Union[str, pathlib.Path]):
        self.path = pathlib.Path(path)
        self._lock = threading.Lock()
        self._fh = None

    def log(self, metrics: dict, *, step: int) -> None:
        rec = jsonl_record(step, metrics)
        with self._lock:
            if self._fh is None:
                self.path.parent.mkdir(parents=True, exist_ok=True)
                self._fh = self.path.open("w")
            self._fh.write(json.dumps(rec) + "\n")
            self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


class Tracker:
    """Fan-out facade over sinks, plus process-local counters/histograms.

    With no sinks it is the null tracker: every call is a cheap no-op, which
    is what uninstrumented runs pay.
    """

    def __init__(self, sinks: Sequence[Sink] = ()):
        self.sinks = list(sinks)
        self._lock = threading.Lock()
        self._counters: dict = {}
        self._hists: dict = {}

    # --- scalars ------------------------------------------------------------
    def log(self, metrics: dict, *, step: int) -> None:
        """Record one step's scalar metrics in every sink."""
        for sink in self.sinks:
            sink.log(metrics, step=step)

    # --- counters / histograms ---------------------------------------------
    def count(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def histogram(self, name: str, value: float) -> None:
        with self._lock:
            self._hists.setdefault(name, []).append(float(value))

    @property
    def counters(self) -> dict:
        with self._lock:
            return dict(self._counters)

    def summary(self) -> dict:
        """Counters plus per-histogram {count,min,max,mean,p50,p95}."""
        with self._lock:
            hists = {}
            for name, vals in self._hists.items():
                s = sorted(vals)
                n = len(s)
                hists[name] = {
                    "count": n, "min": s[0], "max": s[-1],
                    "mean": sum(s) / n,
                    "p50": s[int(0.50 * (n - 1))],
                    "p95": s[int(0.95 * (n - 1))],
                }
            return {"counters": dict(self._counters), "histograms": hists}

    # --- spans / events -----------------------------------------------------
    @contextlib.contextmanager
    def span(self, name: str, *, lane: str = "main",
             **args: Any) -> Iterator[None]:
        """`with tracker.span("ascent_exchange", lane=..., tau=...):` —
        times the body and dispatches one Span to every sink on exit (also
        on exception, so a failing step still shows its cost)."""
        t0 = trace_now()
        try:
            yield
        finally:
            self.span_at(name, lane=lane, t0=t0, t1=trace_now(), **args)

    def span_at(self, name: str, *, lane: str, t0: float, t1: float,
                **args: Any) -> None:
        """Record a span whose endpoints were measured elsewhere (e.g. the
        submit→harvest window of an asynchronous exchange)."""
        if not self.sinks:
            return
        span = Span(name, lane, t0, t1, args)
        for sink in self.sinks:
            sink.span(span)

    def event(self, name: str, *, lane: str = "main", **args: Any) -> None:
        if not self.sinks:
            return
        ev = Event(name, lane, trace_now(), args)
        for sink in self.sinks:
            sink.event(ev)

    # --- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        for sink in self.sinks:
            sink.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


#: The null tracker uninstrumented code paths see.
_NULL_TRACKER = Tracker()
_current: Tracker = _NULL_TRACKER
_current_lock = threading.Lock()


def current_tracker() -> Tracker:
    """The process-global tracker (the null tracker when none installed)."""
    return _current


def set_global_tracker(tracker: Optional[Tracker]) -> None:
    """Install `tracker` globally (None restores the null tracker)."""
    global _current
    with _current_lock:
        _current = tracker if tracker is not None else _NULL_TRACKER


@contextlib.contextmanager
def use_tracker(tracker: Tracker) -> Iterator[Tracker]:
    """Scoped install: `Engine.fit` wraps the loop in this, so lane worker
    threads observe the fit's tracker while it runs and the previous one is
    restored after (trackers don't nest across concurrent fits in one
    process — last installed wins, same as levanter's)."""
    global _current
    with _current_lock:
        prev = _current
        _current = tracker
    try:
        yield tracker
    finally:
        with _current_lock:
            _current = prev
