"""Chrome/Perfetto trace-event exporter — lanes as named tracks.

`TraceEventSink` collects the tracker's spans, events, and counter-eligible
scalars and writes Chrome trace-event JSON (the `{"traceEvents": [...]}`
object form) loadable by ui.perfetto.dev or chrome://tracing. Each lane name
("descent", "ascent-thread", "ascent-remote", "pool-worker-0", "elastic")
becomes its own named track via "M" thread_name metadata, so the paper's
Fig-1 claim — the ascent (perturbation) computation hiding under descent
compute — renders as literal span overlap between the two tracks.

Timestamps arrive in `trace_now()` seconds (time.perf_counter). The trace
format wants microseconds from an arbitrary epoch; we rebase everything to
the earliest timestamp seen at close() time so traces start at t=0.
"""
from __future__ import annotations

import json
import pathlib
import threading
from typing import Union

from repro.obs.registry import TRACE_COUNTER_KEYS
from repro.obs.tracker import Event, Sink, Span

#: The single synthetic process all tracks live under.
TRACE_PID = 1


class TraceEventSink(Sink):
    """Buffers spans/events/counters; writes the trace JSON on close()."""

    def __init__(self, path: Union[str, pathlib.Path]):
        self.path = pathlib.Path(path)
        self._lock = threading.Lock()
        self._spans: list = []      # Span
        self._events: list = []     # Event
        self._counters: list = []   # (ts, key, value)
        self._lanes: dict = {}      # lane name -> tid (stable discovery order)
        self._closed = False

    def _tid(self, lane: str) -> int:
        if lane not in self._lanes:
            self._lanes[lane] = len(self._lanes) + 1
        return self._lanes[lane]

    def log(self, metrics: dict, *, step: int) -> None:
        # counters ride the step clock: sampled when the engine logs them
        ts = None
        with self._lock:
            for key in TRACE_COUNTER_KEYS:
                if key in metrics:
                    if ts is None:
                        from repro.obs.tracker import trace_now
                        ts = trace_now()
                    self._counters.append((ts, key, float(metrics[key])))

    def span(self, span: Span) -> None:
        with self._lock:
            self._tid(span.lane)
            self._spans.append(span)

    def event(self, event: Event) -> None:
        with self._lock:
            self._tid(event.lane)
            self._events.append(event)

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self.path.write_text(json.dumps(self._render()))

    def _render(self) -> dict:
        stamps = ([s.t0 for s in self._spans]
                  + [e.ts for e in self._events]
                  + [ts for ts, _, _ in self._counters])
        epoch = min(stamps) if stamps else 0.0

        def us(t: float) -> float:
            return round((t - epoch) * 1e6, 3)

        out = [{"name": "process_name", "ph": "M", "pid": TRACE_PID,
                "args": {"name": "repro-asyncsam"}}]
        for lane, tid in self._lanes.items():
            out.append({"name": "thread_name", "ph": "M", "pid": TRACE_PID,
                        "tid": tid, "args": {"name": lane}})
            # sort_index pins descent above ascent above pool/elastic so the
            # overlap story reads top-down
            out.append({"name": "thread_sort_index", "ph": "M",
                        "pid": TRACE_PID, "tid": tid,
                        "args": {"sort_index": tid}})
        for s in self._spans:
            out.append({"name": s.name, "ph": "X", "pid": TRACE_PID,
                        "tid": self._tid(s.lane), "ts": us(s.t0),
                        "dur": round(s.duration_s * 1e6, 3),
                        "cat": s.lane, "args": s.args})
        for e in self._events:
            out.append({"name": e.name, "ph": "i", "s": "g",
                        "pid": TRACE_PID, "tid": self._tid(e.lane),
                        "ts": us(e.ts), "cat": e.lane, "args": e.args})
        for ts, key, value in self._counters:
            out.append({"name": key, "ph": "C", "pid": TRACE_PID,
                        "ts": us(ts), "args": {key: value}})
        return {"traceEvents": out, "displayTimeUnit": "ms"}
