"""repro.obs — unified observability: metric registry, tracker, sinks.

See `repro.obs.tracker` for the architecture. The short version:

    tracker = Tracker([JsonlSink("run.jsonl"), TraceEventSink("trace.json")])
    Engine(...).fit(state, batches, tracker=tracker)

and every layer below — executors, ascent lanes, the remote client, the
ascent pool's workers, the elastic resize path — reports spans and metrics
through `current_tracker()` for the duration of the fit.
"""
from repro.obs.registry import (ENGINE_METRIC_KEYS,
                                ENGINE_OPTIONAL_METRIC_KEYS, METRIC_KEYS,
                                REGISTRY, TRACE_COUNTER_KEYS, MetricKey,
                                UnknownMetricError, metric_key,
                                registry_table, scalar_metrics,
                                validate_keys)
from repro.obs.tracker import (Event, JsonlSink, MemorySink, Sink, Span,
                               Tracker, current_tracker, jsonl_record,
                               set_global_tracker, trace_now, use_tracker)
from repro.obs.trace import TraceEventSink

__all__ = [
    "ENGINE_METRIC_KEYS", "ENGINE_OPTIONAL_METRIC_KEYS", "METRIC_KEYS",
    "REGISTRY", "TRACE_COUNTER_KEYS", "MetricKey", "UnknownMetricError",
    "metric_key", "registry_table", "scalar_metrics", "validate_keys",
    "Event", "JsonlSink", "MemorySink", "Sink", "Span", "Tracker",
    "current_tracker", "jsonl_record", "set_global_tracker", "trace_now",
    "use_tracker", "TraceEventSink",
]
