"""Checkpointing: atomic, async, keep-k, verified, elastic-reshard on restore.

Layout (one directory per step):

    <root>/step_000400.tmp/...      while writing
    <root>/step_000400/
        manifest.json               treedef paths, shapes, dtypes, crc32s, extras
        manifest.crc32              crc32 of the manifest bytes (text)
        arrays/<leaf-path>.npy      one file per leaf (addressable data)

Writes go to a .tmp directory first and are renamed into place (atomic on
POSIX), so a crash mid-save can never corrupt the latest checkpoint; restore
always picks the newest complete directory. `save(..., blocking=False)` hands
the host transfer + IO to a worker thread so the training loop only pays for
device->host of the step it snapshots. A failure on that worker (disk full,
permissions) is captured and re-raised from `wait()` or the next `save()` —
never silently swallowed: `run_resilient` sees it as a failed step and spends
a restart on it.

Integrity: every leaf record carries the crc32 of its array bytes and the
manifest itself is checksummed into a sibling file. `restore` verifies leaf
crcs while loading and falls back to the newest *verified* older step when a
checkpoint is corrupted or truncated instead of crashing or loading garbage;
`all_steps` skips directories that fail the (cheap, manifest-level)
verification. Pre-integrity-era checkpoints — no crc fields, no sibling
file — still restore unchanged: absent checksums verify vacuously.

Elastic restore: arrays are read on host and `jax.device_put` against the
*current* mesh/sharding — a checkpoint written on a 16x16 mesh restores onto
2x16x16 (or a single CPU device) unchanged; tests/test_checkpoint.py covers
save->reshard->restore equality.
"""
from __future__ import annotations

import json
import logging
import pathlib
import re
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

from repro.utils import trees

log = logging.getLogger("repro.checkpoint")

Pytree = Any
_STEP_RE = re.compile(r"step_(\d+)$")


class CheckpointIntegrityError(RuntimeError):
    """A checkpoint directory failed crc32/structure verification."""


def _leaf_crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def _tree_finite(state: Pytree) -> bool:
    """True iff every float leaf is fully finite (host-side; restore-path
    only, so the device round-trip cost is paid once per rollback)."""
    for leaf in jax.tree.leaves(state):
        arr = np.asarray(jax.device_get(leaf))
        if np.issubdtype(arr.dtype, np.floating) and not np.isfinite(arr).all():
            return False
    return True


class CheckpointManager:
    def __init__(self, root: str | pathlib.Path, keep: int = 3):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._worker: Optional[threading.Thread] = None
        self._async_error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Pytree, extras: Optional[dict] = None,
             blocking: bool = True) -> pathlib.Path:
        """Snapshot `state` (any pytree of arrays) at `step`.

        Re-raises a failure from a previous non-blocking save first — the
        caller must not keep training believing checkpoints exist.
        """
        self.wait()
        # snapshot on host NOW so the caller may mutate/donate state after
        leaves, treedef = jax.tree.flatten(state)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        paths = trees.tree_paths(state)
        final = self.root / f"step_{step:08d}"
        tmp = self.root / f"step_{step:08d}.tmp"

        def write():
            if tmp.exists():
                shutil.rmtree(tmp)
            (tmp / "arrays").mkdir(parents=True)
            manifest = {"step": step, "extras": extras or {}, "leaves": []}
            for path, arr in zip(paths, host_leaves):
                fname = path.replace("/", "__") + ".npy"
                np.save(tmp / "arrays" / fname, arr)
                manifest["leaves"].append(
                    {"path": path, "file": fname,
                     "shape": list(arr.shape), "dtype": str(arr.dtype),
                     "crc32": _leaf_crc(arr)})
            manifest_bytes = json.dumps(manifest).encode()
            (tmp / "manifest.json").write_bytes(manifest_bytes)
            # the manifest's own checksum lives in a sibling file (it cannot
            # checksum itself); a torn/corrupted manifest then fails cheap
            # verification instead of parsing into garbage leaf records
            (tmp / "manifest.crc32").write_text(
                str(zlib.crc32(manifest_bytes)))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()

        if blocking:
            write()
        else:
            def guarded():
                try:
                    write()
                except BaseException as e:  # noqa: BLE001 — captured, not
                    self._async_error = e   # swallowed: wait()/save() re-raise
            self._worker = threading.Thread(target=guarded, daemon=True)
            self._worker.start()
        return final

    def wait(self) -> None:
        """Join any in-flight async save; re-raise its failure (once)."""
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if self._async_error is not None:
            err, self._async_error = self._async_error, None
            raise RuntimeError(
                f"async checkpoint save failed: {type(err).__name__}: {err}"
            ) from err

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)

    # ---------------------------------------------------------- verification
    def _verify_manifest(self, d: pathlib.Path) -> Optional[dict]:
        """Cheap structural check: manifest parses, matches its sibling
        checksum, and every leaf file exists. Returns the manifest, or None.
        Legacy directories (no crc sibling) verify on structure alone."""
        try:
            manifest_bytes = (d / "manifest.json").read_bytes()
            crc_file = d / "manifest.crc32"
            if crc_file.exists() and \
                    int(crc_file.read_text()) != zlib.crc32(manifest_bytes):
                return None
            manifest = json.loads(manifest_bytes)
            for rec in manifest["leaves"]:
                if not (d / "arrays" / rec["file"]).is_file():
                    return None
            return manifest
        except (OSError, ValueError, KeyError, TypeError):
            return None

    def verify_step(self, step: int, deep: bool = True) -> bool:
        """Full verification of one step: manifest + (deep) per-leaf crc32."""
        d = self.root / f"step_{step:08d}"
        manifest = self._verify_manifest(d)
        if manifest is None:
            return False
        if not deep:
            return True
        for rec in manifest["leaves"]:
            try:
                arr = np.load(d / "arrays" / rec["file"])
            except (OSError, ValueError):
                return False
            if "crc32" in rec and _leaf_crc(arr) != rec["crc32"]:
                return False
        return True

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        """Steps with a structurally verified checkpoint directory."""
        out = []
        for p in self.root.iterdir():
            m = _STEP_RE.search(p.name)
            if m and p.is_dir() and self._verify_manifest(p) is not None:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_step(self, step: int, like: Pytree,
                   shardings: Optional[Pytree]) -> tuple[Pytree, dict]:
        """Load one verified step, crc-checking every leaf as it is read.

        Raises CheckpointIntegrityError on any mismatch/corruption so
        `restore` can fall back to an older step.
        """
        d = self.root / f"step_{step:08d}"
        manifest = self._verify_manifest(d)
        if manifest is None:
            raise CheckpointIntegrityError(f"{d}: manifest failed "
                                           "verification")
        by_path = {rec["path"]: rec for rec in manifest["leaves"]}

        leaves, treedef = jax.tree.flatten(like)
        paths = trees.tree_paths(like)
        shard_leaves = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None
                                        or hasattr(x, "spec"))
                        if shardings is not None else [None] * len(leaves))
        out = []
        for path, leaf, sh in zip(paths, leaves, shard_leaves):
            rec = by_path.get(path)
            assert rec is not None, f"checkpoint missing leaf {path}"
            try:
                arr = np.load(d / "arrays" / rec["file"])
            except (OSError, ValueError) as e:
                raise CheckpointIntegrityError(
                    f"{d}: leaf {path} unreadable ({e})") from e
            if "crc32" in rec and _leaf_crc(arr) != rec["crc32"]:
                raise CheckpointIntegrityError(
                    f"{d}: leaf {path} crc32 mismatch (corrupted data)")
            assert tuple(arr.shape) == tuple(leaf.shape), \
                f"{path}: ckpt {arr.shape} vs model {leaf.shape}"
            arr = arr.astype(leaf.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        return jax.tree.unflatten(treedef, out), manifest["extras"]

    def restore(self, like: Pytree, step: Optional[int] = None,
                shardings: Optional[Pytree] = None, *,
                require_finite: bool = False) -> tuple[Pytree, dict]:
        """Restore into the structure of `like`; device_put against
        `shardings` (elastic re-shard) when given. Returns (state, extras).

        A corrupted/truncated checkpoint falls back to the newest verified
        older step (a stale-but-true rollback target beats a fresh lie);
        only when every candidate fails does this raise. `require_finite`
        extends the same fallback to NUMERIC corruption: a checkpoint whose
        float leaves contain NaN/Inf (saved by an unguarded run after the
        dynamics already diverged) is skipped for the newest finite older
        step — the diverge-proof half of the numerics-guard rollback.
        """
        steps = self.all_steps()
        assert steps, f"no checkpoints under {self.root}"
        if step is not None:
            candidates = [s for s in steps if s <= step]
            assert candidates, f"no checkpoint at or before step {step}"
        else:
            candidates = steps
        last_err: Optional[Exception] = None
        for s in reversed(candidates):
            try:
                state, extras = self._load_step(s, like, shardings)
            except CheckpointIntegrityError as e:
                log.warning("checkpoint step %d failed verification (%s); "
                            "falling back to an older step", s, e)
                last_err = e
                continue
            if require_finite and not _tree_finite(state):
                log.warning("checkpoint step %d holds non-finite values; "
                            "falling back to an older step", s)
                last_err = CheckpointIntegrityError(
                    f"step {s}: non-finite leaf values")
                continue
            return state, extras
        raise CheckpointIntegrityError(
            f"no verifiable checkpoint under {self.root}") from last_err
