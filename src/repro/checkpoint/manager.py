"""Checkpointing: atomic, async, keep-k, elastic-reshard on restore.

Layout (one directory per step):

    <root>/step_000400.tmp/...      while writing
    <root>/step_000400/
        manifest.json               treedef paths, shapes, dtypes, extras
        arrays/<leaf-path>.npy      one file per leaf (addressable data)

Writes go to a .tmp directory first and are renamed into place (atomic on
POSIX), so a crash mid-save can never corrupt the latest checkpoint; restore
always picks the newest complete directory. `save(..., blocking=False)` hands
the host transfer + IO to a worker thread so the training loop only pays for
device->host of the step it snapshots.

Elastic restore: arrays are read on host and `jax.device_put` against the
*current* mesh/sharding — a checkpoint written on a 16x16 mesh restores onto
2x16x16 (or a single CPU device) unchanged; tests/test_checkpoint.py covers
save->reshard->restore equality.
"""
from __future__ import annotations

import json
import pathlib
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.utils import trees

Pytree = Any
_STEP_RE = re.compile(r"step_(\d+)$")


class CheckpointManager:
    def __init__(self, root: str | pathlib.Path, keep: int = 3):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._worker: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Pytree, extras: Optional[dict] = None,
             blocking: bool = True) -> pathlib.Path:
        """Snapshot `state` (any pytree of arrays) at `step`."""
        self.wait()
        # snapshot on host NOW so the caller may mutate/donate state after
        leaves, treedef = jax.tree.flatten(state)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        paths = trees.tree_paths(state)
        final = self.root / f"step_{step:08d}"
        tmp = self.root / f"step_{step:08d}.tmp"

        def write():
            if tmp.exists():
                shutil.rmtree(tmp)
            (tmp / "arrays").mkdir(parents=True)
            manifest = {"step": step, "extras": extras or {}, "leaves": []}
            for path, arr in zip(paths, host_leaves):
                fname = path.replace("/", "__") + ".npy"
                np.save(tmp / "arrays" / fname, arr)
                manifest["leaves"].append(
                    {"path": path, "file": fname,
                     "shape": list(arr.shape), "dtype": str(arr.dtype)})
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()

        if blocking:
            write()
        else:
            self._worker = threading.Thread(target=write, daemon=True)
            self._worker.start()
        return final

    def wait(self) -> None:
        """Join any in-flight async save."""
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for p in self.root.iterdir():
            m = _STEP_RE.search(p.name)
            if m and p.is_dir() and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Pytree, step: Optional[int] = None,
                shardings: Optional[Pytree] = None) -> tuple[Pytree, dict]:
        """Restore into the structure of `like`; device_put against
        `shardings` (elastic re-shard) when given. Returns (state, extras)."""
        step = step if step is not None else self.latest_step()
        assert step is not None, f"no checkpoints under {self.root}"
        d = self.root / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        by_path = {rec["path"]: rec for rec in manifest["leaves"]}

        leaves, treedef = jax.tree.flatten(like)
        paths = trees.tree_paths(like)
        shard_leaves = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None
                                        or hasattr(x, "spec"))
                        if shardings is not None else [None] * len(leaves))
        out = []
        for path, leaf, sh in zip(paths, leaves, shard_leaves):
            rec = by_path.get(path)
            assert rec is not None, f"checkpoint missing leaf {path}"
            arr = np.load(d / "arrays" / rec["file"])
            assert tuple(arr.shape) == tuple(leaf.shape), \
                f"{path}: ckpt {arr.shape} vs model {leaf.shape}"
            arr = arr.astype(leaf.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        return jax.tree.unflatten(treedef, out), manifest["extras"]
