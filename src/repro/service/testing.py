"""Importable toy losses for the ascent-service loopback path.

The standalone server resolves its loss function by import path
(``--loss module:attr``), so losses used by loopback tests, benchmarks and
examples must live somewhere the server subprocess can import under
``PYTHONPATH=src``. This module is the single source of the generic
``w{i}/b{i}`` MLP: ``benchmarks/common.py`` re-exports `mlp_loss` from here,
so the descent side of a loopback benchmark and the server resolving
`MLP_LOSS_SPEC` always execute the same function.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

#: what a loopback client passes as `loss_spec` to reach `mlp_loss` below
MLP_LOSS_SPEC = "repro.service.testing:mlp_loss"


def mlp_init(key, widths=(8, 32, 4)) -> dict:
    params = {}
    for i, (a, b) in enumerate(zip(widths[:-1], widths[1:])):
        k = jax.random.fold_in(key, i)
        params[f"w{i}"] = jax.random.normal(k, (a, b)) / jnp.sqrt(a)
        params[f"b{i}"] = jnp.zeros(b)
    return params


def mlp_loss(params, batch, rng):
    h = batch["x"]
    n = len([k for k in params if k.startswith("w")])
    for i in range(n):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            h = jax.nn.gelu(h)
    onehot = jax.nn.one_hot(batch["y"], h.shape[-1])
    loss = -jnp.mean(jnp.sum(jax.nn.log_softmax(h) * onehot, axis=-1))
    return loss, {"logits": h}
