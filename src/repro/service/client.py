"""RemoteAscentClient — the descent host's end of the multi-host ascent lane.

Satisfies the same `AscentLane` protocol as the in-process thread lane
(`runtime.async_executor.ThreadAscentLane`): `submit` is non-blocking with a
depth-1 job queue (the paper's depth-1 exchange — backpressure, not
buffering), `poll` harvests finished gradients, and a single worker thread
owns the socket: connect + HELLO handshake, send JOB, await GRAD, reconnect
with backoff on any drop.

Reconnect-and-reset semantics mirror the generation-fenced `reset()` of the
executor: a connection drop loses exactly the in-flight exchange (the job
that was on the wire and whatever the server was computing), the held-
gradient staleness ledger on the executor side keeps aging (tau grows, then
SGD fallback), and training never stalls on a dead helper. `close()` is
shutdown-safe for a client that never managed to connect: the connect loop
polls the stop event between bounded attempts, so the join cannot hang.
"""
from __future__ import annotations

import queue
import sys
import threading
import time
from typing import Any, Optional

import jax

from repro.core.ascent import Compressor
from repro.runtime.async_executor import drain_queue, poll_queue
from repro.service import protocol
from repro.service.protocol import FrameType, ProtocolError

Pytree = Any


class RemoteAscentClient:
    """Non-blocking client for `repro.service.ascent_server`."""

    def __init__(self, addr: str, compressor: Optional[Compressor] = None, *,
                 connect_timeout_s: float = 60.0,
                 reconnect_backoff_s: float = 0.25):
        self._addr = addr
        self._addr_lock = threading.Lock()
        self._compressor = compressor or Compressor(kind="none")
        self.connect_timeout_s = connect_timeout_s
        self.reconnect_backoff_s = reconnect_backoff_s
        self._jobs: queue.Queue = queue.Queue(maxsize=1)
        self._results: queue.Queue = queue.Queue(maxsize=2)
        self._stop = threading.Event()
        self._closed = False
        self._sock = None
        self.connected = threading.Event()
        # telemetry
        self.reconnects = 0          # successful (re)connections after the first
        self.drops = 0               # exchanges lost to a dead connection
        self.server_errors = 0       # ERROR frames (connection stayed up)
        self.last_error = ""         # last server/exchange failure, for ops
        self.exchanges = 0
        self.wire_in_bytes = 0       # totals across the session
        self.wire_out_bytes = 0
        self.last_rtt_s = 0.0
        self.last_wire_in_bytes = 0  # GRAD frame length of the last exchange
        self.last_wire_out_bytes = 0
        self.wire_bytes_per_exchange = 0   # measured GRAD frame bytes
        self.timings: list[float] = []     # per-exchange round-trip seconds
        self._ever_connected = False
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # --- AscentLane surface ----------------------------------------------------
    def full(self) -> bool:
        return self._jobs.full()

    def submit(self, gen: int, params: Pytree, batch: Pytree, rng,
               step: int) -> bool:
        if self._jobs.full():
            return False
        try:
            self._jobs.put_nowait((gen, jax.device_get(params),
                                   jax.device_get(batch),
                                   jax.device_get(rng), step))
        except queue.Full:
            return False
        return True

    def poll(self, block: bool = False, timeout: Optional[float] = None):
        return poll_queue(self._results, block, timeout)

    def probe(self, params: Pytree, batch: Pytree, rng, probes: int) -> float:
        """Timed blocking round trips for calibrate(): measures the real slow
        lane — server compute plus the wire. The first exchange (connect +
        server-side jit compile) is the excluded warmup."""
        def once(timeout):
            if not self.submit(0, params, batch, rng, 0):
                raise RuntimeError("probe: remote lane busy")
            got = self.poll(block=True, timeout=timeout)
            if got is None:
                raise RuntimeError(
                    f"ascent service at {self.address} did not answer the "
                    f"calibration probe within {timeout:.0f}s")
            return got

        once(self.connect_timeout_s + 600.0)   # warmup: connect + compile
        t0 = time.perf_counter()
        for _ in range(probes):
            once(600.0)
        return time.perf_counter() - t0

    def reset(self) -> None:
        drain_queue(self._jobs)
        drain_queue(self._results)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self._drop_socket()          # unblocks a worker inside recv/sendall
        self.reset()
        if self._thread.is_alive():
            self._thread.join(timeout=10.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # --- address / connection --------------------------------------------------
    @property
    def address(self) -> str:
        with self._addr_lock:
            return self._addr

    def set_address(self, addr: str) -> None:
        """Point at a replacement server (loopback respawn); forces reconnect."""
        with self._addr_lock:
            self._addr = addr
        self._drop_socket()

    def wait_connected(self, timeout: float) -> bool:
        return self.connected.wait(timeout)

    def _note_error(self, msg: str) -> None:
        """Record the failure and print it once per distinct message (a
        persistent server-side fault would otherwise be invisible: the run
        keeps completing steps in SGD fallback)."""
        if msg != self.last_error:
            print(f"[remote-ascent] {msg}", file=sys.stderr, flush=True)
        self.last_error = msg

    def _drop_socket(self) -> None:
        sock, self._sock = self._sock, None
        self.connected.clear()
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass

    def _connect_once(self):
        """Attempt one connect + HELLO handshake; returns the socket or None."""
        try:
            sock = protocol.connect(self.address, timeout=2.0)
        except OSError:
            return None
        try:
            protocol.send_frame(sock, FrameType.HELLO,
                                protocol.encode_hello(self._compressor))
            ftype, _payload, _ = protocol.recv_frame(sock, stop=self._stop,
                                                     timeout=30.0)
            if ftype != FrameType.HELLO_ACK:
                raise ProtocolError(f"expected HELLO_ACK, got {ftype.name}")
        except (OSError, ProtocolError, TimeoutError, ConnectionError):
            try:
                sock.close()
            except OSError:
                pass
            return None
        self._sock = sock
        if self._ever_connected:
            self.reconnects += 1
        self._ever_connected = True
        self.connected.set()
        return sock

    # --- worker ----------------------------------------------------------------
    def _worker(self) -> None:
        while not self._stop.is_set():
            # local reference: set_address()/close() may null self._sock from
            # another thread at any point (the closed socket then raises
            # OSError here, which is the reconnect path, not a crash)
            sock = self._sock
            if sock is None:
                sock = self._connect_once()
                if sock is None:
                    # bounded attempts + stop polling: a client that never
                    # connects still closes promptly (no hanging join)
                    self._stop.wait(self.reconnect_backoff_s)
                    continue
            try:
                job = self._jobs.get(timeout=0.1)
            except queue.Empty:
                continue
            if self._stop.is_set():
                break
            gen, params, batch, rng, step = job
            treedef = jax.tree.structure(params)
            t0 = time.perf_counter()
            try:
                out_bytes = protocol.send_frame(
                    sock, FrameType.JOB,
                    protocol.encode_job(gen, step, params, batch, rng))
                # no deadline: a slow helper is staleness, not an error —
                # a dead one surfaces as a socket error / EOF
                ftype, payload, in_bytes = protocol.recv_frame(
                    sock, stop=self._stop)
                if ftype == FrameType.ERROR:
                    # server-side compute failure: the connection is still
                    # good (the server kept its loop), only this exchange is
                    # lost — surface the server's diagnostic, don't tear down
                    self.server_errors += 1
                    self._note_error("ascent server error: "
                                     + payload.decode(errors="replace"))
                    self._post_failure(gen)
                    continue
                if ftype != FrameType.GRAD:
                    raise ProtocolError(f"expected GRAD, got {ftype.name}")
                rtt = time.perf_counter() - t0
                rgen, _job_step, norm, compute_s, leaves = \
                    protocol.decode_grad(payload)
                g = jax.tree.unflatten(treedef, leaves)
            except ConnectionAbortedError:
                break        # close() interrupted the wait
            except (OSError, ConnectionError, ProtocolError, TimeoutError) as e:
                if self._stop.is_set():
                    break    # close() tore the socket down, not a real drop
                self.drops += 1
                self._note_error(f"exchange dropped ({type(e).__name__}: {e})")
                self._post_failure(gen)
                self._drop_socket()   # in-flight exchange is lost; reconnect
                continue
            except Exception as e:  # noqa: BLE001 — the lane must never die
                # silently: an encode/decode bug (e.g. a >4GiB frame
                # overflowing the u32 length, or an unflatten mismatch)
                # would otherwise kill this daemon thread and leave training
                # in permanent SGD fallback with a forever-full job queue
                self.drops += 1
                self._note_error(
                    f"exchange failed ({type(e).__name__}: {e})")
                self._post_failure(gen)
                self._drop_socket()
                continue
            self.exchanges += 1
            self.timings.append(rtt)
            self.last_rtt_s = rtt
            self.last_wire_in_bytes = in_bytes
            self.last_wire_out_bytes = out_bytes
            self.wire_in_bytes += in_bytes
            self.wire_out_bytes += out_bytes
            self.wire_bytes_per_exchange = in_bytes
            meta = {"wire_bytes": float(in_bytes + out_bytes), "rtt_s": rtt,
                    "wire_in_bytes": in_bytes, "wire_out_bytes": out_bytes,
                    "server_compute_s": compute_s}
            try:
                self._results.put((rgen, g, norm, meta), timeout=1.0)
            except queue.Full:
                pass         # consumer lagging: drop (stale anyway)

    def _post_failure(self, gen: int) -> None:
        """Lost-exchange sentinel (grad=None): releases a lockstep waiter
        immediately instead of letting it sit out the full poll timeout."""
        try:
            self._results.put_nowait((gen, None, 0.0, {}))
        except queue.Full:
            pass
