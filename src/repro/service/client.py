"""RemoteAscentClient — the descent host's end of the multi-host ascent lane.

Satisfies the same `AscentLane` protocol as the in-process thread lane
(`runtime.async_executor.ThreadAscentLane`): `submit` is non-blocking with a
depth-1 job queue (the paper's depth-1 exchange — backpressure, not
buffering), `poll` harvests finished gradients, and a single worker thread
owns the socket: connect + HELLO handshake, send JOB, await GRAD, reconnect
with backoff on any drop.

Reconnect-and-reset semantics mirror the generation-fenced `reset()` of the
executor: a connection drop loses exactly the in-flight exchange (the job
that was on the wire and whatever the server was computing), the held-
gradient staleness ledger on the executor side keeps aging (tau grows, then
SGD fallback), and training never stalls on a dead helper. `close()` is
shutdown-safe for a client that never managed to connect: the connect loop
polls the stop event between bounded attempts, so the join cannot hang.

The JOB direction is encoded by `service.delta.JobEncoder` at submit time
(on the executor thread, while the donated device params are still alive):
full snapshots by default, delta+quantized bucket sections against a shared
shadow when `job_encoding`/`job_delta` ask for it and the HELLO handshake
negotiated a server that understands them. Any event that could skew the
server's shadow — connection drop, RESYNC frame, executor reset — falls
back to a full-snapshot JOB. With `retry_inflight` (the lockstep test
mode), a dropped exchange is resent as a snapshot of the encoder's shadow
instead of being reported lost, so a mid-fit server kill stays bitwise
transparent to the training schedule.

Against a multi-client pool server (protocol revision 3) the client also
declares its identity in HELLO — `client_id` (stable across reconnects),
`sync_group` (same-group clients receive the pool's shared smoothed ascent
gradient per generation/step), `auth_token` (non-loopback listeners) — and
handles the pool's two new frames: BUSY (queue saturated; the exchange is
reported lost and the executor's staleness ledger absorbs it) and DETACH
(the canonical shadow's epoch moved past this stream; the encoder
fast-forwards and re-installs with a snapshot). Reconnects use jittered
exponential backoff so a restarted pool is not thundering-herded by its
whole fleet.
"""
from __future__ import annotations

import os
import queue
import random
import sys
import threading
import time
from typing import Any, Optional

import jax

from repro.core.ascent import Compressor
from repro.obs import current_tracker, trace_now
from repro.runtime.async_executor import drain_queue, poll_queue
from repro.service import protocol
from repro.service.delta import EncodedJob, JobEncoder
from repro.service.pool import client_uid
from repro.service.protocol import FrameType, ProtocolError

Pytree = Any

_client_seq = [0]
_client_seq_lock = threading.Lock()


def _default_client_id() -> str:
    """Process-unique default identity (the pool keys private canonical
    shadows and error-feedback streams by it, so same-client reconnects must
    present the same id while two clients in one process must not)."""
    with _client_seq_lock:
        _client_seq[0] += 1
        return f"client-{os.getpid()}-{_client_seq[0]}"


def reconnect_delay(attempt: int, base_s: float, cap_s: float,
                    rand=random.random) -> float:
    """Jittered exponential reconnect backoff (attempt counts from 1).

    The exponential span doubles per failed attempt up to `cap_s`; the delay
    is drawn uniformly from [span/2, span] so N clients that lost the same
    pool at the same instant spread their retries instead of thundering-herd
    reconnecting in lockstep (the pre-pool client slept a FIXED
    `reconnect_backoff_s`, synchronizing the whole fleet). `rand` is
    injectable for deterministic tests.
    """
    span = min(float(cap_s), float(base_s) * (2.0 ** (max(1, attempt) - 1)))
    return span * (0.5 + 0.5 * rand())


class RemoteAscentClient:
    """Non-blocking client for `repro.service.ascent_server`."""

    #: the executor hands this lane raw (device) params; the encoder owns
    #: the host hop (and shrinks it to the quantized delta when enabled)
    encodes_jobs = True
    #: trace track this lane's rpc spans render on
    lane_name = "ascent-remote"

    def __init__(self, addr: str, compressor: Optional[Compressor] = None, *,
                 connect_timeout_s: float = 60.0,
                 reconnect_backoff_s: float = 0.25,
                 reconnect_backoff_max_s: float = 8.0,
                 job_encoding: str = "none", job_delta: bool = True,
                 job_topk_fraction: Optional[float] = None,
                 retry_inflight: bool = False,
                 client_id: str = "", sync_group: str = "",
                 auth_token: str = ""):
        self._addr = addr
        self._addr_lock = threading.Lock()
        self._compressor = compressor or Compressor(kind="none")
        self.connect_timeout_s = connect_timeout_s
        self.reconnect_backoff_s = reconnect_backoff_s
        self.reconnect_backoff_max_s = reconnect_backoff_max_s
        self.retry_inflight = retry_inflight
        self.client_id = client_id or _default_client_id()
        self.client_uid = client_uid(self.client_id)
        self.sync_group = sync_group
        self.auth_token = auth_token
        # negotiated server capabilities (set by the worker at HELLO time):
        # None = never connected, False = revision-1 server (legacy JOB
        # frames only), True = v2 jobs accepted
        self._v2_ok: Optional[bool] = None
        self._srv_encodings: set = set()
        self._srv_pool = False   # proto>=3 ACK: GRADs carry the pool prelude
        self._encoder = JobEncoder(
            job_encoding,
            topk_fraction=(job_topk_fraction
                           if job_topk_fraction is not None
                           else self._compressor.topk_fraction),
            delta=job_delta,
            caps_fn=lambda: (self._v2_ok, self._srv_encodings))
        self._jobs: queue.Queue = queue.Queue(maxsize=1)
        self._results: queue.Queue = queue.Queue(maxsize=2)
        self._stop = threading.Event()
        self._closed = False
        self._sock = None
        self.connected = threading.Event()
        # telemetry
        self.reconnects = 0          # successful (re)connections after the first
        self.drops = 0               # exchanges lost to a dead connection
        self.retried_exchanges = 0   # exchanges resent after a drop (lockstep)
        self.server_errors = 0       # ERROR frames (connection stayed up)
        self.busy_rejections = 0     # BUSY frames (pool queue saturated)
        self.detaches = 0            # DETACH frames (shadow epoch moved on)
        self.last_error = ""         # last server/exchange failure, for ops
        self.fatal_error = ""        # auth rejection: the worker gave up
        self.last_pool_depth = 0
        self.last_pool_wait_s = 0.0
        self._connect_failures = 0   # consecutive, drives the backoff
        self.exchanges = 0
        self.wire_in_bytes = 0       # totals across the session
        self.wire_out_bytes = 0
        self.last_rtt_s = 0.0
        self.last_wire_in_bytes = 0  # GRAD frame length of the last exchange
        self.last_wire_out_bytes = 0
        self.wire_bytes_per_exchange = 0   # measured GRAD frame bytes
        self.last_job_kind = ""            # "snapshot" | "int8" | "topk"
        #: measured JOB frame bytes of the last exchange, per job kind —
        #: what run_remote asserts against `protocol.job_frame_bytes`
        self.job_frame_measured: dict = {}
        self.timings: list[float] = []     # per-exchange round-trip seconds
        self._ever_connected = False
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # --- AscentLane surface ----------------------------------------------------
    def full(self) -> bool:
        return self._jobs.full()

    @property
    def job_encoder(self) -> JobEncoder:
        return self._encoder

    def submit(self, gen: int, params: Pytree, batch: Pytree, rng,
               step: int) -> bool:
        if self.fatal_error:
            raise RuntimeError(f"ascent service at {self.address} rejected "
                               f"this client: {self.fatal_error}")
        if self._jobs.full():
            return False
        # encode advances the shadow, so it must not run for a job that
        # cannot be queued — with the executor as the only submitter the
        # full() check above guarantees the put below succeeds
        job = self._encoder.encode(gen, params, jax.device_get(batch),
                                   jax.device_get(rng), step)
        try:
            self._jobs.put_nowait(job)
        except queue.Full:
            return False
        return True

    def poll(self, block: bool = False, timeout: Optional[float] = None):
        if self.fatal_error:
            # fail fast instead of letting a blocking waiter sit out its
            # whole timeout against a server that will never answer us
            raise RuntimeError(f"ascent service at {self.address} rejected "
                               f"this client: {self.fatal_error}")
        return poll_queue(self._results, block, timeout)

    def probe(self, params: Pytree, batch: Pytree, rng, probes: int) -> float:
        """Timed blocking round trips for calibrate(): measures the real slow
        lane — server compute plus the wire. The first exchange (connect +
        server-side jit compile) is the excluded warmup."""
        def once(timeout):
            if not self.submit(0, params, batch, rng, 0):
                raise RuntimeError("probe: remote lane busy")
            got = self.poll(block=True, timeout=timeout)
            if got is None:
                raise RuntimeError(
                    f"ascent service at {self.address} did not answer the "
                    f"calibration probe within {timeout:.0f}s")
            return got

        once(self.connect_timeout_s + 600.0)   # warmup: connect + compile
        t0 = time.perf_counter()
        for _ in range(probes):
            once(600.0)
        return time.perf_counter() - t0

    def reset(self) -> None:
        drain_queue(self._jobs)
        drain_queue(self._results)
        # a reset means the params timeline moved under us (checkpoint
        # restore / generation fence) — resync the delta stream
        self._encoder.invalidate()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self._drop_socket()          # unblocks a worker inside recv/sendall
        self.reset()
        if self._thread.is_alive():
            self._thread.join(timeout=10.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # --- address / connection --------------------------------------------------
    @property
    def address(self) -> str:
        with self._addr_lock:
            return self._addr

    def set_address(self, addr: str) -> None:
        """Point at a replacement server (loopback respawn); forces reconnect."""
        with self._addr_lock:
            self._addr = addr
        self._drop_socket()

    def wait_connected(self, timeout: float) -> bool:
        return self.connected.wait(timeout)

    def _note_error(self, msg: str) -> None:
        """Record the failure and print it once per distinct message (a
        persistent server-side fault would otherwise be invisible: the run
        keeps completing steps in SGD fallback)."""
        if msg != self.last_error:
            print(f"[remote-ascent] {msg}", file=sys.stderr, flush=True)
        self.last_error = msg

    def _drop_socket(self) -> None:
        sock, self._sock = self._sock, None
        self.connected.clear()
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass

    def _connect_once(self):
        """Attempt one connect + HELLO handshake; returns the socket or None."""
        try:
            sock = protocol.connect(self.address, timeout=2.0)
        except OSError:
            return None
        try:
            protocol.send_frame(sock, FrameType.HELLO,
                                protocol.encode_hello(
                                    self._compressor,
                                    client_id=self.client_id,
                                    group=self.sync_group,
                                    token=self.auth_token))
            ftype, payload, _ = protocol.recv_frame(sock, stop=self._stop,
                                                    timeout=30.0)
            if ftype == FrameType.ERROR:
                msg = payload.decode(errors="replace")
                if msg.startswith("auth-rejected"):
                    # a retry loop cannot fix a bad shared token: surface a
                    # fatal error (submit/poll raise) instead of silently
                    # reconnect-spamming a server that will keep refusing
                    self.fatal_error = msg
                    self._note_error(msg)
                raise ProtocolError(f"HELLO refused: {msg}")
            if ftype != FrameType.HELLO_ACK:
                raise ProtocolError(f"expected HELLO_ACK, got {ftype.name}")
            _, ack = protocol.decode_hello(payload)
        except (OSError, ProtocolError, TimeoutError, ConnectionError):
            try:
                sock.close()
            except OSError:
                pass
            return None
        # capability negotiation: a revision-1 server's ACK has no "proto"
        # key — degrade to full-snapshot legacy JOB frames instead of
        # failing mid-fit with an unknown-frame error
        proto = int(ack.get("proto") or 0)
        v2 = proto >= 2
        self._srv_encodings = set(ack.get("job_encodings") or []) if v2 else set()
        self._v2_ok = v2
        # gate on the revision that INTRODUCED the pool GRAD prelude, not
        # the moving PROTO_REVISION: a rev-3 server emits the prelude for
        # any client declaring proto>=3, and this client must decode it
        self._srv_pool = proto >= protocol.POOL_REVISION
        if not v2:
            self._encoder.invalidate()
        self._sock = sock
        self._connect_failures = 0
        if self._ever_connected:
            self.reconnects += 1
        self._ever_connected = True
        self.connected.set()
        return sock

    # --- worker ----------------------------------------------------------------
    def _frame_for(self, job: EncodedJob) -> tuple[FrameType, bytes]:
        """Frame a queued job for the negotiated protocol revision."""
        if self._v2_ok:
            return FrameType.JOB_DELTA, protocol.encode_job_v2(
                job.sync, job.seq, job.gen, job.step, job.batch, job.rng,
                params=job.params, kind=job.kind, deltas=job.deltas)
        if job.kind != "snapshot":
            # a delta job raced a reconnect onto a revision-1 server; it
            # cannot be expressed there — the caller drops the exchange
            raise ProtocolError(
                "delta-encoded job against a revision-1 server")
        return FrameType.JOB, protocol.encode_job(
            job.gen, job.step, job.params, job.batch, job.rng)

    def _worker(self) -> None:
        pending: Optional[EncodedJob] = None   # carried across retries
        while not self._stop.is_set():
            # local reference: set_address()/close() may null self._sock from
            # another thread at any point (the closed socket then raises
            # OSError here, which is the reconnect path, not a crash)
            sock = self._sock
            if sock is None:
                sock = self._connect_once()
                if sock is None:
                    if self.fatal_error:
                        # auth rejection: the server will keep refusing this
                        # token — stop retrying, surface via submit()/poll()
                        self._post_failure(0)
                        return
                    # bounded attempts + stop polling: a client that never
                    # connects still closes promptly (no hanging join);
                    # jittered exponential backoff so a restarted pool is
                    # not thundering-herded by its whole fleet at once
                    self._connect_failures += 1
                    self._stop.wait(reconnect_delay(
                        self._connect_failures, self.reconnect_backoff_s,
                        self.reconnect_backoff_max_s))
                    continue
            if pending is None:
                try:
                    pending = self._jobs.get(timeout=0.1)
                except queue.Empty:
                    continue
            if self._stop.is_set():
                break
            job = pending
            t0 = time.perf_counter()
            try:
                ftype_out, out_payload = self._frame_for(job)
                out_bytes = protocol.send_frame(sock, ftype_out, out_payload)
                # no deadline: a slow helper is staleness, not an error —
                # a dead one surfaces as a socket error / EOF
                ftype, payload, in_bytes = protocol.recv_frame(
                    sock, stop=self._stop)
                if ftype == FrameType.ERROR:
                    # server-side compute failure: the connection is still
                    # good (the server kept its loop and its shadow — a
                    # delta job was applied before the ascent ran), only
                    # this exchange is lost — surface the diagnostic
                    pending = None
                    self.server_errors += 1
                    self._note_error("ascent server error: "
                                     + payload.decode(errors="replace"))
                    self._post_failure(job.gen)
                    continue
                if ftype == FrameType.BUSY:
                    # pool queue saturated: the job was applied to the
                    # shadow but NOT computed — the delta stream is intact,
                    # only this exchange is lost (the executor's staleness
                    # ledger absorbs it, eventually SGD fallback)
                    pending = None
                    self.busy_rejections += 1
                    info = protocol.decode_busy(payload)
                    self.last_pool_depth = int(info.get("depth") or 0)
                    self._note_error(
                        f"pool busy (queue depth {info.get('depth')}); "
                        "exchange deferred to the staleness ledger")
                    self._post_failure(job.gen)
                    continue
                if ftype == FrameType.DETACH:
                    # the canonical shadow's epoch moved past our stream
                    # (another client or a reconnect advanced it): fast-
                    # forward the encoder's sync floor and re-install with a
                    # snapshot of the shadow — bitwise the same params
                    info = protocol.decode_resync(payload)
                    self.detaches += 1
                    self._encoder.fast_forward(int(info.get("sync") or 0))
                    retry = self._encoder.resync_job(job)
                    if retry is None:
                        pending = None
                        self._encoder.invalidate()
                        self.drops += 1
                        self._note_error("detached from canonical shadow "
                                         f"({info.get('reason')}); "
                                         "exchange dropped")
                        self._post_failure(job.gen)
                    else:
                        pending = retry
                        self.retried_exchanges += 1
                    continue
                if ftype == FrameType.RESYNC:
                    # the server's shadow cannot take this delta (fresh
                    # process, skewed sync/seq): resend as a full snapshot
                    # of the encoder's shadow — bitwise the same params
                    info = protocol.decode_resync(payload)
                    retry = self._encoder.resync_job(job)
                    if retry is None:
                        pending = None
                        self._encoder.invalidate()
                        self.drops += 1
                        self._note_error("resync requested "
                                         f"({info.get('reason')}); "
                                         "exchange dropped")
                        self._post_failure(job.gen)
                    else:
                        pending = retry
                        self.retried_exchanges += 1
                    continue
                if ftype != FrameType.GRAD:
                    raise ProtocolError(f"expected GRAD, got {ftype.name}")
                rtt = time.perf_counter() - t0
                rgen, _job_step, norm, compute_s, leaves, pool_meta = \
                    protocol.decode_grad(payload, pool=self._srv_pool)
                g = jax.tree.unflatten(job.treedef, leaves)
            except ConnectionAbortedError:
                break        # close() interrupted the wait
            except (OSError, ConnectionError, ProtocolError, TimeoutError) as e:
                if self._stop.is_set():
                    break    # close() tore the socket down, not a real drop
                self._drop_socket()   # in-flight exchange is interrupted
                if self.retry_inflight:
                    # lockstep mode: the exchange is recoverable — resend it
                    # (as a snapshot of the shadow if it was a delta) once
                    # the reconnect loop lands on a live server
                    retry = self._encoder.resync_job(job)
                    if retry is not None:
                        pending = retry
                        self.retried_exchanges += 1
                        self._note_error(
                            f"exchange interrupted ({type(e).__name__}: {e});"
                            " retrying as full snapshot")
                        continue
                pending = None
                self._encoder.invalidate()   # server shadow died with the
                self.drops += 1              # connection
                self._note_error(f"exchange dropped ({type(e).__name__}: {e})")
                self._post_failure(job.gen)
                continue
            except Exception as e:  # noqa: BLE001 — the lane must never die
                # silently: an encode/decode bug (e.g. a >4GiB frame
                # overflowing the u32 length, or an unflatten mismatch)
                # would otherwise kill this daemon thread and leave training
                # in permanent SGD fallback with a forever-full job queue
                pending = None
                self.drops += 1
                self._note_error(
                    f"exchange failed ({type(e).__name__}: {e})")
                self._post_failure(job.gen)
                self._drop_socket()
                self._encoder.invalidate()
                continue
            pending = None
            self.exchanges += 1
            # the on-wire window of this exchange (send JOB -> GRAD decoded),
            # on the remote lane's own trace track
            current_tracker().span_at(
                "ascent_rpc", lane=self.lane_name, t0=t0, t1=trace_now(),
                gen=rgen, step=job.step, kind=job.kind,
                wire_bytes=in_bytes + out_bytes,
                server_compute_s=round(compute_s, 6))
            self.timings.append(rtt)
            self.last_rtt_s = rtt
            self.last_wire_in_bytes = in_bytes
            self.last_wire_out_bytes = out_bytes
            self.wire_in_bytes += in_bytes
            self.wire_out_bytes += out_bytes
            self.wire_bytes_per_exchange = in_bytes
            self.last_job_kind = job.kind
            self.job_frame_measured[job.kind] = out_bytes
            meta = {"wire_bytes": float(in_bytes + out_bytes), "rtt_s": rtt,
                    "wire_in_bytes": in_bytes, "wire_out_bytes": out_bytes,
                    "job_bytes": float(out_bytes),
                    "grad_bytes": float(in_bytes),
                    "server_compute_s": compute_s,
                    "client_id": float(self.client_uid)}
            if pool_meta:
                self.last_pool_depth = pool_meta["pool_depth"]
                self.last_pool_wait_s = pool_meta["pool_wait_s"]
                meta["pool_depth"] = float(pool_meta["pool_depth"])
                meta["pool_wait_s"] = float(pool_meta["pool_wait_s"])
            try:
                self._results.put((rgen, g, norm, meta), timeout=1.0)
            except queue.Full:
                pass         # consumer lagging: drop (stale anyway)

    def _post_failure(self, gen: int) -> None:
        """Lost-exchange sentinel (grad=None): releases a lockstep waiter
        immediately instead of letting it sit out the full poll timeout."""
        try:
            self._results.put_nowait((gen, None, 0.0, {}))
        except queue.Full:
            pass


def fetch_pool_stats(addr: str, *, auth_token: str = "",
                     timeout: float = 30.0) -> dict:
    """Scrape one STATS snapshot from a pool server (revision 4).

    Connects as an *observer* (HELLO with `observe`, so the server creates no
    canonical shadow and the scrape never shows up as a training client),
    sends an empty STATS request, and returns the decoded snapshot dict —
    scheduler counters, queue capacity/depth, and the per-client/per-shadow
    detail sections. Raises ProtocolError against a pre-revision-4 server
    (whose ACK declares an older proto) and ConnectionError/OSError on an
    unreachable address; the caller decides whether a failed scrape matters.
    """
    sock = protocol.connect(addr, timeout=timeout)
    try:
        protocol.send_frame(sock, FrameType.HELLO, protocol.encode_hello(
            Compressor(kind="none"), client_id="stats-observer",
            token=auth_token, extra={"observe": True}))
        ftype, payload, _ = protocol.recv_frame(sock, timeout=timeout)
        if ftype == FrameType.ERROR:
            raise ProtocolError(
                f"HELLO refused: {payload.decode(errors='replace')}")
        if ftype != FrameType.HELLO_ACK:
            raise ProtocolError(f"expected HELLO_ACK, got {ftype.name}")
        _, ack = protocol.decode_hello(payload)
        if int(ack.get("proto") or 0) < protocol.STATS_REVISION:
            raise ProtocolError(
                f"server proto {ack.get('proto')} predates the STATS frame "
                f"(revision {protocol.STATS_REVISION})")
        protocol.send_frame(sock, FrameType.STATS, b"")
        ftype, payload, _ = protocol.recv_frame(sock, timeout=timeout)
        if ftype != FrameType.STATS:
            raise ProtocolError(f"expected STATS, got {ftype.name}")
        return protocol.decode_stats(payload)
    finally:
        try:
            sock.close()
        except OSError:
            pass
