"""AscentPool — the multi-client scheduler behind the ascent server.

PR 3/5 proved the paper's claim across a wire for exactly one descent client
talking to one ascent helper; LSAM (arXiv 2509.03110) is the argument that
asynchronous *distributed* SAM — many data-parallel workers sharing a
smoothed ascent signal — is where the approach pays off. This module turns
the one-connection serve loop into that fleet service:

    descent host 1 ──┐                       ┌── worker 1 ─┐
    descent host 2 ──┤  accept ──> bounded   ├── worker 2 ─┼─> jit ascent
        ...          │  threads    work queue│    ...      │   (shared fn)
    descent host N ──┘                       └── worker M ─┘

Three ideas, each replacing a per-connection structure from the old server:

**One canonical shadow per (scope, generation)** — `SharedShadow`. The old
server kept a `ShadowState` per connection; N data-parallel replicas would
each ship their own snapshot and delta stream of the *same* params. The pool
keeps ONE generation-stamped shadow per attach scope (the client's sync
group, or a private scope for ungrouped clients) that every same-scope
client's stream lands on: the first snapshot installs it, every subsequent
identical snapshot is an idempotent skip, and because lockstep DP replicas
emit identical power-of-two-scaled delta streams, a replica's delta that a
peer already applied is served from a short replay ring instead of being
re-applied (the sharing win — the shadow advances once, bitwise-identically,
no matter how many replicas feed it). Streams that genuinely skew fall back
to the PR 5 RESYNC contract, and a stream whose epoch the canonical shadow
has moved past gets a DETACH carrying the canonical sync so the client can
fast-forward its encoder and re-install above it.

**`global` ascent-sync groups** — `_Group`. Clients registered under the
same HELLO `group` receive a *consistent* ascent gradient per (generation,
step): the first job to arrive computes it (under the group lock, with the
group's own error-feedback state), an LSAM-style EMA smooths it across
steps, and a small keyed cache hands the same smoothed leaves to every other
group member asking for that (generation, step) — so all DP replicas perturb
coherently instead of each chasing its own noisy ascent direction.

**Bounded admission with BUSY backpressure.** Jobs are admitted to a
bounded queue served by M workers; when the queue is full the client gets a
BUSY frame instead of unbounded buffering — it treats the exchange as failed
and falls back to its staleness ledger, exactly the paper's depth-1
semantics generalized to N clients. Shadow deltas are applied BEFORE the
admission check, so a BUSY rejection costs the compute but never desyncs the
delta stream.

Hardening for non-loopback listeners: shared-token auth at HELLO (wrong or
missing token draws an immediate ERROR and a closed socket), per-client recv
idle deadlines and whole-frame send deadlines, and per-client error
isolation — a connection that speaks garbage, wedges, or dies is dropped
without touching the queue, the workers, or any other client.
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
import zlib
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core import make_ascent_fn
from repro.obs import current_tracker
from repro.runtime.async_executor import ascent_exchange
from repro.service import protocol
from repro.service.delta import ShadowState
from repro.service.protocol import FrameType, ProtocolError
from repro.utils import buckets, trees

Pytree = Any


def client_uid(client_id: str) -> int:
    """Stable numeric form of a client id for float-coerced telemetry.

    `StalenessTelemetry` coerces every optional metric through float(), so
    the jsonl `client_id` field is crc32 of the declared string id (or the
    integer itself when the id is already numeric)."""
    cid = str(client_id)
    if cid.isdigit():
        return int(cid)
    return zlib.crc32(cid.encode())


@dataclasses.dataclass
class PoolConfig:
    """Scheduler knobs for one AscentPool."""
    workers: int = 1              #: M concurrent ascent workers
    queue_depth: int = 4          #: admission bound before BUSY
    auth_token: str = ""          #: shared secret; "" disables auth
    hello_timeout_s: float = 30.0
    idle_timeout_s: float = 600.0  #: per-client recv deadline between jobs
    send_timeout_s: float = 120.0  #: whole-frame send budget per client
    shadow_history: int = 4       #: replay-ring depth per canonical shadow
    smooth_beta: float = 0.9      #: LSAM-style group-gradient EMA (0 = off)
    group_cache: int = 8          #: (gen, step) entries kept per group
    delay_s: float = 0.0          #: injected straggle (tests/benchmarks)
    legacy_hello: bool = False    #: behave like a revision-1 server


class SharedShadow:
    """One canonical generation-stamped shadow many delta streams land on.

    Wraps the PR 5 `ShadowState` (strict sync/seq gating, validate-before-
    apply) with the multi-writer dispositions: idempotent snapshot skips, a
    replay ring of the last `history` post-delta params (owned copies — the
    live buffers keep mutating under later deltas), and the DETACH signal
    for a stream whose sync epoch the canonical shadow has moved past.
    All dispositions run under one lock; the params trees handed back are
    cut from owned buffers, safe to read while the shadow advances.
    """

    def __init__(self, history: int = 4):
        self._state = ShadowState()
        self._lock = threading.Lock()
        self._history = max(1, int(history))
        self._ring: "collections.OrderedDict[int, list]" = \
            collections.OrderedDict()     # seq -> owned fp32 bucket buffers
        self.installs = 0
        self.skips = 0
        self.deltas_applied = 0
        self.replays = 0

    @property
    def sync(self) -> int:
        return self._state.sync

    @property
    def seq(self) -> int:
        return self._state.seq

    def bufs_copy(self) -> Optional[list]:
        """Owned copy of the current shadow buffers (test introspection)."""
        with self._lock:
            if self._state.bufs is None:
                return None
            return [b.copy() for b in self._state.bufs]

    def _cut(self, bufs: list) -> Pytree:
        return buckets.host_buckets_to_tree(bufs, self._state.layout,
                                            self._state.leaf_dtypes)

    def _record(self, seq: int) -> None:
        self._ring[seq] = [b.copy() for b in self._state.bufs]
        while len(self._ring) > self._history:
            self._ring.popitem(last=False)

    def take_snapshot(self, params: Pytree, sync: int) -> str:
        """-> "install" | "skip". The job computes from the frame's own
        params either way; only the canonical shadow bookkeeping differs."""
        with self._lock:
            st = self._state
            if st.bufs is None or int(sync) > st.sync:
                st.install(params, sync)
                self._ring.clear()
                self.installs += 1
                return "install"
            # same-or-older sync: a replica re-declaring the install the
            # first member already made (lockstep DP), a late joiner whose
            # peer's deltas advanced the shadow, or a stale stream that will
            # draw a DETACH on its first delta — never roll back
            self.skips += 1
            return "skip"

    def take_delta(self, kind: str, sections: list, sync: int,
                   seq: int) -> tuple:
        """-> ("apply"|"replay", params) | ("resync", reason) |
        ("detach", canonical_sync, reason).

        Raises ProtocolError (caller drops the connection) only for
        structurally damaged sections, with the shadow untouched."""
        with self._lock:
            st = self._state
            if st.bufs is None:
                return ("resync", "no shadow installed")
            if int(sync) == st.sync:
                if int(seq) == st.seq + 1:
                    st.apply(kind, sections, sync, seq)
                    self.deltas_applied += 1
                    self._record(int(seq))
                    return ("apply", self._cut(self._ring[int(seq)]))
                if int(seq) in self._ring:
                    # a lockstep peer already advanced the shadow through
                    # this seq; serve the recorded post-delta params without
                    # re-applying — the canonical shadow advances once
                    self.replays += 1
                    return ("replay", self._cut(self._ring[int(seq)]))
                return ("resync",
                        f"shadow at (sync={st.sync}, seq={st.seq}) cannot "
                        f"take (sync={sync}, seq={seq})")
            if int(sync) < st.sync:
                return ("detach", st.sync,
                        f"shadow epoch moved to sync={st.sync}, past this "
                        f"stream's sync={sync}")
            return ("resync",
                    f"shadow at sync={st.sync} never saw install "
                    f"sync={sync}")


class _Group:
    """Shared ascent-gradient state for one `global` sync group."""

    def __init__(self, beta: float, cache_size: int):
        self.lock = threading.Lock()
        self.beta = float(beta)
        self.cache_size = max(1, int(cache_size))
        self.cache: "collections.OrderedDict[tuple, tuple]" = \
            collections.OrderedDict()   # (gen, step) -> (leaves, norm, dt)
        self.smoothed: Optional[list] = None   # EMA leaves (np fp32)
        self.comp_state = None                 # group error-feedback state
        self.hits = 0
        self.computes = 0


@dataclasses.dataclass
class _Work:
    """One admitted exchange, queued for a pool worker."""
    client: "_ClientConn"
    gen: int
    step: int
    params: Pytree
    batch: Pytree
    rng: Any
    enq_t: float
    depth: int          # queue depth observed at admission


class _ClientConn:
    """One accepted connection's identity + framed-send discipline."""

    _anon = 0
    _anon_lock = threading.Lock()

    def __init__(self, conn, compressor, meta: dict):
        self.conn = conn
        self.compressor = compressor
        self.send_lock = threading.Lock()
        self.alive = True
        cid = str(meta.get("client_id") or "")
        if not cid:
            with _ClientConn._anon_lock:
                _ClientConn._anon += 1
                cid = f"anon-{_ClientConn._anon}"
        self.client_id = cid
        self.group = str(meta.get("group") or "")
        self.generation = int(meta.get("generation") or 0)
        self.proto = int(meta.get("proto") or 0)
        #: a stats observer connects only to scrape (no shadow, no jobs)
        self.observer = bool(meta.get("observe"))
        # per-client scheduler telemetry, served in the STATS snapshot
        self.exchanges = 0
        self.last_wait_s = 0.0

    @property
    def pool_grad(self) -> bool:
        """Whether GRAD frames to this client carry the pool prelude."""
        return self.proto >= protocol.POOL_REVISION

    @property
    def scope(self) -> str:
        """The canonical-shadow attach scope: the sync group, or a private
        per-identity scope for ungrouped clients (same-id reconnects land on
        the same shadow; anonymous connections get a fresh one)."""
        return self.group if self.group else f"client:{self.client_id}"

    def send(self, ftype: FrameType, payload: bytes,
             timeout: Optional[float]) -> int:
        with self.send_lock:
            return protocol.send_frame_deadline(self.conn, ftype, payload,
                                                timeout)

    def close(self) -> None:
        self.alive = False
        try:
            self.conn.close()
        except OSError:
            pass


class AscentPool:
    """Scheduler: N client connections -> bounded queue -> M ascent workers.

    Owns the jitted ascent function (shared across workers — jax compiled
    computations are thread-safe), the canonical shadows, the sync groups,
    and every counter the server reports. `attach(conn)` is the only entry
    point the accept loop needs; everything after that is per-client
    isolated.
    """

    def __init__(self, loss_fn: Callable, cfg: Optional[PoolConfig] = None,
                 *, device=None):
        self.cfg = cfg or PoolConfig()
        self._ascent = jax.jit(make_ascent_fn(loss_fn))
        self._norm = jax.jit(trees.global_norm)
        self._device = device
        self._stop = threading.Event()
        self._queue: "queue.Queue[_Work]" = queue.Queue(
            maxsize=max(1, self.cfg.queue_depth))
        self._lock = threading.Lock()          # registries + counters
        self._clients: set = set()
        self._shadows: dict = {}               # (scope, gen) -> SharedShadow
        self._groups: dict = {}                # name -> _Group
        self._comp_states: dict = {}           # stream key -> comp_state
        self._threads: list = []
        # counters (all mutated under self._lock or single-writer)
        self.connections = 0
        self.exchanges = 0
        self.resyncs_sent = 0
        self.detaches_sent = 0
        self.busy_rejections = 0
        self.auth_rejections = 0
        self.server_errors = 0
        self.dropped_clients = 0
        self.orphaned_jobs = 0
        self._workers = [threading.Thread(target=self._worker_loop,
                                          name=f"ascent-worker-{i}",
                                          daemon=True)
                         for i in range(max(1, self.cfg.workers))]
        for w in self._workers:
            w.start()

    # --- registries --------------------------------------------------------

    def _shadow_for(self, scope: str, gen: int) -> SharedShadow:
        with self._lock:
            key = (scope, int(gen))
            shadow = self._shadows.get(key)
            if shadow is None:
                shadow = self._shadows[key] = SharedShadow(
                    self.cfg.shadow_history)
                # retire shadows of older generations in this scope: a gen
                # bump (executor reset) invalidates their epoch for good
                for old in [k for k in self._shadows
                            if k[0] == scope and k[1] < int(gen)]:
                    del self._shadows[old]
            return shadow

    def _group_for(self, name: str) -> _Group:
        with self._lock:
            grp = self._groups.get(name)
            if grp is None:
                grp = self._groups[name] = _Group(self.cfg.smooth_beta,
                                                  self.cfg.group_cache)
            return grp

    def stats(self) -> dict:
        """Counter snapshot (also printed as the exit stats line)."""
        with self._lock:
            shadow_installs = sum(s.installs for s in self._shadows.values())
            shadow_skips = sum(s.skips for s in self._shadows.values())
            deltas_applied = sum(s.deltas_applied
                                 for s in self._shadows.values())
            delta_replays = sum(s.replays for s in self._shadows.values())
            group_hits = sum(g.hits for g in self._groups.values())
            group_computes = sum(g.computes for g in self._groups.values())
            return {
                "connections": self.connections,
                "clients": len(self._clients),
                "exchanges": self.exchanges,
                "busy_rejections": self.busy_rejections,
                "auth_rejections": self.auth_rejections,
                "resyncs_sent": self.resyncs_sent,
                "detaches_sent": self.detaches_sent,
                "shadow_installs": shadow_installs,
                "shadow_skips": shadow_skips,
                "deltas_applied": deltas_applied,
                "delta_replays": delta_replays,
                "shadows": len(self._shadows),
                "group_hits": group_hits,
                "group_computes": group_computes,
                "server_errors": self.server_errors,
                "dropped_clients": self.dropped_clients,
                "orphaned_jobs": self.orphaned_jobs,
            }

    def stats_snapshot(self) -> dict:
        """The full STATS-frame snapshot: `stats()` counters plus scheduler
        capacity and the per-client / per-shadow detail sections a fleet
        observer joins against its own jsonl traces (client uids match the
        `client_id` metric). Observer connections are excluded from the
        detail — a scraper must not see itself as a training client."""
        snap = self.stats()
        snap["workers"] = len(self._workers)
        snap["queue_capacity"] = self._queue.maxsize
        snap["queue_depth"] = self._queue.qsize()
        with self._lock:
            snap["clients_detail"] = [
                {"uid": client_uid(c.client_id),
                 "group_uid": client_uid(c.group) if c.group else 0,
                 "exchanges": c.exchanges,
                 "last_wait_s": c.last_wait_s}
                for c in sorted(self._clients, key=lambda c: c.client_id)
                if not c.observer]
            snap["shadows_detail"] = [
                {"scope_uid": client_uid(scope), "gen": gen,
                 "sync": shadow.sync, "seq": shadow.seq,
                 "replays": shadow.replays}
                for (scope, gen), shadow in sorted(self._shadows.items())]
        return snap

    # --- accept-side -------------------------------------------------------

    def attach(self, conn) -> threading.Thread:
        """Hand one accepted socket to its own handler thread."""
        with self._lock:
            self.connections += 1
        t = threading.Thread(target=self._serve_client, args=(conn,),
                             name="ascent-client", daemon=True)
        with self._lock:
            self._threads.append(t)
            self._threads = [x for x in self._threads if x.is_alive()][-64:]
        t.start()
        return t

    def _serve_client(self, conn) -> None:
        client: Optional[_ClientConn] = None
        try:
            ftype, payload, _ = protocol.recv_frame(
                conn, stop=self._stop, timeout=self.cfg.hello_timeout_s)
            if ftype != FrameType.HELLO:
                raise ProtocolError(f"expected HELLO, got {ftype.name}")
            compressor, hello = protocol.decode_hello(payload)
            if self.cfg.auth_token and \
                    hello.get("token") != self.cfg.auth_token:
                with self._lock:
                    self.auth_rejections += 1
                protocol.send_frame_deadline(
                    conn, FrameType.ERROR,
                    b"auth-rejected: bad or missing token",
                    self.cfg.send_timeout_s)
                return
            client = _ClientConn(conn, compressor, hello)
            if self.cfg.legacy_hello:
                # a revision-1 server never sends the pool GRAD prelude, no
                # matter what revision the client declared
                client.proto = 0
            with self._lock:
                self._clients.add(client)
            if self.cfg.legacy_hello:
                ack = protocol.encode_hello(compressor, proto=None)
            elif client.observer:
                # stats scrapers get no canonical shadow: they never send
                # jobs, and creating one would pin an empty (scope, gen)
                # entry in the registry the STATS reply then reports
                ack = protocol.encode_hello(
                    compressor, proto=protocol.PROTO_REVISION,
                    extra={"pool_workers": len(self._workers),
                           "queue_depth": self._queue.maxsize,
                           "shadow_sync": 0})
            else:
                shadow = self._shadow_for(client.scope, client.generation)
                ack = protocol.encode_hello(
                    compressor, proto=protocol.PROTO_REVISION,
                    extra={"pool_workers": len(self._workers),
                           "queue_depth": self._queue.maxsize,
                           "shadow_sync": shadow.sync})
            client.send(FrameType.HELLO_ACK, ack, self.cfg.send_timeout_s)
            self._client_loop(client)
        except (ConnectionError, ProtocolError, OSError, TimeoutError):
            pass            # client went away / spoke garbage / idled out
        except Exception as e:  # noqa: BLE001 — one bad connection must
            # never take down the pool; log and move on
            print(f"ascent-pool: connection failed: "
                  f"{type(e).__name__}: {e}", flush=True)
        finally:
            if client is not None:
                with self._lock:
                    self._clients.discard(client)
                    if not self._stop.is_set():
                        self.dropped_clients += 1
                client.close()
            else:
                try:
                    conn.close()
                except OSError:
                    pass

    def _client_loop(self, client: _ClientConn) -> None:
        while not self._stop.is_set():
            try:
                ftype, payload, _ = protocol.recv_frame(
                    client.conn, stop=self._stop,
                    timeout=self.cfg.idle_timeout_s)
            except ConnectionAbortedError:
                return       # pool stop while waiting for the next job
            if ftype == FrameType.JOB:
                try:
                    gen, step, params, batch, rng = \
                        protocol.decode_job(payload)
                except Exception as e:
                    raise ProtocolError(
                        f"malformed JOB payload ({type(e).__name__}: {e})"
                    ) from e
            elif ftype == FrameType.JOB_DELTA and not self.cfg.legacy_hello:
                try:
                    (sync, seq, gen, step, kind, params, batch, rng,
                     sections) = protocol.decode_job_v2(payload)
                except ProtocolError:
                    raise
                except Exception as e:
                    raise ProtocolError(
                        f"malformed JOB_DELTA payload "
                        f"({type(e).__name__}: {e})") from e
                shadow = self._shadow_for(client.scope, gen)
                if kind == "snapshot":
                    if sync:          # sync == 0: stateless, no stream
                        shadow.take_snapshot(params, sync)
                    # compute from the frame's own params either way
                else:
                    verdict = shadow.take_delta(kind, sections, sync, seq)
                    if verdict[0] == "resync":
                        with self._lock:
                            self.resyncs_sent += 1
                        client.send(FrameType.RESYNC,
                                    protocol.encode_resync(verdict[1],
                                                           shadow.sync),
                                    self.cfg.send_timeout_s)
                        continue
                    if verdict[0] == "detach":
                        with self._lock:
                            self.detaches_sent += 1
                        client.send(FrameType.DETACH,
                                    protocol.encode_resync(verdict[2],
                                                           verdict[1]),
                                    self.cfg.send_timeout_s)
                        continue
                    params = verdict[1]       # "apply" or "replay"
            elif ftype == FrameType.STATS and not self.cfg.legacy_hello:
                # revision-4 scrape: reply with the fixed-layout snapshot
                # and wait for the next request on the same socket
                client.send(FrameType.STATS,
                            protocol.encode_stats(self.stats_snapshot()),
                            self.cfg.send_timeout_s)
                continue
            else:
                raise ProtocolError(f"expected JOB, got {ftype.name}")
            # admission AFTER the shadow work: a BUSY rejection loses the
            # compute, never the delta-stream alignment
            depth = self._queue.qsize()
            work = _Work(client=client, gen=gen, step=step, params=params,
                         batch=batch, rng=rng, enq_t=time.monotonic(),
                         depth=depth)
            try:
                self._queue.put_nowait(work)
            except queue.Full:
                with self._lock:
                    self.busy_rejections += 1
                client.send(FrameType.BUSY,
                            protocol.encode_busy(depth, gen, step),
                            self.cfg.send_timeout_s)

    # --- worker-side -------------------------------------------------------

    def _compute(self, client: _ClientConn, work: _Work) -> tuple:
        """-> (leaves, norm, compute_time_s) for one job, group-aware."""
        if client.group:
            grp = self._group_for(client.group)
            with grp.lock:
                key = (work.gen, work.step)
                hit = grp.cache.get(key)
                if hit is not None:
                    grp.hits += 1
                    return hit
                t0 = time.perf_counter()
                g, norm, _wire, grp.comp_state = ascent_exchange(
                    self._ascent, self._norm, client.compressor,
                    grp.comp_state, work.params, work.batch,
                    np.asarray(work.rng), device=self._device,
                    delay_s=self.cfg.delay_s)
                leaves = [np.asarray(x, dtype=np.float32)
                          for x in jax.tree.leaves(g)]
                beta = grp.beta
                if grp.smoothed is not None and 0.0 < beta < 1.0 and \
                        len(grp.smoothed) == len(leaves) and \
                        all(o.shape == n.shape
                            for o, n in zip(grp.smoothed, leaves)):
                    leaves = [np.asarray(beta * o + (1.0 - beta) * n,
                                         dtype=np.float32)
                              for o, n in zip(grp.smoothed, leaves)]
                    norm = float(np.sqrt(sum(
                        float(np.sum(np.square(l, dtype=np.float64)))
                        for l in leaves)))
                grp.smoothed = leaves
                grp.computes += 1
                entry = (leaves, float(norm), time.perf_counter() - t0)
                grp.cache[key] = entry
                while len(grp.cache) > grp.cache_size:
                    grp.cache.popitem(last=False)
                return entry
        # ungrouped: a private error-feedback stream per client identity,
        # the exact single-client math (lockstep parity depends on it)
        key = client.client_id
        with self._lock:
            comp_state = self._comp_states.get(key)
        t0 = time.perf_counter()
        g, norm, _wire, comp_state = ascent_exchange(
            self._ascent, self._norm, client.compressor, comp_state,
            work.params, work.batch, np.asarray(work.rng),
            device=self._device, delay_s=self.cfg.delay_s)
        with self._lock:
            self._comp_states[key] = comp_state
        return (jax.tree.leaves(g), float(norm), time.perf_counter() - t0)

    def _worker_loop(self) -> None:
        while not self._stop.is_set():
            try:
                work = self._queue.get(timeout=0.2)
            except queue.Empty:
                continue
            client = work.client
            if not client.alive:
                with self._lock:
                    self.orphaned_jobs += 1
                continue
            wait_s = time.monotonic() - work.enq_t
            pool = (work.depth, wait_s) if client.pool_grad else None
            try:
                with current_tracker().span(
                        "pool_exchange",
                        lane=threading.current_thread().name,
                        client_id=client.client_id, group=client.group,
                        gen=work.gen, step=work.step,
                        wait_s=round(wait_s, 6)):
                    leaves, norm, dt = self._compute(client, work)
                payload = protocol.encode_grad(
                    work.gen, work.step, norm, dt, leaves,
                    client.compressor, pool=pool)
            except Exception as e:  # noqa: BLE001 — surfaced to the client,
                # never fatal to the worker slot
                with self._lock:
                    self.server_errors += 1
                try:
                    client.send(FrameType.ERROR,
                                f"{type(e).__name__}: {e}".encode(),
                                self.cfg.send_timeout_s)
                except (OSError, TimeoutError):
                    client.close()
                continue
            try:
                client.send(FrameType.GRAD, payload,
                            self.cfg.send_timeout_s)
                with self._lock:
                    self.exchanges += 1
                    client.exchanges += 1
                    client.last_wait_s = wait_s
            except (OSError, TimeoutError):
                client.close()   # the handler thread's recv will notice

    # --- shutdown ----------------------------------------------------------

    def close(self) -> None:
        self._stop.set()
        with self._lock:
            clients = list(self._clients)
        for client in clients:
            client.close()
        for w in self._workers:
            w.join(timeout=2.0)
