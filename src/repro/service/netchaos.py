"""netchaos — a frame-aware TCP chaos proxy for the ascent wire.

Sits between `RemoteAscentClient` and an ascent server/pool and attacks the
connection at the protocol-frame level, driven by a seeded, deterministic
`FaultSchedule`. Where `runtime.chaos` injects mesh-level events (device
loss, preemption), this module injects wire-level ones — the two harnesses
together cover both failure domains the ROADMAP cares about.

The proxy understands the `service.protocol` framing (16-byte header +
payload), so faults are *per frame kind*: a schedule can corrupt every GRAD,
stall the 3rd JOB_DELTA mid-frame, or blackhole the server->client direction
for 300ms — each of which lands on a different hardening path in the client
(crc drop, reconnect+retry, staleness ledger) and, above it, the
`runtime.health` degradation ladder.

Fault actions:

    corrupt     flip a payload byte and forward — the receiver's crc32 check
                rejects the frame (ProtocolError -> drop/reconnect path)
    truncate    forward the header + half the payload, then kill the link —
                the receiver sees EOF mid-frame (ConnectionError)
    drop        kill the link without forwarding the frame
    delay       sleep `delay_s`, then forward intact (transient: the
                exchange completes, late)
    stall       forward half the frame, sleep `delay_s`, forward the rest
                (transient mid-frame hiccup: completes)
    blackhole   swallow the frame and go silent for `duration_s`, then kill
                the link — the receiver gets neither data nor an error until
                the link dies (the failure mode only `LaneHealth.stalled()`
                or the eventual connection loss can catch)
    duplicate   forward the frame twice (sequence skew: exercises the
                server-side replay / RESYNC guards)

Rules fire deterministically (`nth`/`every`/`count`) or probabilistically
from a seeded `random.Random`, so a schedule replays identically run to run.

    schedule = parse_faults("corrupt:GRAD:nth=2,drop:JOB_DELTA:nth=5")
    with ChaosProxy(server.addr, schedule) as proxy:
        cfg = ExecutorConfig(ascent_addr=proxy.addr, ...)

The launcher exposes the same spec grammar as `--netchaos SPEC` for local
soak runs; `scripts/tier1.sh --netchaos` pins the whole harness in CI.
"""
from __future__ import annotations

import dataclasses
import random
import socket
import threading
import time
from typing import Optional

from repro.service import protocol
from repro.service.protocol import FRAME_HEADER_BYTES

FAULT_ACTIONS = ("corrupt", "truncate", "drop", "delay", "stall",
                 "blackhole", "duplicate")


@dataclasses.dataclass
class FaultRule:
    """One line of a schedule: which frames, which fault, when.

    A rule *matches* frames by kind and direction; among its matches it
    *fires* on the `nth` match (1-based), on every `every`-th match, with
    probability `prob`, or — when none of those are set — on every match.
    `count` bounds total firings (-1 = unlimited), so a hostile opening can
    give way to a clean tail the ladder can recover into.
    """

    action: str
    frame: str = "*"           # FrameType name ("GRAD", "JOB_DELTA", ...) | "*"
    direction: str = "*"       # "c2s" | "s2c" | "*"
    nth: int = 0               # fire on the nth matching frame (1-based)
    every: int = 0             # fire on every k-th matching frame
    prob: float = 0.0          # fire with this probability per match
    delay_s: float = 0.05      # delay / stall sleep
    duration_s: float = 0.25   # blackhole silence window
    count: int = -1            # max firings; -1 = unlimited
    seen: int = 0              # matching frames observed (mutable state)
    fired: int = 0             # times this rule fired (mutable state)

    def __post_init__(self):
        if self.action not in FAULT_ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r} "
                             f"(expected one of {FAULT_ACTIONS})")

    def matches(self, direction: str, frame_name: str) -> bool:
        return ((self.frame == "*" or self.frame == frame_name)
                and (self.direction == "*" or self.direction == direction))

    def should_fire(self, rng: random.Random) -> bool:
        """Call once per matching frame (advances the match counter)."""
        self.seen += 1
        if self.count >= 0 and self.fired >= self.count:
            return False
        if self.nth:
            fire = self.seen == self.nth
        elif self.every:
            fire = self.seen % self.every == 0
        elif self.prob:
            fire = rng.random() < self.prob
        else:
            fire = True
        if fire:
            self.fired += 1
        return fire


class FaultSchedule:
    """Ordered fault rules + one seeded RNG; first firing rule wins."""

    def __init__(self, rules: list, seed: int = 0):
        self.rules = list(rules)
        self.rng = random.Random(seed)
        self._lock = threading.Lock()

    def fire(self, direction: str, frame_name: str) -> Optional[FaultRule]:
        """The rule that fires for this frame, or None to pass it through.

        Locked: the proxy runs one pump thread per direction per link, and
        rule counters must advance deterministically across all of them.
        """
        with self._lock:
            for rule in self.rules:
                if rule.matches(direction, frame_name) \
                        and rule.should_fire(self.rng):
                    return rule
        return None

    def fired_actions(self) -> dict:
        with self._lock:
            out: dict = {}
            for r in self.rules:
                out[r.action] = out.get(r.action, 0) + r.fired
            return out


_FLOAT_KEYS = ("prob", "delay_s", "duration_s")
_INT_KEYS = ("nth", "every", "count")


def parse_faults(spec: str, seed: int = 0) -> FaultSchedule:
    """Parse a schedule spec: comma-separated `action[:FRAME][:key=val...]`.

        "corrupt:GRAD:nth=2,delay:*:prob=0.2:delay_s=0.1,drop:HELLO"

    Mirrors `runtime.chaos.parse_schedule`'s grammar style so the two
    launcher flags (`--chaos` / `--netchaos`) read the same way.
    """
    rules = []
    for part in filter(None, (p.strip() for p in spec.split(","))):
        fields = part.split(":")
        kwargs: dict = {"action": fields[0]}
        for field in fields[1:]:
            if "=" in field:
                key, _, val = field.partition("=")
                if key in _FLOAT_KEYS:
                    kwargs[key] = float(val)
                elif key in _INT_KEYS:
                    kwargs[key] = int(val)
                elif key == "direction":
                    kwargs[key] = val
                else:
                    raise ValueError(f"unknown fault option {key!r} in "
                                     f"{part!r}")
            else:
                kwargs["frame"] = field
        rules.append(FaultRule(**kwargs))
    return FaultSchedule(rules, seed=seed)


class _Link:
    """One proxied client connection: a socket pair + its two pump threads."""

    def __init__(self, client: socket.socket, server: socket.socket):
        self.client = client
        self.server = server
        self._dead = threading.Event()

    def kill(self) -> None:
        """Tear both sides down (idempotent); both pumps exit on the error."""
        self._dead.set()
        for sock in (self.client, self.server):
            try:
                sock.close()
            except OSError:
                pass

    @property
    def dead(self) -> bool:
        return self._dead.is_set()


class ChaosProxy:
    """Frame-aware TCP proxy applying a `FaultSchedule` to the ascent wire.

    Accepts any number of client connections (reconnects included — that is
    half the point), dials `upstream` per connection, and pumps whole
    protocol frames in both directions through the schedule. Counters
    (`connections`, `frames`, `faults`) are observable for assertions.
    """

    def __init__(self, upstream: str, schedule: Optional[FaultSchedule] = None,
                 *, bind: str = "127.0.0.1:0", dial_timeout_s: float = 10.0):
        self.upstream = upstream
        self.schedule = schedule or FaultSchedule([])
        self.dial_timeout_s = dial_timeout_s
        self._listener, self.addr = protocol.bind_listener(bind, backlog=16)
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self._links: list = []
        self.connections = 0            # accepted client connections
        self.frames: dict = {}          # (direction, frame name) -> forwarded
        self.faults: list = []          # (direction, frame name, action) log
        self._accept_thread = threading.Thread(target=self._accept_loop,
                                               daemon=True)
        self._accept_thread.start()

    # --- plumbing --------------------------------------------------------------
    def _accept_loop(self) -> None:
        self._listener.settimeout(0.2)
        while not self._stop.is_set():
            try:
                client, _ = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break       # listener closed under us (close())
            with self._lock:
                self.connections += 1
            try:
                server = protocol.connect(self.upstream,
                                          timeout=self.dial_timeout_s)
            except OSError:
                try:
                    client.close()   # upstream gone: the client sees a drop
                except OSError:
                    pass
                continue
            link = _Link(client, server)
            with self._lock:
                self._links.append(link)
            for direction, src, dst in (("c2s", client, server),
                                        ("s2c", server, client)):
                threading.Thread(target=self._pump,
                                 args=(link, direction, src, dst),
                                 daemon=True).start()

    def _pump(self, link: _Link, direction: str, src: socket.socket,
              dst: socket.socket) -> None:
        """Read whole frames off `src`, run them through the schedule, and
        forward to `dst`; any socket/frame error kills the link (both pumps
        exit — a half-proxied connection is worse than a dead one)."""
        try:
            while not self._stop.is_set() and not link.dead:
                header = protocol.recv_exact(src, FRAME_HEADER_BYTES,
                                             stop=self._stop)
                ftype, length, _crc = protocol.decode_frame_header(header)
                payload = protocol.recv_exact(src, length, stop=self._stop)
                rule = self.schedule.fire(direction, ftype.name)
                if rule is not None:
                    with self._lock:
                        self.faults.append((direction, ftype.name,
                                            rule.action))
                    if self._apply(rule, link, dst, header, payload):
                        continue        # frame handled (or link killed)
                # count before forwarding: once the peer can observe the
                # frame, the counter must already reflect it
                with self._lock:
                    key = (direction, ftype.name)
                    self.frames[key] = self.frames.get(key, 0) + 1
                dst.sendall(header + payload)
        except (OSError, ConnectionError, TimeoutError,
                protocol.ProtocolError):
            pass
        finally:
            link.kill()

    def _apply(self, rule: FaultRule, link: _Link, dst: socket.socket,
               header: bytes, payload: bytes) -> bool:
        """Apply one fault. Returns True when the frame was consumed here
        (forwarded mutated, duplicated, or the link was killed); False to
        fall through to the normal forward."""
        action = rule.action
        if action == "corrupt":
            if payload:
                bad = bytearray(payload)
                bad[len(bad) // 2] ^= 0xFF
                dst.sendall(header + bytes(bad))
            else:
                # no payload to flip: corrupt the header's crc field instead
                bad = bytearray(header)
                bad[-1] ^= 0xFF
                dst.sendall(bytes(bad))
            return True
        if action == "truncate":
            dst.sendall(header + payload[:len(payload) // 2])
            link.kill()
            return True
        if action == "drop":
            link.kill()
            return True
        if action == "delay":
            time.sleep(rule.delay_s)
            return False                # forward intact, late
        if action == "stall":
            cut = (FRAME_HEADER_BYTES + len(payload)) // 2
            buf = header + payload
            dst.sendall(buf[:cut])
            time.sleep(rule.delay_s)
            dst.sendall(buf[cut:])
            return True
        if action == "blackhole":
            # swallow the frame, hold the link open and silent, then kill it:
            # the receiver sees nothing at all until the connection dies
            self._stop.wait(rule.duration_s)
            link.kill()
            return True
        if action == "duplicate":
            dst.sendall(header + payload)
            dst.sendall(header + payload)
            return True
        raise AssertionError(f"unhandled fault action {action!r}")

    # --- observation / teardown ------------------------------------------------
    def fault_count(self) -> int:
        with self._lock:
            return len(self.faults)

    def kill_links(self) -> None:
        """Drop every live proxied connection (clients will reconnect)."""
        with self._lock:
            links = list(self._links)
        for link in links:
            link.kill()

    def close(self) -> None:
        if self._stop.is_set():
            return
        self._stop.set()
        try:
            self._listener.close()
        except OSError:
            pass
        self.kill_links()
        self._accept_thread.join(timeout=10.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
