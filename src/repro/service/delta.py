"""Delta-encoded JOB payloads: the params direction of the ascent exchange.

The remote lane's wire is dominated by the direction PR 3 never compressed:
every exchange ships a full fp32 params snapshot out, ~4x the compressed
GRAD frame coming back. Distributed-SAM results (LSAM, SAMPa) show the
ascent signal tolerates stale/approximate weights, so this is exactly where
lossy, delta-coded encoding belongs.

Both ends keep a generation-stamped fp32 *shadow* of the last-synced params,
bucketed by dtype (`utils.buckets.bucket_layout` — the same grouping the
fused weight-space path persists, so a bucket-resident executor's buffers
feed the encoder with zero gathers). Per exchange the client ships
`quantize(params - shadow + residual)` per bucket and BOTH ends advance
their shadow by the *quantized* value, so the server's reconstruction never
drifts from the client's; the quantization error stays client-side as an
error-feedback residual folded into the next delta. Any doubt about the
server's shadow (reconnect, respawn, RESYNC, checkpoint restore) is resolved
by falling back to a full-snapshot JOB that re-installs the shadow under a
fresh sync id.

`JobEncoder` (client) owns shadow/residual/sync state and the
delta+quantize pass — `kernels.ops.delta_amax`/`delta_encode_i8` (Pallas on
TPU, jnp oracle elsewhere) read the param and shadow buckets once per
exchange instead of walking the tree per leaf. `ShadowState` (server) is the
numpy receiving end: install from a snapshot, apply int8/topk bucket
sections, cut the params pytree back out of the shadow buffers.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.service import protocol
from repro.service.protocol import ProtocolError
from repro.utils import buckets

Pytree = Any


@dataclasses.dataclass
class EncodedJob:
    """One encoded exchange-out, ready for the client worker to frame.

    `params` (host tree) is present only for kind "snapshot"; `deltas` holds
    the per-bucket sections (`protocol.encode_job_v2` format) otherwise.
    `treedef` is the params tree structure the GRAD reply unflattens into.
    """
    kind: str
    sync: int
    seq: int
    gen: int
    step: int
    batch: Pytree
    rng: Any
    treedef: Any
    params: Pytree = None
    deltas: Optional[list] = None


def _caps_default() -> tuple[Optional[bool], set]:
    return None, set()


def _pow2_scale(amax: float) -> np.float32:
    """Smallest power-of-two >= amax/127 (1.0 for a zero delta).

    A power-of-two scale makes `q * scale` EXACT in fp32 (int8 mantissa,
    exponent shift only), so the shadow advance `s + q * scale` rounds
    identically whether it runs as the Pallas kernel, the jnp oracle, or the
    server's numpy apply — FMA contraction cannot skew the two shadows. The
    cost is <= 2x quantization granularity, absorbed by error feedback.
    """
    import math
    raw = amax / 127.0
    if not (raw > 0.0) or not math.isfinite(raw):
        return np.float32(1.0)
    return np.float32(2.0 ** math.ceil(math.log2(raw)))


class JobEncoder:
    """Client-side JOB encoding with shadow + error-feedback state.

    `caps_fn` reports the negotiated server capabilities
    `(v2_ok: True/False/None-unknown, supported encodings)`; the encoder
    degrades to full snapshots whenever delta encoding is not (yet) known to
    be safe. Thread-safe: `encode` runs on the executor thread at submit
    time (while the donated device params are still alive), `invalidate` /
    `resync_job` on the client worker thread.
    """

    def __init__(self, encoding: str = "none", *, topk_fraction: float = 0.01,
                 delta: bool = True,
                 caps_fn: Callable[[], tuple] = _caps_default,
                 impl: Optional[str] = None):
        if encoding not in protocol.JOB_ENCODINGS:
            raise ValueError(f"unknown job encoding {encoding!r}")
        self.encoding = encoding
        self.topk_fraction = topk_fraction
        self.delta = delta
        self._caps_fn = caps_fn
        self._impl = impl
        self._lock = threading.Lock()
        self._shadow: Optional[list] = None   # fp32 jax buffers, per bucket
        self._err: Optional[list] = None      # fp32 residual, congruent
        self._layout = None
        self._leaf_dtypes: Optional[list] = None
        self._sync = 0          # monotonically increasing install id
        self._seq = 0           # delta counter within the current sync
        self._sync_floor = 0    # DETACH fast-forward: next install id must
        #                         exceed the pool's canonical shadow sync
        # telemetry
        self.snapshot_jobs = 0
        self.delta_jobs = 0
        self.resyncs = 0
        self.encode_failures = 0
        self.last_encode_error = ""

    # --- state management ------------------------------------------------------
    def invalidate(self) -> None:
        """Drop the shadow: the next job is a full snapshot under a new sync
        id. Called on connection drops (the server's per-connection shadow is
        gone), on RESYNC, and on executor reset (checkpoint restore)."""
        with self._lock:
            self._shadow = self._err = None
            self._layout = self._leaf_dtypes = None

    def fast_forward(self, sync: int) -> None:
        """Raise the floor for the next install id past the pool's canonical
        shadow sync (a DETACH told us the shared shadow's epoch moved beyond
        our stream). Does NOT touch `_sync`/`_seq`, so an in-flight job can
        still be rebuilt by `resync_job` — only the snapshot that rebuild
        (or the next fresh snapshot) emits is stamped above `sync`, which is
        what lets it install over the canonical shadow instead of being
        skipped as stale."""
        with self._lock:
            self._sync_floor = max(self._sync_floor, int(sync))

    def _wants_delta(self) -> bool:
        if not self.delta or self.encoding == "none":
            return False
        v2, encodings = self._caps_fn()
        if v2 is False:
            return False          # revision-1 server: snapshots only
        return v2 is None or self.encoding in encodings

    # --- encoding --------------------------------------------------------------
    def encode(self, gen: int, params: Pytree, batch: Pytree, rng,
               step: int) -> EncodedJob:
        """Encode one job against the current shadow (delta when possible).

        `params` may be a device pytree, a `BucketedState`, or a host numpy
        tree (the calibration probe path); `batch`/`rng` are host values.
        """
        with self._lock:
            if self._wants_delta() and self._shadow is not None:
                try:
                    return self._encode_delta(gen, params, batch, rng, step)
                except Exception as e:  # noqa: BLE001 — layout drift or a
                    # kernel failure must degrade to a snapshot, not kill
                    # training; but a PERSISTENT failure silently re-running
                    # full fp32 snapshots would defeat --job-compress, so
                    # surface each distinct failure once
                    self._shadow = self._err = None
                    self.encode_failures += 1
                    msg = f"delta encode failed ({type(e).__name__}: {e}); " \
                          "sending full snapshot"
                    if msg != self.last_encode_error:
                        import sys
                        print(f"[job-encoder] {msg}", file=sys.stderr,
                              flush=True)
                    self.last_encode_error = msg
            return self._encode_snapshot(gen, params, batch, rng, step)

    def _encode_snapshot(self, gen, params, batch, rng, step) -> EncodedJob:
        host = buckets.host_portable(params)
        treedef = jax.tree.structure(host)
        sync = 0
        if self._wants_delta():
            layout = buckets.bucket_layout(host)
            bufs, _ = buckets.group_buffers(host, layout)
            self._shadow = [b.astype(jnp.float32) for b in bufs]
            self._err = [jnp.zeros_like(s) for s in self._shadow]
            self._layout = layout
            self._leaf_dtypes = [np.asarray(x).dtype
                                 for x in jax.tree.leaves(host)]
            self._sync = max(self._sync, self._sync_floor) + 1
            self._seq = 0
            sync = self._sync
        self.snapshot_jobs += 1
        return EncodedJob(kind="snapshot", sync=sync, seq=0, gen=gen,
                          step=step, batch=batch, rng=rng, treedef=treedef,
                          params=host)

    def _encode_delta(self, gen, params, batch, rng, step) -> EncodedJob:
        bufs, layout = buckets.group_buffers(params, self._layout)
        if (len(bufs) != len(self._shadow)
                or any(b.shape != s.shape for b, s in zip(bufs, self._shadow))):
            raise ValueError("params layout no longer matches the shadow")
        deltas = []
        new_shadow, new_err = [], []
        for p, s, e in zip(bufs, self._shadow, self._err):
            if self.encoding == "int8":
                amax = float(ops.delta_amax(p, s, e, impl=self._impl))
                scale = _pow2_scale(amax)
                q, s2, e2 = ops.delta_encode_i8(p, s, e, scale,
                                                impl=self._impl)
                deltas.append((float(scale), np.asarray(jax.device_get(q))))
            else:                                   # topk
                d = (p.astype(jnp.float32) - s + e)
                k = max(1, int(d.shape[0] * self.topk_fraction))
                _, idx = jax.lax.top_k(jnp.abs(d), k)
                val = d[idx]
                s2 = s.at[idx].add(val)
                e2 = d.at[idx].set(0.0)
                deltas.append((int(d.shape[0]),
                               np.asarray(jax.device_get(idx),
                                          dtype=np.uint32),
                               np.asarray(jax.device_get(val))))
            new_shadow.append(s2)
            new_err.append(e2)
        self._shadow, self._err = new_shadow, new_err
        self._seq += 1
        self.delta_jobs += 1
        return EncodedJob(kind=self.encoding, sync=self._sync, seq=self._seq,
                          gen=gen, step=step, batch=batch, rng=rng,
                          treedef=self._layout.treedef, deltas=deltas)

    # --- resync ----------------------------------------------------------------
    def resync_job(self, job: EncodedJob) -> Optional[EncodedJob]:
        """Rebuild `job` as a full-snapshot JOB of the *current shadow*.

        The shadow after encoding `job` is exactly the params the server
        would have reconstructed from it, so resending it as a snapshot
        yields a bitwise-identical exchange — the retry path after a dropped
        connection or a RESYNC. Returns None when the shadow has advanced
        past `job` (a newer job was encoded meanwhile): the exchange is
        unrecoverable and must be reported lost.
        """
        if job.kind == "snapshot":
            return job               # snapshots are naturally idempotent
        with self._lock:
            if (self._shadow is None or self._layout is None
                    or job.sync != self._sync or job.seq != self._seq):
                return None
            host_bufs = [np.asarray(jax.device_get(s)) for s in self._shadow]
            tree = buckets.host_buckets_to_tree(host_bufs, self._layout,
                                                self._leaf_dtypes)
            # a lossy leaf dtype (e.g. bf16) rounds the snapshot the server
            # will install; re-derive our shadow through the same cast and
            # fold the rounding into the residual so p - (shadow + err) is
            # preserved and both shadows stay bit-identical
            if any(g.dtype != "float32" for g in self._layout.groups):
                cast_bufs = buckets.host_tree_to_buckets(tree, self._layout)
                for gi, grp in enumerate(self._layout.groups):
                    if grp.dtype == "float32":
                        continue
                    s_new = jnp.asarray(cast_bufs[gi].astype(np.float32))
                    self._err[gi] = self._err[gi] + (self._shadow[gi] - s_new)
                    self._shadow[gi] = s_new
            self._sync = max(self._sync, self._sync_floor) + 1
            self._seq = 0
            self.resyncs += 1
            self.snapshot_jobs += 1
            return EncodedJob(kind="snapshot", sync=self._sync, seq=0,
                              gen=job.gen, step=job.step, batch=job.batch,
                              rng=job.rng, treedef=job.treedef, params=tree)


# ---------------------------------------------------------------------------
# Server side: the numpy shadow a connection reconstructs params from
# ---------------------------------------------------------------------------

class ShadowState:
    """Per-connection receiving end of the delta stream.

    Installed from a snapshot JOB (sync >= 1), advanced by int8/topk bucket
    sections with strict sync/seq checking — any mismatch means the ends
    have skewed and the caller must ask for a RESYNC. Deltas are fully
    decoded (and validated by `protocol.decode_job_v2`) before any buffer is
    touched, so a corrupted frame never half-applies.
    """

    def __init__(self):
        self.layout = None
        self.bufs: Optional[list] = None      # fp32 numpy, one per bucket
        self.leaf_dtypes: Optional[list] = None
        self.sync = 0
        self.seq = 0
        self.installs = 0
        self.deltas_applied = 0

    def install(self, params: Pytree, sync: int) -> None:
        self.layout = buckets.bucket_layout(params)
        self.leaf_dtypes = [np.asarray(x).dtype
                            for x in jax.tree.leaves(params)]
        # force writable owned buffers: decode_trees leaves are read-only
        # frombuffer views and a single-leaf bucket would alias them
        self.bufs = [np.array(b, dtype=np.float32, copy=True) for b in
                     buckets.host_tree_to_buckets(params, self.layout)]
        self.sync = int(sync)
        self.seq = 0
        self.installs += 1

    def can_apply(self, sync: int, seq: int) -> bool:
        return (self.bufs is not None and int(sync) == self.sync
                and int(seq) == self.seq + 1)

    def apply(self, kind: str, sections: list, sync: int, seq: int) -> None:
        """Advance the shadow by one fully-decoded delta."""
        if not self.can_apply(sync, seq):
            raise ProtocolError(
                f"delta (sync={sync}, seq={seq}) does not extend shadow "
                f"(sync={self.sync}, seq={self.seq})")
        if len(sections) != len(self.bufs):
            raise ProtocolError(
                f"delta has {len(sections)} buckets, shadow has "
                f"{len(self.bufs)}")
        # validate every section BEFORE touching any buffer, so a malformed
        # delta can never leave the shadow half-applied
        for i, (entry, buf) in enumerate(zip(sections, self.bufs)):
            if kind == "int8":
                _scale, q = entry
                if q.size != buf.size:
                    raise ProtocolError(
                        f"bucket {i}: int8 payload of {q.size} elements "
                        f"!= shadow size {buf.size}")
            else:                                   # topk
                size, idx, _val = entry
                if size != buf.size:
                    raise ProtocolError(
                        f"bucket {i}: topk section for {size} elements "
                        f"!= shadow size {buf.size}")
                if idx.size and int(idx.max()) >= buf.size:
                    raise ProtocolError(f"bucket {i}: topk index out of range")
        for entry, buf in zip(sections, self.bufs):
            if kind == "int8":
                scale, q = entry
                # f32 mul-then-add; the power-of-two scale makes the product
                # exact, matching the encoder kernel's advance bit for bit
                buf += q.astype(np.float32) * np.float32(scale)
            else:                                   # topk
                _size, idx, val = entry
                buf[idx] += val
        self.seq = int(seq)
        self.deltas_applied += 1

    def params(self) -> Pytree:
        """The params pytree the current shadow encodes (original dtypes)."""
        return buckets.host_buckets_to_tree(self.bufs, self.layout,
                                            self.leaf_dtypes)
