"""Wire protocol for the multi-host ascent service.

One frame format carries everything that crosses the process boundary:

    0   4  magic  b"ASAM"
    4   1  protocol version (PROTOCOL_VERSION)
    5   1  frame type (FrameType)
    6   2  flags (reserved, 0)
    8   4  payload length, big-endian u32
    12  4  crc32 of the payload
    16  N  payload

Frames out (client -> server): HELLO (compressor config handshake) and JOB
(a params snapshot + ascent batch + rng, i.e. the tuple the in-process lane
hands its worker thread). Frames back: HELLO_ACK, GRAD (the compressed ascent
gradient + its norm + staleness metadata), and ERROR (server-side exception
text). JOB/HELLO payloads are self-describing (JSON tree spec + raw leaf
bytes); GRAD payloads are fixed-layout binary so their length is exactly
modeled: `grad_frame_bytes(compressor, grad)` == len of the encoded frame,
with `Compressor.wire_bytes` as the payload term and the framing/shape
metadata accounted here (the frame-overhead model `Compressor.wire_bytes`
deliberately excludes).

The GRAD encodings mirror `core.ascent.Compressor`'s representations:

    none  fp32 leaves, raw                              4n bytes
    int8  per-leaf f64 scale + int8 payload             n + 8 bytes/leaf
    topk  per-leaf u32 k + k (u32 index, f32 value)     8k + 4 bytes/leaf

so re-encoding the *reconstruction* `Compressor.compress` produced is
lossless for "none"/"topk" and exact up to one rounding ulp for "int8"
(the reconstruction is scale * int8 already).
"""
from __future__ import annotations

import io
import json
import os
import socket
import stat
import struct
import threading
import time
import zlib
from enum import IntEnum
from typing import Any, Optional

import numpy as np

from repro.core.ascent import Compressor

Pytree = Any

MAGIC = b"ASAM"
PROTOCOL_VERSION = 1
FRAME_HEADER_BYTES = 16
#: fixed GRAD-payload prelude: gen u32 + job_step u32 + norm f64 +
#: compute_time f64 + kind u8 + n_leaves u32
GRAD_FIXED_BYTES = 4 + 4 + 8 + 8 + 1 + 4
_MAX_PAYLOAD = 1 << 31   # sanity bound against corrupt length fields

_KIND_CODES = {"none": 0, "int8": 1, "topk": 2}
_KIND_NAMES = {v: k for k, v in _KIND_CODES.items()}


class FrameType(IntEnum):
    HELLO = 1
    HELLO_ACK = 2
    JOB = 3
    GRAD = 4
    ERROR = 5


class ProtocolError(RuntimeError):
    """Malformed frame: bad magic/version/length/checksum/encoding."""


# ---------------------------------------------------------------------------
# Frame layer
# ---------------------------------------------------------------------------

def encode_frame(ftype: FrameType, payload: bytes) -> bytes:
    if len(payload) >= _MAX_PAYLOAD:
        raise ProtocolError(
            f"payload of {len(payload)} bytes exceeds the frame bound "
            f"({_MAX_PAYLOAD}); ship a compressed/sharded representation")
    header = MAGIC + struct.pack(">BBHII", PROTOCOL_VERSION, int(ftype), 0,
                                 len(payload), zlib.crc32(payload))
    return header + payload


def decode_frame_header(header: bytes) -> tuple[FrameType, int, int]:
    """-> (frame type, payload length, expected crc32). Raises ProtocolError."""
    if len(header) != FRAME_HEADER_BYTES or header[:4] != MAGIC:
        raise ProtocolError(f"bad frame magic {header[:4]!r}")
    version, ftype, _flags, length, crc = struct.unpack(">BBHII", header[4:])
    if version != PROTOCOL_VERSION:
        raise ProtocolError(f"protocol version {version} != {PROTOCOL_VERSION}")
    if length > _MAX_PAYLOAD:
        raise ProtocolError(f"payload length {length} exceeds bound")
    try:
        ftype = FrameType(ftype)
    except ValueError:
        raise ProtocolError(f"unknown frame type {ftype}") from None
    return ftype, length, crc


def decode_frame(buf: bytes) -> tuple[FrameType, bytes]:
    """Decode one complete frame from `buf` (exact length)."""
    ftype, length, crc = decode_frame_header(buf[:FRAME_HEADER_BYTES])
    payload = buf[FRAME_HEADER_BYTES:]
    if len(payload) != length:
        raise ProtocolError(f"payload length {len(payload)} != header {length}")
    if zlib.crc32(payload) != crc:
        raise ProtocolError("payload checksum mismatch")
    return ftype, payload


# ---------------------------------------------------------------------------
# Socket helpers (stop-aware blocking I/O)
# ---------------------------------------------------------------------------

def send_frame(sock: socket.socket, ftype: FrameType, payload: bytes) -> int:
    """Send one frame; returns total bytes on the wire.

    Sends in blocking mode: `recv_exact` leaves a short poll timeout on the
    socket, and since py3.5 that timeout is sendall's budget for the WHOLE
    frame — a multi-MB params frame over a real link needs longer. A send
    wedged on a dead peer is interrupted by close() on the other thread
    (sendall then raises OSError -> the caller's reconnect path).
    """
    frame = encode_frame(ftype, payload)
    sock.settimeout(None)
    sock.sendall(frame)
    return len(frame)


def recv_exact(sock: socket.socket, n: int, *,
               stop: Optional[threading.Event] = None,
               deadline: Optional[float] = None) -> bytes:
    """Read exactly n bytes; poll in short slices so `stop` can interrupt.

    Raises ConnectionError on EOF, TimeoutError past `deadline` (absolute
    time.monotonic()), and ConnectionAbortedError when `stop` is set.
    """
    buf = io.BytesIO()
    got = 0
    while got < n:
        if stop is not None and stop.is_set():
            raise ConnectionAbortedError("stopped while receiving")
        if deadline is not None and time.monotonic() > deadline:
            raise TimeoutError(f"timed out receiving frame ({got}/{n} bytes)")
        sock.settimeout(0.2)
        try:
            chunk = sock.recv(min(1 << 20, n - got))
        except socket.timeout:
            continue
        if not chunk:
            raise ConnectionError("peer closed the connection")
        buf.write(chunk)
        got += len(chunk)
    return buf.getvalue()


def recv_frame(sock: socket.socket, *,
               stop: Optional[threading.Event] = None,
               timeout: Optional[float] = None
               ) -> tuple[FrameType, bytes, int]:
    """Receive one frame -> (type, payload, total wire bytes)."""
    deadline = None if timeout is None else time.monotonic() + timeout
    header = recv_exact(sock, FRAME_HEADER_BYTES, stop=stop, deadline=deadline)
    ftype, length, crc = decode_frame_header(header)
    payload = recv_exact(sock, length, stop=stop, deadline=deadline)
    if zlib.crc32(payload) != crc:
        raise ProtocolError("payload checksum mismatch")
    return ftype, payload, FRAME_HEADER_BYTES + length


# ---------------------------------------------------------------------------
# Address plumbing ("host:port" TCP or "unix:/path" domain sockets)
# ---------------------------------------------------------------------------

def parse_addr(spec: str) -> tuple[str, Any]:
    """-> ("unix", path) | ("tcp", (host, port))."""
    if spec.startswith("unix:"):
        return "unix", spec[len("unix:"):]
    host, _, port = spec.rpartition(":")
    if not host:
        raise ValueError(f"address {spec!r} is not 'host:port' or 'unix:/path'")
    return "tcp", (host, int(port))


def bind_listener(spec: str, backlog: int = 1) -> tuple[socket.socket, str]:
    """Bind + listen on `spec`; returns (socket, resolved address string).

    TCP port 0 resolves to the kernel-assigned port, so callers can always
    advertise a connectable address.
    """
    family, target = parse_addr(spec)
    if family == "unix":
        try:
            if stat.S_ISSOCK(os.stat(target).st_mode):
                os.unlink(target)   # stale path from a previous server:
        except FileNotFoundError:   # bind would fail with EADDRINUSE
            pass
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.bind(target)
        sock.listen(backlog)
        return sock, f"unix:{target}"
    sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    sock.bind(target)
    sock.listen(backlog)
    host, port = sock.getsockname()[:2]
    return sock, f"{host}:{port}"


def connect(spec: str, timeout: float = 5.0) -> socket.socket:
    family, target = parse_addr(spec)
    if family == "unix":
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.settimeout(timeout)
        sock.connect(target)
        return sock
    return socket.create_connection(target, timeout=timeout)


# ---------------------------------------------------------------------------
# Pytree codec (JOB / HELLO payloads): JSON tree spec + raw leaf bytes
# ---------------------------------------------------------------------------

def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # registered extension dtypes (bfloat16, ...)
        return np.dtype(getattr(ml_dtypes, name))


def _pack_tree(tree: Pytree, leaves: list) -> Any:
    if tree is None:
        return None
    if isinstance(tree, dict):
        return {"t": "dict", "k": list(tree),
                "v": [_pack_tree(tree[k], leaves) for k in tree]}
    if isinstance(tree, (list, tuple)):
        return {"t": "tuple" if isinstance(tree, tuple) else "list",
                "v": [_pack_tree(x, leaves) for x in tree]}
    arr = np.ascontiguousarray(np.asarray(tree))
    leaves.append(arr)
    return {"t": "leaf", "dtype": arr.dtype.name, "shape": list(arr.shape)}


def _unpack_tree(spec: Any, leaves: "list[np.ndarray]", cursor: list) -> Pytree:
    if spec is None:
        return None
    t = spec["t"]
    if t == "dict":
        return {k: _unpack_tree(v, leaves, cursor)
                for k, v in zip(spec["k"], spec["v"])}
    if t in ("list", "tuple"):
        out = [_unpack_tree(v, leaves, cursor) for v in spec["v"]]
        return tuple(out) if t == "tuple" else out
    arr = leaves[cursor[0]]
    cursor[0] += 1
    return arr


def encode_trees(meta: dict, **trees: Pytree) -> bytes:
    """Pack host pytrees + JSON-able metadata into one payload.

    Layout: u32 json_len | json {meta, specs} | concatenated leaf bytes.
    """
    leaves: list[np.ndarray] = []
    specs = {name: _pack_tree(tree, leaves) for name, tree in trees.items()}
    header = json.dumps({"meta": meta, "trees": specs},
                        separators=(",", ":")).encode()
    out = io.BytesIO()
    out.write(struct.pack(">I", len(header)))
    out.write(header)
    for arr in leaves:
        out.write(arr.tobytes())
    return out.getvalue()


def decode_trees(payload: bytes) -> tuple[dict, dict]:
    """Inverse of encode_trees -> (meta, {name: pytree of np arrays})."""
    (json_len,) = struct.unpack_from(">I", payload, 0)
    header = json.loads(payload[4:4 + json_len].decode())
    off = 4 + json_len
    leaves: list[np.ndarray] = []

    def walk(spec):
        nonlocal off
        if spec is None:
            return
        if spec["t"] == "leaf":
            dtype = _np_dtype(spec["dtype"])
            n = int(np.prod(spec["shape"], dtype=np.int64)) if spec["shape"] else 1
            nbytes = n * dtype.itemsize
            if off + nbytes > len(payload):
                raise ProtocolError("leaf data overruns payload")
            arr = np.frombuffer(payload, dtype=dtype, count=n, offset=off)
            leaves.append(arr.reshape(spec["shape"]))
            off += nbytes
            return
        for v in spec["v"]:
            walk(v)

    for spec in header["trees"].values():
        walk(spec)
    cursor = [0]
    trees = {name: _unpack_tree(spec, leaves, cursor)
             for name, spec in header["trees"].items()}
    return header["meta"], trees


# ---------------------------------------------------------------------------
# JOB / HELLO payloads
# ---------------------------------------------------------------------------

def encode_hello(compressor: Compressor) -> bytes:
    return json.dumps({"version": PROTOCOL_VERSION, "kind": compressor.kind,
                       "topk_fraction": compressor.topk_fraction}).encode()


def decode_hello(payload: bytes) -> Compressor:
    meta = json.loads(payload.decode())
    if meta.get("version") != PROTOCOL_VERSION:
        raise ProtocolError(f"client protocol version {meta.get('version')} "
                            f"!= {PROTOCOL_VERSION}")
    return Compressor(kind=meta["kind"], topk_fraction=meta["topk_fraction"])


def encode_job(gen: int, step: int, params: Pytree, batch: Pytree,
               rng) -> bytes:
    return encode_trees({"gen": int(gen), "step": int(step)},
                        params=params, batch=batch, rng=rng)


def decode_job(payload: bytes) -> tuple[int, int, Pytree, Pytree, Any]:
    meta, trees = decode_trees(payload)
    return (int(meta["gen"]), int(meta["step"]),
            trees["params"], trees["batch"], trees["rng"])


# ---------------------------------------------------------------------------
# GRAD payload: fixed binary layout, exact length model
# ---------------------------------------------------------------------------

def _leaf_topk_k(n: int, fraction: float) -> int:
    return max(1, int(n * fraction))


def encode_grad(gen: int, job_step: int, norm: float, compute_time_s: float,
                leaves: "list[np.ndarray]", compressor: Compressor) -> bytes:
    """Pack the ascent gradient leaves (flatten order) for the wire.

    `leaves` is the output of `jax.tree.leaves` on the (already
    error-feedback-compressed, reconstructed) gradient; the receiver
    re-assembles with its own treedef (both ends hold the same params
    structure).
    """
    kind = compressor.kind
    out = io.BytesIO()
    out.write(struct.pack(">IIddBI", int(gen), int(job_step), float(norm),
                          float(compute_time_s), _KIND_CODES[kind],
                          len(leaves)))
    for leaf in leaves:
        arr = np.ascontiguousarray(np.asarray(leaf, dtype=np.float32))
        out.write(struct.pack(">B", arr.ndim))
        out.write(struct.pack(f">{arr.ndim}I", *arr.shape))
        if kind == "none":
            out.write(struct.pack(">B", 0))    # dtype code: fp32
            out.write(arr.tobytes())
        elif kind == "int8":
            amax = float(np.max(np.abs(arr))) if arr.size else 0.0
            scale = (amax / 127.0) or 1.0
            q = np.clip(np.round(arr / scale), -127, 127).astype(np.int8)
            out.write(struct.pack(">d", scale))
            out.write(q.tobytes())
        elif kind == "topk":
            flat = arr.reshape(-1)
            k = _leaf_topk_k(flat.size, compressor.topk_fraction)
            idx = np.argpartition(np.abs(flat), -k)[-k:].astype(np.uint32)
            out.write(struct.pack(">I", k))
            out.write(idx.tobytes())
            out.write(flat[idx].astype(np.float32).tobytes())
        else:
            raise ValueError(f"unknown compressor kind {kind!r}")
    return out.getvalue()


def decode_grad(payload: bytes
                ) -> tuple[int, int, float, float, "list[np.ndarray]"]:
    """-> (gen, job_step, norm, compute_time_s, fp32 leaves in flatten order)."""
    gen, job_step, norm, dt, kind_code, n_leaves = struct.unpack_from(
        ">IIddBI", payload, 0)
    kind = _KIND_NAMES.get(kind_code)
    if kind is None:
        raise ProtocolError(f"unknown grad kind code {kind_code}")
    off = GRAD_FIXED_BYTES
    leaves = []
    for _ in range(n_leaves):
        (ndim,) = struct.unpack_from(">B", payload, off)
        off += 1
        shape = struct.unpack_from(f">{ndim}I", payload, off)
        off += 4 * ndim
        n = int(np.prod(shape, dtype=np.int64)) if ndim else 1
        if kind == "none":
            off += 1                            # dtype code (fp32 only)
            arr = np.frombuffer(payload, np.float32, n, off).reshape(shape)
            off += 4 * n
        elif kind == "int8":
            (scale,) = struct.unpack_from(">d", payload, off)
            off += 8
            q = np.frombuffer(payload, np.int8, n, off).reshape(shape)
            off += n
            arr = q.astype(np.float32) * np.float32(scale)
        else:                                   # topk
            (k,) = struct.unpack_from(">I", payload, off)
            off += 4
            idx = np.frombuffer(payload, np.uint32, k, off)
            off += 4 * k
            val = np.frombuffer(payload, np.float32, k, off)
            off += 4 * k
            flat = np.zeros(n, np.float32)
            flat[idx] = val
            arr = flat.reshape(shape)
        leaves.append(np.ascontiguousarray(arr))
    if off != len(payload):
        raise ProtocolError(f"grad payload has {len(payload) - off} trailing bytes")
    return int(gen), int(job_step), float(norm), float(dt), leaves


def grad_frame_bytes(compressor: Compressor, grad: Pytree) -> int:
    """Exact length of the GRAD *frame* that would carry `grad`.

    `Compressor.wire_bytes` models the compressed payload only; this adds the
    framing the payload model deliberately excludes: the 16-byte frame header,
    the fixed GRAD prelude, and the per-leaf shape/structure metadata. A test
    asserts modeled == len(encode_frame(...)) for every compressor kind.
    """
    import jax
    leaves = [np.asarray(x) for x in jax.tree.leaves(grad)]
    structural = sum(1 + 4 * leaf.ndim for leaf in leaves)   # ndim + dims
    if compressor.kind == "none":
        structural += len(leaves)        # dtype code byte
    elif compressor.kind == "topk":
        structural += 4 * len(leaves)    # per-leaf k
    # int8's per-leaf 8-byte scale is already part of the payload model
    return (FRAME_HEADER_BYTES + GRAD_FIXED_BYTES + structural
            + compressor.wire_bytes(grad))
