"""Wire protocol for the multi-host ascent service.

One frame format carries everything that crosses the process boundary:

    0   4  magic  b"ASAM"
    4   1  protocol version (PROTOCOL_VERSION)
    5   1  frame type (FrameType)
    6   2  flags (reserved, 0)
    8   4  payload length, big-endian u32
    12  4  crc32 of the payload
    16  N  payload

Frames out (client -> server): HELLO (compressor config + capability
handshake), JOB (legacy v1: a params snapshot + ascent batch + rng, i.e. the
tuple the in-process lane hands its worker thread), and JOB_DELTA (v2: the
same job with the params direction either a generation-stamped full snapshot
or a delta-encoded update against the server's shadow of the last-synced
params). Frames back: HELLO_ACK, GRAD (the compressed ascent gradient + its
norm + staleness metadata), RESYNC (the server's shadow cannot take this
delta — resend as a full snapshot), and ERROR (server-side exception text).
JOB/HELLO payloads are self-describing (JSON tree spec + raw leaf bytes);
GRAD and the JOB_DELTA bucket sections are fixed-layout binary so their
length is exactly modeled: `grad_frame_bytes(compressor, grad)` /
`job_frame_bytes(encoding, params, batch, rng)` == len of the encoded frame,
with `Compressor.wire_bytes` as the GRAD payload term and the framing/shape
metadata accounted here (the frame-overhead model `Compressor.wire_bytes`
deliberately excludes).

The GRAD encodings mirror `core.ascent.Compressor`'s representations:

    none  fp32 leaves, raw                              4n bytes
    int8  per-leaf f64 scale + int8 payload             n + 8 bytes/leaf
    topk  per-leaf u32 k + k (u32 index, f32 value)     8k + 4 bytes/leaf

so re-encoding the *reconstruction* `Compressor.compress` produced is
lossless for "none"/"topk" and exact up to one rounding ulp for "int8"
(the reconstruction is scale * int8 already).

The JOB_DELTA bucket sections carry the params direction per *dtype bucket*
(`utils.buckets.bucket_layout` grouping — both ends derive the same layout
from the snapshot's tree spec), not per leaf:

    int8  u32 size + f32 scale + int8 payload           n + 8 bytes/bucket
    topk  u32 size + u32 k + k (u32 index, f32 value)   8k + 8 bytes/bucket

HELLO carries `proto`/`job_encodings` capability keys a v1 server ignores
(and whose absence from HELLO_ACK tells a v2 client to degrade to
full-snapshot v1 JOB frames — no codec error mid-fit against an old server).
"""
from __future__ import annotations

import io
import json
import os
import socket
import stat
import struct
import threading
import time
import zlib
from enum import IntEnum
from typing import Any, Optional

import numpy as np

from repro.core.ascent import Compressor

Pytree = Any

MAGIC = b"ASAM"
PROTOCOL_VERSION = 1
#: application-level protocol revision, negotiated in HELLO/HELLO_ACK (the
#: frame-header version stays at PROTOCOL_VERSION so v1 peers still parse
#: the handshake); revision 2 adds JOB_DELTA/RESYNC and the job encodings,
#: revision 3 adds the multi-client pool semantics: HELLO identity/auth
#: fields (client_id/group/generation/token), BUSY/DETACH frames, and the
#: pool-telemetry GRAD prelude extension (depth + queue-wait, emitted only
#: when BOTH ends negotiated revision >= 3); revision 4 adds the STATS
#: request/reply frame — a fleet observer scrapes the pool's scheduler
#: counters, per-client wait, and shadow generations over the same socket,
#: no stdout parsing
PROTO_REVISION = 4
#: the protocol revision that introduced the pool semantics above — feature
#: gates must compare against the feature's revision, never PROTO_REVISION
#: (which keeps moving), or a newer client mis-decodes against older servers
POOL_REVISION = 3
STATS_REVISION = 4
#: JOB-direction encodings a revision-2+ server accepts
JOB_ENCODINGS = ("none", "int8", "topk")
FRAME_HEADER_BYTES = 16
#: fixed GRAD-payload prelude: gen u32 + job_step u32 + norm f64 +
#: compute_time f64 + kind u8 + n_leaves u32
GRAD_FIXED_BYTES = 4 + 4 + 8 + 8 + 1 + 4
#: revision-3 pool-telemetry GRAD prelude extension: queue depth u32 +
#: queue-wait seconds f64 (present iff both peers negotiated proto >= 3)
GRAD_POOL_BYTES = 4 + 8
#: fixed JOB_DELTA-payload prelude: sync u32 + seq u32 + gen u32 + step u32 +
#: kind u8 + n_buckets u32
JOB_FIXED_BYTES = 4 + 4 + 4 + 4 + 1 + 4
_MAX_PAYLOAD = 1 << 31   # sanity bound against corrupt length fields

_KIND_CODES = {"none": 0, "int8": 1, "topk": 2}
_KIND_NAMES = {v: k for k, v in _KIND_CODES.items()}

#: JOB_DELTA params-direction kinds ("snapshot" installs/refreshes the shadow)
_JOB_KIND_CODES = {"snapshot": 0, "int8": 1, "topk": 2}
_JOB_KIND_NAMES = {v: k for k, v in _JOB_KIND_CODES.items()}


class FrameType(IntEnum):
    HELLO = 1
    HELLO_ACK = 2
    JOB = 3
    GRAD = 4
    ERROR = 5
    JOB_DELTA = 6
    RESYNC = 7
    #: revision 3 — pool queue full: the job was NOT admitted; the client
    #: should treat the exchange as failed (ledger fallback) and keep its
    #: delta stream as-is (the server applied any shadow delta before
    #: rejecting, so (sync, seq) stays aligned)
    BUSY = 8
    #: revision 3 — the canonical shadow's epoch moved past this client's
    #: delta stream (another client or a reconnect advanced it); payload is
    #: the resync codec carrying the canonical sync the client must
    #: fast-forward beyond before its next snapshot
    DETACH = 9
    #: revision 4 — pool statistics scrape. Request: empty payload
    #: (client -> server, in place of a JOB). Reply: the fixed-layout
    #: binary snapshot `encode_stats` renders (server -> client), exactly
    #: modeled by `stats_frame_bytes` like the JOB/GRAD frames.
    STATS = 10


class ProtocolError(RuntimeError):
    """Malformed frame: bad magic/version/length/checksum/encoding."""


# ---------------------------------------------------------------------------
# Frame layer
# ---------------------------------------------------------------------------

def encode_frame(ftype: FrameType, payload: bytes) -> bytes:
    if len(payload) >= _MAX_PAYLOAD:
        raise ProtocolError(
            f"payload of {len(payload)} bytes exceeds the frame bound "
            f"({_MAX_PAYLOAD}); ship a compressed/sharded representation")
    header = MAGIC + struct.pack(">BBHII", PROTOCOL_VERSION, int(ftype), 0,
                                 len(payload), zlib.crc32(payload))
    return header + payload


def decode_frame_header(header: bytes) -> tuple[FrameType, int, int]:
    """-> (frame type, payload length, expected crc32). Raises ProtocolError."""
    if len(header) != FRAME_HEADER_BYTES or header[:4] != MAGIC:
        raise ProtocolError(f"bad frame magic {header[:4]!r}")
    version, ftype, _flags, length, crc = struct.unpack(">BBHII", header[4:])
    if version != PROTOCOL_VERSION:
        raise ProtocolError(f"protocol version {version} != {PROTOCOL_VERSION}")
    if length > _MAX_PAYLOAD:
        raise ProtocolError(f"payload length {length} exceeds bound")
    try:
        ftype = FrameType(ftype)
    except ValueError:
        raise ProtocolError(f"unknown frame type {ftype}") from None
    return ftype, length, crc


def decode_frame(buf: bytes) -> tuple[FrameType, bytes]:
    """Decode one complete frame from `buf` (exact length)."""
    ftype, length, crc = decode_frame_header(buf[:FRAME_HEADER_BYTES])
    payload = buf[FRAME_HEADER_BYTES:]
    if len(payload) != length:
        raise ProtocolError(f"payload length {len(payload)} != header {length}")
    if zlib.crc32(payload) != crc:
        raise ProtocolError("payload checksum mismatch")
    return ftype, payload


# ---------------------------------------------------------------------------
# Socket helpers (stop-aware blocking I/O)
# ---------------------------------------------------------------------------

def send_frame(sock: socket.socket, ftype: FrameType, payload: bytes) -> int:
    """Send one frame; returns total bytes on the wire.

    Sends in blocking mode: `recv_exact` leaves a short poll timeout on the
    socket, and since py3.5 that timeout is sendall's budget for the WHOLE
    frame — a multi-MB params frame over a real link needs longer. A send
    wedged on a dead peer is interrupted by close() on the other thread
    (sendall then raises OSError -> the caller's reconnect path).
    """
    frame = encode_frame(ftype, payload)
    sock.settimeout(None)
    sock.sendall(frame)
    return len(frame)


def send_frame_deadline(sock: socket.socket, ftype: FrameType, payload: bytes,
                        timeout: Optional[float]) -> int:
    """`send_frame` with a whole-frame send budget (pool per-client deadline).

    A pool worker sending to a wedged client must not stall its slot forever;
    `timeout` bounds the sendall for the entire frame (None keeps the
    unbounded `send_frame` behavior).
    """
    if timeout is None:
        return send_frame(sock, ftype, payload)
    frame = encode_frame(ftype, payload)
    sock.settimeout(timeout)
    try:
        sock.sendall(frame)
    except socket.timeout as exc:
        raise TimeoutError(f"timed out sending {ftype.name} frame "
                           f"({len(frame)} bytes)") from exc
    return len(frame)


def recv_exact(sock: socket.socket, n: int, *,
               stop: Optional[threading.Event] = None,
               deadline: Optional[float] = None) -> bytes:
    """Read exactly n bytes; poll in short slices so `stop` can interrupt.

    Raises ConnectionError on EOF, TimeoutError past `deadline` (absolute
    time.monotonic()), and ConnectionAbortedError when `stop` is set.
    """
    buf = io.BytesIO()
    got = 0
    while got < n:
        if stop is not None and stop.is_set():
            raise ConnectionAbortedError("stopped while receiving")
        if deadline is not None and time.monotonic() > deadline:
            raise TimeoutError(f"timed out receiving frame ({got}/{n} bytes)")
        sock.settimeout(0.2)
        try:
            chunk = sock.recv(min(1 << 20, n - got))
        except socket.timeout:
            continue
        if not chunk:
            raise ConnectionError("peer closed the connection")
        buf.write(chunk)
        got += len(chunk)
    return buf.getvalue()


def recv_frame(sock: socket.socket, *,
               stop: Optional[threading.Event] = None,
               timeout: Optional[float] = None
               ) -> tuple[FrameType, bytes, int]:
    """Receive one frame -> (type, payload, total wire bytes)."""
    deadline = None if timeout is None else time.monotonic() + timeout
    header = recv_exact(sock, FRAME_HEADER_BYTES, stop=stop, deadline=deadline)
    ftype, length, crc = decode_frame_header(header)
    payload = recv_exact(sock, length, stop=stop, deadline=deadline)
    if zlib.crc32(payload) != crc:
        raise ProtocolError("payload checksum mismatch")
    return ftype, payload, FRAME_HEADER_BYTES + length


# ---------------------------------------------------------------------------
# Address plumbing ("host:port" TCP or "unix:/path" domain sockets)
# ---------------------------------------------------------------------------

def parse_addr(spec: str) -> tuple[str, Any]:
    """-> ("unix", path) | ("tcp", (host, port))."""
    if spec.startswith("unix:"):
        return "unix", spec[len("unix:"):]
    host, _, port = spec.rpartition(":")
    if not host:
        raise ValueError(f"address {spec!r} is not 'host:port' or 'unix:/path'")
    return "tcp", (host, int(port))


def bind_listener(spec: str, backlog: int = 1) -> tuple[socket.socket, str]:
    """Bind + listen on `spec`; returns (socket, resolved address string).

    TCP port 0 resolves to the kernel-assigned port, so callers can always
    advertise a connectable address.
    """
    family, target = parse_addr(spec)
    if family == "unix":
        try:
            if stat.S_ISSOCK(os.stat(target).st_mode):
                os.unlink(target)   # stale path from a previous server:
        except FileNotFoundError:   # bind would fail with EADDRINUSE
            pass
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.bind(target)
        sock.listen(backlog)
        return sock, f"unix:{target}"
    sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    sock.bind(target)
    sock.listen(backlog)
    host, port = sock.getsockname()[:2]
    return sock, f"{host}:{port}"


def connect(spec: str, timeout: float = 5.0) -> socket.socket:
    family, target = parse_addr(spec)
    if family == "unix":
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.settimeout(timeout)
        sock.connect(target)
        return sock
    return socket.create_connection(target, timeout=timeout)


# ---------------------------------------------------------------------------
# Pytree codec (JOB / HELLO payloads): JSON tree spec + raw leaf bytes
# ---------------------------------------------------------------------------

def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # registered extension dtypes (bfloat16, ...)
        return np.dtype(getattr(ml_dtypes, name))


def _pack_tree(tree: Pytree, leaves: list) -> Any:
    if tree is None:
        return None
    if isinstance(tree, dict):
        return {"t": "dict", "k": list(tree),
                "v": [_pack_tree(tree[k], leaves) for k in tree]}
    if isinstance(tree, (list, tuple)):
        return {"t": "tuple" if isinstance(tree, tuple) else "list",
                "v": [_pack_tree(x, leaves) for x in tree]}
    arr = np.ascontiguousarray(np.asarray(tree))
    leaves.append(arr)
    return {"t": "leaf", "dtype": arr.dtype.name, "shape": list(arr.shape)}


def _unpack_tree(spec: Any, leaves: "list[np.ndarray]", cursor: list) -> Pytree:
    if spec is None:
        return None
    t = spec["t"]
    if t == "dict":
        return {k: _unpack_tree(v, leaves, cursor)
                for k, v in zip(spec["k"], spec["v"])}
    if t in ("list", "tuple"):
        out = [_unpack_tree(v, leaves, cursor) for v in spec["v"]]
        return tuple(out) if t == "tuple" else out
    arr = leaves[cursor[0]]
    cursor[0] += 1
    return arr


def _trees_header(meta: dict, specs: dict) -> bytes:
    return json.dumps({"meta": meta, "trees": specs},
                      separators=(",", ":")).encode()


def encode_trees(meta: dict, **trees: Pytree) -> bytes:
    """Pack host pytrees + JSON-able metadata into one payload.

    Layout: u32 json_len | json {meta, specs} | concatenated leaf bytes.
    """
    leaves: list[np.ndarray] = []
    specs = {name: _pack_tree(tree, leaves) for name, tree in trees.items()}
    header = _trees_header(meta, specs)
    out = io.BytesIO()
    out.write(struct.pack(">I", len(header)))
    out.write(header)
    for arr in leaves:
        out.write(arr.tobytes())
    return out.getvalue()


def _spec_tree(tree: Pytree, nbytes: list) -> Any:
    """`_pack_tree`'s spec for the byte model: same JSON, no serialization.

    Works on anything with .shape/.dtype (numpy arrays, jax arrays,
    ShapeDtypeStructs) so wire budgets can be modeled from abstract params.
    """
    if tree is None:
        return None
    if isinstance(tree, dict):
        return {"t": "dict", "k": list(tree),
                "v": [_spec_tree(tree[k], nbytes) for k in tree]}
    if isinstance(tree, (list, tuple)):
        return {"t": "tuple" if isinstance(tree, tuple) else "list",
                "v": [_spec_tree(x, nbytes) for x in tree]}
    if not hasattr(tree, "shape"):
        tree = np.asarray(tree)
    dtype = np.dtype(tree.dtype)
    n = int(np.prod(tree.shape, dtype=np.int64)) if len(tree.shape) else 1
    nbytes.append(n * dtype.itemsize)
    return {"t": "leaf", "dtype": dtype.name, "shape": list(tree.shape)}


def trees_payload_bytes(meta: dict, **trees: Pytree) -> int:
    """Exact `len(encode_trees(meta, **trees))` without serializing.

    Exact only when `meta`'s JSON rendering is value-independent (the v2 JOB
    path keeps all varying integers in the fixed binary prelude for this
    reason); leaf shapes/dtypes may come from abstract arrays.
    """
    nbytes: list[int] = []
    specs = {name: _spec_tree(tree, nbytes) for name, tree in trees.items()}
    return 4 + len(_trees_header(meta, specs)) + sum(nbytes)


def decode_trees(payload: bytes) -> tuple[dict, dict]:
    """Inverse of encode_trees -> (meta, {name: pytree of np arrays})."""
    (json_len,) = struct.unpack_from(">I", payload, 0)
    header = json.loads(payload[4:4 + json_len].decode())
    off = 4 + json_len
    leaves: list[np.ndarray] = []

    def walk(spec):
        nonlocal off
        if spec is None:
            return
        if spec["t"] == "leaf":
            dtype = _np_dtype(spec["dtype"])
            n = int(np.prod(spec["shape"], dtype=np.int64)) if spec["shape"] else 1
            nbytes = n * dtype.itemsize
            if off + nbytes > len(payload):
                raise ProtocolError("leaf data overruns payload")
            arr = np.frombuffer(payload, dtype=dtype, count=n, offset=off)
            leaves.append(arr.reshape(spec["shape"]))
            off += nbytes
            return
        for v in spec["v"]:
            walk(v)

    for spec in header["trees"].values():
        walk(spec)
    cursor = [0]
    trees = {name: _unpack_tree(spec, leaves, cursor)
             for name, spec in header["trees"].items()}
    return header["meta"], trees


# ---------------------------------------------------------------------------
# JOB / HELLO payloads
# ---------------------------------------------------------------------------

def encode_hello(compressor: Compressor, *,
                 proto: Optional[int] = PROTO_REVISION,
                 job_encodings: Optional[tuple] = JOB_ENCODINGS,
                 client_id: str = "", group: str = "", generation: int = 0,
                 token: str = "", extra: Optional[dict] = None) -> bytes:
    """HELLO / HELLO_ACK payload.

    `version` stays the v1 key a revision-1 peer validates; `proto` and
    `job_encodings` are capability keys it ignores. `proto=None` renders the
    exact revision-1 payload (the degrade test's "old server" mode).

    Revision-3 identity/auth keys are added only when truthy, so a pool-aware
    client talking to a v2 server sends byte-compatible payloads when it has
    nothing to declare: `client_id` (stable identity across reconnects),
    `group` (ascent-sync group — same-group clients receive the group's
    shared smoothed gradient), `generation` (the model generation the client
    attaches its canonical shadow to), `token` (shared-secret auth for
    non-loopback listeners). `extra` merges server-side ACK info (pool
    capability report) without widening this signature per key.
    """
    meta = {"version": PROTOCOL_VERSION, "kind": compressor.kind,
            "topk_fraction": compressor.topk_fraction}
    if proto is not None:
        meta["proto"] = int(proto)
        meta["job_encodings"] = list(job_encodings or ())
    if client_id:
        meta["client_id"] = str(client_id)
    if group:
        meta["group"] = str(group)
    if generation:
        meta["generation"] = int(generation)
    if token:
        meta["token"] = str(token)
    if extra:
        meta.update(extra)
    return json.dumps(meta).encode()


def decode_hello(payload: bytes) -> tuple[Compressor, dict]:
    """-> (gradient-direction Compressor, full handshake meta).

    `meta.get("proto")` is None for a revision-1 peer — the signal to stay on
    full-snapshot v1 JOB frames.
    """
    meta = json.loads(payload.decode())
    if meta.get("version") != PROTOCOL_VERSION:
        raise ProtocolError(f"client protocol version {meta.get('version')} "
                            f"!= {PROTOCOL_VERSION}")
    return Compressor(kind=meta["kind"],
                      topk_fraction=meta["topk_fraction"]), meta


def encode_job(gen: int, step: int, params: Pytree, batch: Pytree,
               rng) -> bytes:
    """Legacy (revision-1) JOB payload: full snapshot, JSON meta."""
    return encode_trees({"gen": int(gen), "step": int(step)},
                        params=params, batch=batch, rng=rng)


def decode_job(payload: bytes) -> tuple[int, int, Pytree, Pytree, Any]:
    meta, trees = decode_trees(payload)
    return (int(meta["gen"]), int(meta["step"]),
            trees["params"], trees["batch"], trees["rng"])


# ---------------------------------------------------------------------------
# JOB_DELTA payload (v2 jobs): fixed prelude + aux trees + bucket sections
#
#   sync u32 | seq u32 | gen u32 | step u32 | kind u8 | n_buckets u32
#   aux_len u32 | encode_trees({}, [params,] batch, rng)
#   per bucket:  int8: size u32 | scale f32 | int8[size]
#                topk: size u32 | k u32 | u32 idx[k] | f32 val[k]
#
# kind "snapshot" ships the full params tree inside the aux (self-describing
# — it is what defines the bucket layout on both ends) with n_buckets == 0;
# sync == 0 marks a *stateless* snapshot (no delta stream will follow, the
# server need not keep a shadow). All varying integers live in the fixed
# prelude so `job_frame_bytes` is exact.
# ---------------------------------------------------------------------------

def encode_job_v2(sync: int, seq: int, gen: int, step: int, batch: Pytree,
                  rng, *, params: Pytree = None, kind: str = "snapshot",
                  deltas: Optional[list] = None) -> bytes:
    """v2 job payload. `deltas` per bucket: (scale, q int8) for "int8",
    (idx u32, val f32) for "topk"; `params` only for kind "snapshot"."""
    deltas = deltas or []
    if kind == "snapshot":
        aux = encode_trees({}, params=params, batch=batch, rng=rng)
    else:
        aux = encode_trees({}, batch=batch, rng=rng)
    out = io.BytesIO()
    out.write(struct.pack(">IIIIBI", int(sync), int(seq), int(gen), int(step),
                          _JOB_KIND_CODES[kind], len(deltas)))
    out.write(struct.pack(">I", len(aux)))
    out.write(aux)
    for entry in deltas:
        if kind == "int8":
            scale, q = entry
            q = np.ascontiguousarray(np.asarray(q, dtype=np.int8))
            out.write(struct.pack(">If", q.size, float(scale)))
            out.write(q.tobytes())
        elif kind == "topk":
            size, idx, val = entry
            idx = np.ascontiguousarray(np.asarray(idx, dtype=np.uint32))
            val = np.ascontiguousarray(np.asarray(val, dtype=np.float32))
            out.write(struct.pack(">II", int(size), idx.size))
            out.write(idx.tobytes())
            out.write(val.tobytes())
        else:
            raise ValueError(f"kind {kind!r} carries no bucket sections")
    return out.getvalue()


def decode_job_v2(payload: bytes):
    """-> (sync, seq, gen, step, kind, params-or-None, batch, rng, buckets).

    `buckets` mirrors encode_job_v2's `deltas`. Raises ProtocolError on any
    structural damage, before the caller touches its shadow.
    """
    if len(payload) < JOB_FIXED_BYTES + 4:
        raise ProtocolError("JOB_DELTA payload shorter than its prelude")
    sync, seq, gen, step, kind_code, n_buckets = struct.unpack_from(
        ">IIIIBI", payload, 0)
    kind = _JOB_KIND_NAMES.get(kind_code)
    if kind is None:
        raise ProtocolError(f"unknown job kind code {kind_code}")
    (aux_len,) = struct.unpack_from(">I", payload, JOB_FIXED_BYTES)
    off = JOB_FIXED_BYTES + 4
    if off + aux_len > len(payload):
        raise ProtocolError("JOB_DELTA aux overruns payload")
    meta, trees = decode_trees(payload[off:off + aux_len])
    off += aux_len
    buckets = []
    for _ in range(n_buckets):
        if kind == "int8":
            if off + 8 > len(payload):
                raise ProtocolError("JOB_DELTA bucket header overruns payload")
            size, scale = struct.unpack_from(">If", payload, off)
            off += 8
            if off + size > len(payload):
                raise ProtocolError("JOB_DELTA int8 bucket overruns payload")
            q = np.frombuffer(payload, np.int8, size, off)
            off += size
            buckets.append((float(scale), q))
        elif kind == "topk":
            if off + 8 > len(payload):
                raise ProtocolError("JOB_DELTA bucket header overruns payload")
            size, k = struct.unpack_from(">II", payload, off)
            off += 8
            if off + 8 * k > len(payload):
                raise ProtocolError("JOB_DELTA topk bucket overruns payload")
            idx = np.frombuffer(payload, np.uint32, k, off)
            off += 4 * k
            val = np.frombuffer(payload, np.float32, k, off)
            off += 4 * k
            buckets.append((int(size), idx, val))
        else:
            raise ProtocolError("snapshot job carries bucket sections")
    if off != len(payload):
        raise ProtocolError(
            f"JOB_DELTA payload has {len(payload) - off} trailing bytes")
    return (int(sync), int(seq), int(gen), int(step), kind,
            trees.get("params"), trees["batch"], trees["rng"], buckets)


def encode_resync(reason: str, sync: int = 0) -> bytes:
    return json.dumps({"reason": reason, "sync": int(sync)}).encode()


def decode_resync(payload: bytes) -> dict:
    try:
        return json.loads(payload.decode())
    except Exception:  # diagnostics only — never fail the resync itself
        return {"reason": payload.decode(errors="replace"), "sync": 0}


def encode_busy(depth: int, gen: int = 0, step: int = 0) -> bytes:
    """BUSY payload: the pool queue depth that rejected this exchange, plus
    the (gen, step) of the rejected job so the client can fail the right
    pending exchange."""
    return json.dumps({"depth": int(depth), "gen": int(gen),
                       "step": int(step)}).encode()


def decode_busy(payload: bytes) -> dict:
    try:
        return json.loads(payload.decode())
    except Exception:  # diagnostics only
        return {"depth": 0, "gen": 0, "step": 0}


# ---------------------------------------------------------------------------
# GRAD payload: fixed binary layout, exact length model
# ---------------------------------------------------------------------------

def _leaf_topk_k(n: int, fraction: float) -> int:
    return max(1, int(n * fraction))


def encode_grad(gen: int, job_step: int, norm: float, compute_time_s: float,
                leaves: "list[np.ndarray]", compressor: Compressor, *,
                pool: Optional[tuple] = None) -> bytes:
    """Pack the ascent gradient leaves (flatten order) for the wire.

    `leaves` is the output of `jax.tree.leaves` on the (already
    error-feedback-compressed, reconstructed) gradient; the receiver
    re-assembles with its own treedef (both ends hold the same params
    structure).

    `pool=(depth, wait_s)` appends the revision-3 pool-telemetry prelude
    extension (GRAD_POOL_BYTES) — only emit it to a peer whose HELLO declared
    proto >= 3, and decode with `decode_grad(..., pool=True)`; a v2 peer
    parsing the extended payload would see trailing bytes.
    """
    kind = compressor.kind
    out = io.BytesIO()
    out.write(struct.pack(">IIddBI", int(gen), int(job_step), float(norm),
                          float(compute_time_s), _KIND_CODES[kind],
                          len(leaves)))
    if pool is not None:
        depth, wait_s = pool
        out.write(struct.pack(">Id", int(depth), float(wait_s)))
    for leaf in leaves:
        arr = np.ascontiguousarray(np.asarray(leaf, dtype=np.float32))
        out.write(struct.pack(">B", arr.ndim))
        out.write(struct.pack(f">{arr.ndim}I", *arr.shape))
        if kind == "none":
            out.write(struct.pack(">B", 0))    # dtype code: fp32
            out.write(arr.tobytes())
        elif kind == "int8":
            amax = float(np.max(np.abs(arr))) if arr.size else 0.0
            scale = (amax / 127.0) or 1.0
            q = np.clip(np.round(arr / scale), -127, 127).astype(np.int8)
            out.write(struct.pack(">d", scale))
            out.write(q.tobytes())
        elif kind == "topk":
            flat = arr.reshape(-1)
            k = _leaf_topk_k(flat.size, compressor.topk_fraction)
            idx = np.argpartition(np.abs(flat), -k)[-k:].astype(np.uint32)
            out.write(struct.pack(">I", k))
            out.write(idx.tobytes())
            out.write(flat[idx].astype(np.float32).tobytes())
        else:
            raise ValueError(f"unknown compressor kind {kind!r}")
    return out.getvalue()


def decode_grad(payload: bytes, *, pool: bool = False
                ) -> tuple[int, int, float, float, "list[np.ndarray]", dict]:
    """-> (gen, job_step, norm, compute_time_s, fp32 leaves, pool_meta).

    `pool=True` parses the revision-3 pool-telemetry prelude extension into
    `pool_meta` ({"pool_depth", "pool_wait_s"}); with `pool=False` (a v2
    GRAD) `pool_meta` is empty. The flag is the HELLO/HELLO_ACK-negotiated
    capability — payloads are not self-describing here so the exact byte
    model stays exact.
    """
    gen, job_step, norm, dt, kind_code, n_leaves = struct.unpack_from(
        ">IIddBI", payload, 0)
    kind = _KIND_NAMES.get(kind_code)
    if kind is None:
        raise ProtocolError(f"unknown grad kind code {kind_code}")
    off = GRAD_FIXED_BYTES
    pool_meta: dict = {}
    if pool:
        depth, wait_s = struct.unpack_from(">Id", payload, off)
        off += GRAD_POOL_BYTES
        pool_meta = {"pool_depth": int(depth), "pool_wait_s": float(wait_s)}
    leaves = []
    for _ in range(n_leaves):
        (ndim,) = struct.unpack_from(">B", payload, off)
        off += 1
        shape = struct.unpack_from(f">{ndim}I", payload, off)
        off += 4 * ndim
        n = int(np.prod(shape, dtype=np.int64)) if ndim else 1
        if kind == "none":
            off += 1                            # dtype code (fp32 only)
            arr = np.frombuffer(payload, np.float32, n, off).reshape(shape)
            off += 4 * n
        elif kind == "int8":
            (scale,) = struct.unpack_from(">d", payload, off)
            off += 8
            q = np.frombuffer(payload, np.int8, n, off).reshape(shape)
            off += n
            arr = q.astype(np.float32) * np.float32(scale)
        else:                                   # topk
            (k,) = struct.unpack_from(">I", payload, off)
            off += 4
            idx = np.frombuffer(payload, np.uint32, k, off)
            off += 4 * k
            val = np.frombuffer(payload, np.float32, k, off)
            off += 4 * k
            flat = np.zeros(n, np.float32)
            flat[idx] = val
            arr = flat.reshape(shape)
        leaves.append(np.ascontiguousarray(arr))
    if off != len(payload):
        raise ProtocolError(f"grad payload has {len(payload) - off} trailing bytes")
    return int(gen), int(job_step), float(norm), float(dt), leaves, pool_meta


def grad_frame_bytes(compressor: Compressor, grad: Pytree, *,
                     pool: bool = False) -> int:
    """Exact length of the GRAD *frame* that would carry `grad`.

    `Compressor.wire_bytes` models the compressed payload only; this adds the
    framing the payload model deliberately excludes: the 16-byte frame header,
    the fixed GRAD prelude (plus the revision-3 pool-telemetry extension when
    `pool=True` — a proto>=3 pair always carries it), and the per-leaf
    shape/structure metadata. A test asserts modeled ==
    len(encode_frame(...)) for every compressor kind.
    """
    import jax
    leaves = [np.asarray(x) for x in jax.tree.leaves(grad)]
    structural = sum(1 + 4 * leaf.ndim for leaf in leaves)   # ndim + dims
    if compressor.kind == "none":
        structural += len(leaves)        # dtype code byte
    elif compressor.kind == "topk":
        structural += 4 * len(leaves)    # per-leaf k
    # int8's per-leaf 8-byte scale is already part of the payload model
    return (FRAME_HEADER_BYTES + GRAD_FIXED_BYTES
            + (GRAD_POOL_BYTES if pool else 0) + structural
            + compressor.wire_bytes(grad))


# ---------------------------------------------------------------------------
# JOB frame: exact length model (v2 jobs), layered like grad_frame_bytes
# ---------------------------------------------------------------------------

def _bucket_sizes(params: Pytree) -> list[int]:
    """Element count per dtype bucket, via the canonical layout grouping."""
    from repro.utils.buckets import bucket_layout
    return [g.size for g in bucket_layout(params).groups]


def job_frame_breakdown(encoding: str, params: Pytree, batch: Pytree, rng, *,
                        delta: bool = True,
                        topk_fraction: float = 0.01) -> dict:
    """Exact v2 JOB *frame* length model, split by wire direction content.

    Returns {"frame": total frame bytes, "aux": the params-free cost every
    job form pays (frame header, fixed prelude, batch + rng payload and
    their tree-spec JSON), "params": frame - aux, i.e. every byte the params
    direction adds — raw fp32 leaves plus their tree-spec JSON for a
    snapshot, the delta bucket sections for int8/topk}. `params`/`batch`/
    `rng` may be abstract (ShapeDtypeStructs) — wire budgets for pod-scale
    models are modeled without materializing them. Exact because every
    run-varying integer (sync/seq/gen/step) lives in the fixed-width binary
    prelude; a test asserts modeled == len(encode_frame(...)) per encoding.
    """
    common = (FRAME_HEADER_BYTES + JOB_FIXED_BYTES + 4
              + trees_payload_bytes({}, batch=batch, rng=rng))
    snapshot = (encoding == "none") or not delta
    if snapshot:
        frame = (FRAME_HEADER_BYTES + JOB_FIXED_BYTES + 4
                 + trees_payload_bytes({}, params=params, batch=batch,
                                       rng=rng))
        return {"frame": frame, "params": frame - common, "aux": common}
    sizes = _bucket_sizes(params)
    if encoding == "int8":
        section = sum(8 + n for n in sizes)
    elif encoding == "topk":
        section = sum(8 + 8 * max(1, int(n * topk_fraction)) for n in sizes)
    else:
        raise ValueError(f"unknown job encoding {encoding!r}")
    return {"frame": common + section, "params": section, "aux": common}


def job_frame_bytes(encoding: str, params: Pytree, batch: Pytree, rng, *,
                    delta: bool = True, topk_fraction: float = 0.01) -> int:
    """Exact length of the v2 JOB frame carrying one exchange out.

    `encoding` "none" (or `delta=False`) models the full-snapshot form;
    "int8"/"topk" model the delta-encoded bucket sections. The legacy
    (revision-1) JOB frame is not modeled — its JSON meta length varies with
    gen/step digits; v2 keeps those in the fixed prelude precisely so this
    model can be exact.
    """
    return job_frame_breakdown(encoding, params, batch, rng, delta=delta,
                               topk_fraction=topk_fraction)["frame"]


# ---------------------------------------------------------------------------
# STATS payload (revision 4): fixed binary layout, exact length model
#
#   ver u8 | workers u16 | queue_cap u16 | queue_depth u32
#   17 x u64 scheduler counters (STATS_COUNTER_KEYS order)
#   n_clients u32 | per client:  uid u32 | group_uid u32 | exchanges u32 |
#                                last_wait_s f64                   (20 bytes)
#   n_shadows u32 | per shadow:  scope_uid u32 | gen u32 | sync u32 |
#                                seq u32 | replays u32              (20 bytes)
#
# Everything run-varying is fixed-width binary, so `stats_frame_bytes` is
# exact the same way grad/job_frame_bytes are; the payload version byte lets
# the layout grow without another protocol revision.
# ---------------------------------------------------------------------------

#: the pool's scheduler counters, in `AscentPool.stats()` order — the wire
#: layout freezes this order, so it is append-only
STATS_COUNTER_KEYS = (
    "connections", "clients", "exchanges", "busy_rejections",
    "auth_rejections", "resyncs_sent", "detaches_sent", "shadow_installs",
    "shadow_skips", "deltas_applied", "delta_replays", "shadows",
    "group_hits", "group_computes", "server_errors", "dropped_clients",
    "orphaned_jobs",
)
STATS_PAYLOAD_VERSION = 1
#: ver + workers + queue_cap + queue_depth + counters + the two list lengths
STATS_FIXED_BYTES = (1 + 2 + 2 + 4) + 8 * len(STATS_COUNTER_KEYS) + 4 + 4
STATS_CLIENT_BYTES = 4 + 4 + 4 + 8
STATS_SHADOW_BYTES = 4 + 4 + 4 + 4 + 4


def encode_stats(snap: dict) -> bytes:
    """Pack a `AscentPool.stats_snapshot()` dict for the wire."""
    out = io.BytesIO()
    out.write(struct.pack(">BHHI", STATS_PAYLOAD_VERSION,
                          int(snap.get("workers", 0)),
                          int(snap.get("queue_capacity", 0)),
                          int(snap.get("queue_depth", 0))))
    for key in STATS_COUNTER_KEYS:
        out.write(struct.pack(">Q", int(snap.get(key, 0))))
    clients = snap.get("clients_detail", [])
    out.write(struct.pack(">I", len(clients)))
    for c in clients:
        out.write(struct.pack(">IIId", int(c["uid"]), int(c["group_uid"]),
                              int(c["exchanges"]), float(c["last_wait_s"])))
    shadows = snap.get("shadows_detail", [])
    out.write(struct.pack(">I", len(shadows)))
    for s in shadows:
        out.write(struct.pack(">IIIII", int(s["scope_uid"]), int(s["gen"]),
                              int(s["sync"]), int(s["seq"]),
                              int(s["replays"])))
    return out.getvalue()


def decode_stats(payload: bytes) -> dict:
    """Inverse of encode_stats -> the snapshot dict shape."""
    if len(payload) < STATS_FIXED_BYTES:
        raise ProtocolError("STATS payload shorter than its fixed layout")
    ver, workers, queue_cap, queue_depth = struct.unpack_from(">BHHI",
                                                              payload, 0)
    if ver != STATS_PAYLOAD_VERSION:
        raise ProtocolError(f"STATS payload version {ver} "
                            f"!= {STATS_PAYLOAD_VERSION}")
    off = 9
    snap: dict = {"workers": int(workers), "queue_capacity": int(queue_cap),
                  "queue_depth": int(queue_depth)}
    for key in STATS_COUNTER_KEYS:
        (snap[key],) = struct.unpack_from(">Q", payload, off)
        snap[key] = int(snap[key])
        off += 8
    (n_clients,) = struct.unpack_from(">I", payload, off)
    off += 4
    clients = []
    for _ in range(n_clients):
        if off + STATS_CLIENT_BYTES > len(payload):
            raise ProtocolError("STATS client entry overruns payload")
        uid, group_uid, exchanges, last_wait = struct.unpack_from(
            ">IIId", payload, off)
        off += STATS_CLIENT_BYTES
        clients.append({"uid": int(uid), "group_uid": int(group_uid),
                        "exchanges": int(exchanges),
                        "last_wait_s": float(last_wait)})
    snap["clients_detail"] = clients
    if off + 4 > len(payload):
        raise ProtocolError("STATS shadow count overruns payload")
    (n_shadows,) = struct.unpack_from(">I", payload, off)
    off += 4
    shadows = []
    for _ in range(n_shadows):
        if off + STATS_SHADOW_BYTES > len(payload):
            raise ProtocolError("STATS shadow entry overruns payload")
        scope_uid, gen, sync, seq, replays = struct.unpack_from(
            ">IIIII", payload, off)
        off += STATS_SHADOW_BYTES
        shadows.append({"scope_uid": int(scope_uid), "gen": int(gen),
                        "sync": int(sync), "seq": int(seq),
                        "replays": int(replays)})
    snap["shadows_detail"] = shadows
    if off != len(payload):
        raise ProtocolError(
            f"STATS payload has {len(payload) - off} trailing bytes")
    return snap


def stats_frame_bytes(n_clients: int, n_shadows: int) -> int:
    """Exact length of the STATS reply frame for a snapshot of this size.

    Layered like `grad_frame_bytes`/`job_frame_bytes`: frame header + fixed
    payload layout + fixed-width per-entry sections, so a test asserts
    modeled == len(encode_frame(...)) against a live scrape.
    """
    return (FRAME_HEADER_BYTES + STATS_FIXED_BYTES
            + STATS_CLIENT_BYTES * n_clients
            + STATS_SHADOW_BYTES * n_shadows)
