"""AscentServer — the slow-resource half of AsyncSAM as a standalone process.

    python -m repro.service.ascent_server --loss benchmarks.common:mlp_loss
    python -m repro.service.ascent_server --loss arch:olmo-1b:reduced \
        --bind 0.0.0.0:7431 --device cpu:0 --pool-workers 4 \
        --auth-token "$ASAM_TOKEN"

The server holds the loss function (resolved from an import path or an
architecture id), jits `core.make_ascent_fn`, and answers JOB/JOB_DELTA
frames with GRAD frames. The per-exchange math is exactly
`runtime.async_executor.ascent_exchange` — the same function the in-process
thread lane runs — so a loopback remote run reproduces the hetero lane's
hand-off values bit for bit (compressor "none"/"topk"; one rounding ulp for
"int8").

Since the multi-client pool PR the serve core is `service.pool.AscentPool`:
a threaded accept loop hands each connection to its own handler, jobs are
admitted into a bounded queue served by `--pool-workers` ascent workers, and
per-connection shadow state is replaced by one canonical generation-stamped
shadow per attach scope (see pool.py). Backpressure stays structural: each
client keeps a depth-1 job queue (the paper's depth-1 MPI exchange), and the
pool's bounded admission answers BUSY instead of buffering, so a saturated
helper shows up as staleness (tau growth) or ledger fallback on the clients,
never as unbounded memory.

On startup the server prints ``ascent-server listening on <addr>`` to
stdout; `spawn_server` uses that sentinel to implement the loopback mode
(server as a local subprocess) that `--serve-ascent` and the service tests
drive. On shutdown it prints one ``ascent-pool stats {...}`` JSON line — the
subprocess tests read it from the handle's tail to assert pool behavior
(canonical-shadow sharing, BUSY counts) without introspecting the process.
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import importlib
import json
import os
import signal
import socket
import subprocess
import sys
import threading
import time
from typing import Callable, Optional

import jax

from repro.service import protocol
from repro.service.pool import AscentPool, PoolConfig

_LISTEN_SENTINEL = "ascent-server listening on "
_STATS_SENTINEL = "ascent-pool stats "


def resolve_loss(spec: str) -> Callable:
    """Loss-function lookup: "module:attr" or "arch:NAME[:reduced]"."""
    if spec.startswith("arch:"):
        parts = spec.split(":")
        from repro.configs import get_config
        from repro.models import build_model
        cfg = get_config(parts[1], reduced="reduced" in parts[2:])
        return build_model(cfg).loss_fn
    mod, _, attr = spec.partition(":")
    if not mod or not attr:
        raise ValueError(f"loss spec {spec!r} is not 'module:attr' or "
                         "'arch:NAME[:reduced]'")
    return getattr(importlib.import_module(mod), attr)


def parse_device(spec: str) -> Optional[jax.Device]:
    """'cpu', 'cpu:1', 'tpu:0' ... -> the jax.Device (None for '')."""
    if not spec:
        return None
    platform, _, idx = spec.partition(":")
    return jax.devices(platform)[int(idx) if idx else 0]


class AscentServer:
    """Accept loop + AscentPool: serves N clients with M ascent workers."""

    def __init__(self, loss_fn: Callable, *, bind: str = "127.0.0.1:0",
                 device: Optional[jax.Device] = None, delay_s: float = 0.0,
                 legacy_hello: bool = False, pool_workers: int = 1,
                 queue_depth: int = 4, auth_token: str = "",
                 idle_timeout_s: float = 600.0, smooth_beta: float = 0.9,
                 shadow_history: int = 4):
        cfg = PoolConfig(workers=pool_workers, queue_depth=queue_depth,
                         auth_token=auth_token, idle_timeout_s=idle_timeout_s,
                         smooth_beta=smooth_beta,
                         shadow_history=shadow_history, delay_s=delay_s,
                         legacy_hello=legacy_hello)
        self.pool = AscentPool(loss_fn, cfg, device=device)
        self._bind_spec = bind
        self._listener: Optional[socket.socket] = None
        self.address: Optional[str] = None
        self._stop = threading.Event()

    # counter views (the pre-pool server kept these as plain attributes;
    # tests and telemetry read them by name)
    @property
    def exchanges(self) -> int:
        return self.pool.exchanges

    @property
    def connections(self) -> int:
        return self.pool.connections

    @property
    def resyncs_sent(self) -> int:
        return self.pool.resyncs_sent

    @property
    def shadow_installs(self) -> int:
        return self.pool.stats()["shadow_installs"]

    @property
    def deltas_applied(self) -> int:
        return self.pool.stats()["deltas_applied"]

    def stats(self) -> dict:
        return self.pool.stats()

    def start(self) -> str:
        """Bind + listen; returns the resolved address ("host:port"/"unix:...")."""
        if self._listener is None:
            self._listener, self.address = protocol.bind_listener(
                self._bind_spec, backlog=16)
        return self.address

    def serve_forever(self) -> None:
        self.start()
        while not self._stop.is_set():
            self._listener.settimeout(0.2)
            try:
                conn, _peer = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            self.pool.attach(conn)

    def serve_in_thread(self) -> threading.Thread:
        """Test hook: accept loop on a daemon thread (same-process loopback)."""
        self.start()
        t = threading.Thread(target=self.serve_forever, daemon=True)
        t.start()
        return t

    def close(self) -> None:
        self._stop.set()
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
        self._listener = None
        self.pool.close()
        if self.address and self.address.startswith("unix:"):
            try:
                os.unlink(self.address[len("unix:"):])
            except OSError:
                pass


# ---------------------------------------------------------------------------
# Loopback mode: the server as a local subprocess
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServerHandle:
    """A spawned ascent-server subprocess + its advertised address."""
    proc: subprocess.Popen
    addr: str
    loss_spec: str
    tail: "collections.deque[str]"   # last stdout/stderr lines (diagnostics)

    def alive(self) -> bool:
        return self.proc.poll() is None

    def kill(self, timeout: float = 10.0) -> None:
        if self.alive():
            self.proc.terminate()
            try:
                self.proc.wait(timeout=timeout)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait(timeout=timeout)

    def stats(self, timeout: float = 10.0) -> Optional[dict]:
        """The pool's exit stats line, parsed from the captured tail.

        Only meaningful after `kill()` (the server prints it on shutdown);
        waits up to `timeout` for the line to land in the tail."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            for line in list(self.tail):
                if line.startswith(_STATS_SENTINEL):
                    try:
                        return json.loads(line[len(_STATS_SENTINEL):])
                    except ValueError:
                        return None
            if not self.alive() and time.monotonic() + 0.5 > deadline:
                break
            time.sleep(0.1)
        return None


def spawn_server(loss_spec: str, *, bind: str = "127.0.0.1:0",
                 device: str = "", delay_s: float = 0.0,
                 startup_timeout_s: float = 120.0, pool_workers: int = 0,
                 queue_depth: int = 0, auth_token: str = "",
                 smooth_beta: Optional[float] = None) -> ServerHandle:
    """Start ``python -m repro.service.ascent_server`` and wait for its
    listening sentinel; returns a handle with the connectable address.

    A daemon thread keeps draining the child's stdout afterwards, so a chatty
    server can never block on a full pipe; the last lines are retained on the
    handle for post-mortems (including the shutdown stats line). Pool knobs
    at their zero/None defaults are left to the server's own defaults.
    """
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_root + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "repro.service.ascent_server",
           "--bind", bind, "--loss", loss_spec]
    if device:
        cmd += ["--device", device]
    if delay_s:
        cmd += ["--delay-s", str(delay_s)]
    if pool_workers:
        cmd += ["--pool-workers", str(pool_workers)]
    if queue_depth:
        cmd += ["--queue-depth", str(queue_depth)]
    if auth_token:
        cmd += ["--auth-token", auth_token]
    if smooth_beta is not None:
        cmd += ["--smooth-beta", str(smooth_beta)]
    proc = subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)
    tail: collections.deque = collections.deque(maxlen=50)
    addr_box: dict = {}
    sentinel = threading.Event()

    # the reader thread owns the pipe from the start: readline() blocks, so
    # waiting for the sentinel on this thread would defeat startup_timeout_s
    # against a server that wedges silently (e.g. during backend init)
    def _reader(stream):
        for line in stream:
            line = line.rstrip("\n")
            tail.append(line)
            if line.startswith(_LISTEN_SENTINEL) and not sentinel.is_set():
                addr_box["addr"] = line[len(_LISTEN_SENTINEL):].strip()
                sentinel.set()
        stream.close()

    reader = threading.Thread(target=_reader, args=(proc.stdout,), daemon=True)
    reader.start()
    deadline = time.monotonic() + startup_timeout_s
    while time.monotonic() < deadline and not sentinel.is_set():
        if proc.poll() is not None:
            reader.join(timeout=5.0)   # collect the crash output
            break
        sentinel.wait(0.2)
    if "addr" not in addr_box:
        proc.kill()
        raise RuntimeError(
            "ascent server failed to start "
            f"(exit={proc.poll()}):\n" + "\n".join(tail))
    return ServerHandle(proc=proc, addr=addr_box["addr"], loss_spec=loss_spec,
                        tail=tail)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="AsyncSAM ascent-gradient server (paper's slow resource)")
    ap.add_argument("--bind", default="127.0.0.1:0",
                    help="'host:port' (port 0 = kernel-assigned) or "
                         "'unix:/path/to.sock'")
    ap.add_argument("--loss", required=True,
                    help="loss spec: 'module:attr' or 'arch:NAME[:reduced]'")
    ap.add_argument("--device", default="",
                    help="jax device for the ascent compute, e.g. 'cpu:0'")
    ap.add_argument("--delay-s", type=float, default=0.0,
                    help="injected per-exchange delay (straggler emulation)")
    ap.add_argument("--pool-workers", type=int, default=1,
                    help="concurrent ascent workers serving the job queue")
    ap.add_argument("--queue-depth", type=int, default=4,
                    help="admission bound before clients get BUSY")
    ap.add_argument("--auth-token", default="",
                    help="shared secret clients must present in HELLO "
                         "(empty disables auth — loopback only)")
    ap.add_argument("--idle-timeout-s", type=float, default=600.0,
                    help="drop a client that sends no job for this long")
    ap.add_argument("--smooth-beta", type=float, default=0.9,
                    help="LSAM-style EMA coefficient for sync-group "
                         "gradients (0 disables smoothing)")
    ap.add_argument("--legacy-hello", action="store_true",
                    help="test hook: behave like a protocol-revision-1 "
                         "server (no JOB_DELTA support announced or accepted)")
    args = ap.parse_args(argv)

    server = AscentServer(resolve_loss(args.loss), bind=args.bind,
                          device=parse_device(args.device),
                          delay_s=args.delay_s,
                          legacy_hello=args.legacy_hello,
                          pool_workers=args.pool_workers,
                          queue_depth=args.queue_depth,
                          auth_token=args.auth_token,
                          idle_timeout_s=args.idle_timeout_s,
                          smooth_beta=args.smooth_beta)
    addr = server.start()
    print(f"{_LISTEN_SENTINEL}{addr}", flush=True)
    signal.signal(signal.SIGTERM, lambda *_: server.close())
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        server.close()
    finally:
        print(f"{_STATS_SENTINEL}{json.dumps(server.stats())}", flush=True)


if __name__ == "__main__":
    main()
