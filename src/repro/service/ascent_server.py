"""AscentServer — the slow-resource half of AsyncSAM as a standalone process.

    python -m repro.service.ascent_server --loss benchmarks.common:mlp_loss
    python -m repro.service.ascent_server --loss arch:olmo-1b:reduced \
        --bind 0.0.0.0:7431 --device cpu:0

The server holds the loss function (resolved from an import path or an
architecture id), jits `core.make_ascent_fn`, and answers JOB frames
(params snapshot + b'-sized batch + rng) with GRAD frames (compressed ascent
gradient + norm + staleness metadata). The per-exchange math is exactly
`runtime.async_executor.ascent_exchange` — the same function the in-process
thread lane runs — so a loopback remote run reproduces the hetero lane's
hand-off values bit for bit (compressor "none"/"topk"; one rounding ulp for
"int8").

Backpressure is structural: one connection is served at a time, one frame is
in flight per connection (the client keeps a depth-1 job queue, mirroring the
paper's depth-1 MPI exchange), so a slow server shows up as staleness (tau
growth) on the client, never as unbounded buffering.

On startup the server prints ``ascent-server listening on <addr>`` to stdout;
`spawn_server` uses that sentinel to implement the loopback mode (server as a
local subprocess) that `--serve-ascent` and the service tests drive.
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import importlib
import os
import signal
import socket
import subprocess
import sys
import threading
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.core import make_ascent_fn
from repro.runtime.async_executor import ascent_exchange
from repro.service import protocol
from repro.service.delta import ShadowState
from repro.service.protocol import FrameType, ProtocolError
from repro.utils import trees

_LISTEN_SENTINEL = "ascent-server listening on "


def resolve_loss(spec: str) -> Callable:
    """Loss-function lookup: "module:attr" or "arch:NAME[:reduced]"."""
    if spec.startswith("arch:"):
        parts = spec.split(":")
        from repro.configs import get_config
        from repro.models import build_model
        cfg = get_config(parts[1], reduced="reduced" in parts[2:])
        return build_model(cfg).loss_fn
    mod, _, attr = spec.partition(":")
    if not mod or not attr:
        raise ValueError(f"loss spec {spec!r} is not 'module:attr' or "
                         "'arch:NAME[:reduced]'")
    return getattr(importlib.import_module(mod), attr)


def parse_device(spec: str) -> Optional[jax.Device]:
    """'cpu', 'cpu:1', 'tpu:0' ... -> the jax.Device (None for '')."""
    if not spec:
        return None
    platform, _, idx = spec.partition(":")
    return jax.devices(platform)[int(idx) if idx else 0]


class AscentServer:
    """Serves ascent-gradient exchanges to one client at a time."""

    def __init__(self, loss_fn: Callable, *, bind: str = "127.0.0.1:0",
                 device: Optional[jax.Device] = None, delay_s: float = 0.0,
                 legacy_hello: bool = False):
        self._ascent = jax.jit(make_ascent_fn(loss_fn))
        self._norm = jax.jit(trees.global_norm)
        self._device = device
        self._delay_s = delay_s
        self._bind_spec = bind
        # test hook: behave like a revision-1 server (no capability keys in
        # the HELLO_ACK, JOB_DELTA frames rejected) so the client's degrade
        # path is testable without an old binary
        self._legacy_hello = legacy_hello
        self._listener: Optional[socket.socket] = None
        self.address: Optional[str] = None
        self._stop = threading.Event()
        self._conn: Optional[socket.socket] = None
        self.exchanges = 0
        self.connections = 0
        self.resyncs_sent = 0
        self.shadow_installs = 0
        self.deltas_applied = 0

    def start(self) -> str:
        """Bind + listen; returns the resolved address ("host:port"/"unix:...")."""
        if self._listener is None:
            self._listener, self.address = protocol.bind_listener(self._bind_spec)
        return self.address

    def serve_forever(self) -> None:
        self.start()
        while not self._stop.is_set():
            self._listener.settimeout(0.2)
            try:
                conn, _peer = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            self._conn = conn
            self.connections += 1
            try:
                self._handle(conn)
            except (ConnectionError, ProtocolError, OSError, TimeoutError):
                pass        # client went away / spoke garbage: next accept
            except Exception as e:  # noqa: BLE001 — one bad connection must
                # never take down a long-running helper; log and re-accept
                print(f"ascent-server: connection failed: "
                      f"{type(e).__name__}: {e}", flush=True)
            finally:
                try:
                    conn.close()
                except OSError:
                    pass
                self._conn = None

    def serve_in_thread(self) -> threading.Thread:
        """Test hook: accept loop on a daemon thread (same-process loopback)."""
        self.start()
        t = threading.Thread(target=self.serve_forever, daemon=True)
        t.start()
        return t

    def _handle(self, conn: socket.socket) -> None:
        ftype, payload, _ = protocol.recv_frame(conn, stop=self._stop,
                                                timeout=30.0)
        if ftype != FrameType.HELLO:
            raise ProtocolError(f"expected HELLO, got {ftype.name}")
        compressor, _hello = protocol.decode_hello(payload)
        protocol.send_frame(
            conn, FrameType.HELLO_ACK,
            protocol.encode_hello(
                compressor, proto=None if self._legacy_hello else
                protocol.PROTO_REVISION))
        # error-feedback residual and the params shadow are per-connection:
        # a reconnect starts the quantizer's memory fresh and requires a
        # full-snapshot JOB before any delta (the old stream's state
        # belonged to a connection that no longer exists)
        comp_state = None
        shadow = ShadowState()
        while not self._stop.is_set():
            try:
                ftype, payload, _ = protocol.recv_frame(conn, stop=self._stop)
            except ConnectionAbortedError:
                break       # stop was set while waiting for the next job
            if ftype == FrameType.JOB:
                try:
                    gen, step, params, batch, rng = \
                        protocol.decode_job(payload)
                except Exception as e:  # checksummed but malformed: this
                    raise ProtocolError(  # client is skewed — drop it
                        f"malformed JOB payload ({type(e).__name__}: {e})"
                    ) from e
            elif ftype == FrameType.JOB_DELTA and not self._legacy_hello:
                # decode + (for deltas) shadow-apply happen BEFORE any
                # compute; a corrupted frame raises here and drops the
                # connection with the shadow untouched
                try:
                    (sync, seq, gen, step, kind, params, batch, rng,
                     sections) = protocol.decode_job_v2(payload)
                except ProtocolError:
                    raise
                except Exception as e:
                    raise ProtocolError(
                        f"malformed JOB_DELTA payload "
                        f"({type(e).__name__}: {e})") from e
                if kind == "snapshot":
                    if sync:     # sync == 0: stateless, no delta stream
                        shadow.install(params, sync)
                        self.shadow_installs += 1
                else:
                    if not shadow.can_apply(sync, seq):
                        self.resyncs_sent += 1
                        protocol.send_frame(
                            conn, FrameType.RESYNC,
                            protocol.encode_resync(
                                f"shadow at (sync={shadow.sync}, "
                                f"seq={shadow.seq}) cannot take "
                                f"(sync={sync}, seq={seq})", shadow.sync))
                        continue
                    shadow.apply(kind, sections, sync, seq)
                    self.deltas_applied += 1
                    params = shadow.params()
            else:
                raise ProtocolError(f"expected JOB, got {ftype.name}")
            t0 = time.perf_counter()
            try:
                g, norm, _wire, comp_state = ascent_exchange(
                    self._ascent, self._norm, compressor, comp_state,
                    params, batch, np.asarray(rng),
                    device=self._device, delay_s=self._delay_s)
                grad_payload = protocol.encode_grad(
                    gen, step, norm, time.perf_counter() - t0,
                    jax.tree.leaves(g), compressor)
            except Exception as e:  # noqa: BLE001 — surfaced to the client
                protocol.send_frame(conn, FrameType.ERROR,
                                    f"{type(e).__name__}: {e}".encode())
                continue
            protocol.send_frame(conn, FrameType.GRAD, grad_payload)
            self.exchanges += 1

    def close(self) -> None:
        self._stop.set()
        for sock in (self._conn, self._listener):
            if sock is not None:
                try:
                    sock.close()
                except OSError:
                    pass
        self._listener = None
        if self.address and self.address.startswith("unix:"):
            try:
                os.unlink(self.address[len("unix:"):])
            except OSError:
                pass


# ---------------------------------------------------------------------------
# Loopback mode: the server as a local subprocess
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServerHandle:
    """A spawned ascent-server subprocess + its advertised address."""
    proc: subprocess.Popen
    addr: str
    loss_spec: str
    tail: "collections.deque[str]"   # last stdout/stderr lines (diagnostics)

    def alive(self) -> bool:
        return self.proc.poll() is None

    def kill(self, timeout: float = 10.0) -> None:
        if self.alive():
            self.proc.terminate()
            try:
                self.proc.wait(timeout=timeout)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait(timeout=timeout)


def spawn_server(loss_spec: str, *, bind: str = "127.0.0.1:0",
                 device: str = "", delay_s: float = 0.0,
                 startup_timeout_s: float = 120.0) -> ServerHandle:
    """Start ``python -m repro.service.ascent_server`` and wait for its
    listening sentinel; returns a handle with the connectable address.

    A daemon thread keeps draining the child's stdout afterwards, so a chatty
    server can never block on a full pipe; the last lines are retained on the
    handle for post-mortems.
    """
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_root + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "repro.service.ascent_server",
           "--bind", bind, "--loss", loss_spec]
    if device:
        cmd += ["--device", device]
    if delay_s:
        cmd += ["--delay-s", str(delay_s)]
    proc = subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)
    tail: collections.deque = collections.deque(maxlen=50)
    addr_box: dict = {}
    sentinel = threading.Event()

    # the reader thread owns the pipe from the start: readline() blocks, so
    # waiting for the sentinel on this thread would defeat startup_timeout_s
    # against a server that wedges silently (e.g. during backend init)
    def _reader(stream):
        for line in stream:
            line = line.rstrip("\n")
            tail.append(line)
            if line.startswith(_LISTEN_SENTINEL) and not sentinel.is_set():
                addr_box["addr"] = line[len(_LISTEN_SENTINEL):].strip()
                sentinel.set()
        stream.close()

    reader = threading.Thread(target=_reader, args=(proc.stdout,), daemon=True)
    reader.start()
    deadline = time.monotonic() + startup_timeout_s
    while time.monotonic() < deadline and not sentinel.is_set():
        if proc.poll() is not None:
            reader.join(timeout=5.0)   # collect the crash output
            break
        sentinel.wait(0.2)
    if "addr" not in addr_box:
        proc.kill()
        raise RuntimeError(
            "ascent server failed to start "
            f"(exit={proc.poll()}):\n" + "\n".join(tail))
    return ServerHandle(proc=proc, addr=addr_box["addr"], loss_spec=loss_spec,
                        tail=tail)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="AsyncSAM ascent-gradient server (paper's slow resource)")
    ap.add_argument("--bind", default="127.0.0.1:0",
                    help="'host:port' (port 0 = kernel-assigned) or "
                         "'unix:/path/to.sock'")
    ap.add_argument("--loss", required=True,
                    help="loss spec: 'module:attr' or 'arch:NAME[:reduced]'")
    ap.add_argument("--device", default="",
                    help="jax device for the ascent compute, e.g. 'cpu:0'")
    ap.add_argument("--delay-s", type=float, default=0.0,
                    help="injected per-exchange delay (straggler emulation)")
    ap.add_argument("--legacy-hello", action="store_true",
                    help="test hook: behave like a protocol-revision-1 "
                         "server (no JOB_DELTA support announced or accepted)")
    args = ap.parse_args(argv)

    server = AscentServer(resolve_loss(args.loss), bind=args.bind,
                          device=parse_device(args.device),
                          delay_s=args.delay_s,
                          legacy_hello=args.legacy_hello)
    addr = server.start()
    print(f"{_LISTEN_SENTINEL}{addr}", flush=True)
    signal.signal(signal.SIGTERM, lambda *_: server.close())
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        server.close()


if __name__ == "__main__":
    main()
