"""repro.service — the multi-host ascent lane (paper §3.3 across processes).

The heterogeneous executor's ascent lane, moved out of process: a standalone
`AscentServer` (``python -m repro.service.ascent_server``) holds the loss
function and computes ascent gradients on its local device; a non-blocking
`RemoteAscentClient` satisfies the same lane protocol as the in-process
thread lane (`runtime.async_executor.AscentLane`), streaming params/batch
frames out and compressed gradient frames back over TCP or Unix sockets.
`engine.RemoteExecutor` plugs the client into `Engine.fit` unchanged.

`protocol` owns the length-prefixed, versioned, checksummed frame format and
the exact wire-byte accounting in both directions: `grad_frame_bytes`
layered on `core.ascent.Compressor.wire_bytes` for the gradient coming
back, `job_frame_bytes` for the params direction going out — full fp32
snapshots, or the delta-encoded bucket sections `delta` implements
(client-side `JobEncoder` with error feedback, server-side `ShadowState`).

`pool` is the multi-client serve core (`AscentPool`): N concurrent client
connections admitted into a bounded work queue served by M ascent workers,
one canonical generation-stamped `SharedShadow` per attach scope instead of
per-connection shadow state, `global` ascent-sync groups handing all
same-group clients a consistent LSAM-smoothed gradient per (generation,
step), and BUSY/DETACH backpressure + shared-token auth for non-loopback
fleets.
"""
from repro.service.ascent_server import (  # noqa: F401
    AscentServer,
    ServerHandle,
    resolve_loss,
    spawn_server,
)
from repro.service.client import (  # noqa: F401
    RemoteAscentClient,
    fetch_pool_stats,
)
from repro.service.delta import JobEncoder, ShadowState  # noqa: F401
from repro.service.netchaos import (  # noqa: F401
    ChaosProxy,
    FaultRule,
    FaultSchedule,
    parse_faults,
)
from repro.service.pool import (  # noqa: F401
    AscentPool,
    PoolConfig,
    SharedShadow,
)
from repro.service.protocol import (  # noqa: F401
    FrameType,
    ProtocolError,
    decode_frame,
    decode_stats,
    encode_frame,
    encode_stats,
    grad_frame_bytes,
    job_frame_bytes,
    job_frame_breakdown,
    stats_frame_bytes,
)
