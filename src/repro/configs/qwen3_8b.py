"""qwen3-8b — 36L d4096 32H (GQA kv=8) ff12288 v151936, qk_norm
[hf:Qwen/Qwen3-8B; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=12288,
    vocab_size=151936, head_dim=128, act="silu", qk_norm=True, rope_theta=1e6,
)

REDUCED = ModelConfig(
    name="qwen3-8b-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16, act="silu", qk_norm=True,
    remat="none", compute_dtype="float32",
)
