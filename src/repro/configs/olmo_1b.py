"""olmo-1b — 16L d2048 16H (kv=16) ff8192 v50304, non-parametric LayerNorm,
tied embeddings [arXiv:2402.00838; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab_size=50304, act="silu", norm="nonparam_ln", tie_embeddings=True,
    # dots remat fits this model in HBM and removes the re-forward:
    # MFU-bound 0.49 -> 0.77 with AsyncSAM-k4 (EXPERIMENTS §Perf cell A)
    remat="dots",
)

REDUCED = ModelConfig(
    name="olmo-1b-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, act="silu", norm="nonparam_ln", tie_embeddings=True,
    remat="none", compute_dtype="float32",
)
