"""whisper-tiny — enc-dec 4L d384 6H ff1536 v51865, conv frontend stubbed
(precomputed frame embeddings) [arXiv:2212.04356; unverified]."""
from repro.models.config import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
    vocab_size=51865, norm="layernorm", act="gelu", mlp_gated=False,
    tie_embeddings=True,
    encdec=EncDecConfig(n_encoder_layers=4, enc_len_ratio=1.0),
)

REDUCED = ModelConfig(
    name="whisper-tiny-reduced", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, norm="layernorm", act="gelu", mlp_gated=False,
    tie_embeddings=True,
    encdec=EncDecConfig(n_encoder_layers=2, enc_len_ratio=1.0),
    remat="none", compute_dtype="float32",
)
