"""mixtral-8x7b — 32L d4096 32H (GQA kv=8) ff14336 v32000, 8 experts top-2, SWA
[arXiv:2401.04088; hf]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, head_dim=128, act="silu", rope_theta=1e6,
    sliding_window=4096, subquadratic=True,  # SWA bounds the decode cache
    moe=MoEConfig(n_experts=8, top_k=2, expert_d_ff=14336),
)

REDUCED = ModelConfig(
    name="mixtral-8x7b-reduced", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16, act="silu", sliding_window=8, subquadratic=True,
    moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=128),
    remat="none", compute_dtype="float32",
)
