"""gemma-2b — 18L d2048 8H MQA(kv=1) GeGLU ff16384 v256000, head_dim=256,
tied embeddings [arXiv:2403.08295; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
    vocab_size=256000, head_dim=256, act="gelu", tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="gemma-2b-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
    vocab_size=256, head_dim=32, act="gelu", tie_embeddings=True,
    remat="none", compute_dtype="float32",
)
