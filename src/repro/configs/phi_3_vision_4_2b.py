"""phi-3-vision-4.2b — 32L d3072 32H (kv=32) ff8192 v32064 backbone; CLIP
frontend stubbed (precomputed 576 patch embeddings @1024, learned projector)
[hf:microsoft/Phi-3-vision-128k-instruct; hf]."""
from repro.models.config import ModelConfig, VisionStubConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32064, act="silu",
    vision=VisionStubConfig(n_image_tokens=576, clip_dim=1024),
)

REDUCED = ModelConfig(
    name="phi-3-vision-4.2b-reduced", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, act="silu",
    vision=VisionStubConfig(n_image_tokens=8, clip_dim=32),
    remat="none", compute_dtype="float32",
)
