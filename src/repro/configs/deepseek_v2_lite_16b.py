"""deepseek-v2-lite-16b — 27L d2048 16H MLA(kv_lora=512) v102400, 64 routed
top-6 + 2 shared experts, first layer dense [arXiv:2405.04434; hf].

The assignment line lists both "64e top-6" and "160 routed"; HF's V2-Lite is
64 routed + 2 shared (160 is full V2) — we implement the Lite config
(DESIGN.md §4 notes the discrepancy)."""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=102400, act="silu",
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, expert_d_ff=1408, n_shared_experts=2,
                  first_dense_layers=1, dense_d_ff=10944),
)

REDUCED = ModelConfig(
    name="deepseek-v2-lite-16b-reduced", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
    vocab_size=256, act="silu",
    mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                  v_head_dim=16),
    moe=MoEConfig(n_experts=8, top_k=2, expert_d_ff=96, n_shared_experts=1,
                  first_dense_layers=1, dense_d_ff=192),
    remat="none", compute_dtype="float32",
)
