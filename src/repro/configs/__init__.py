"""Architecture config registry: --arch <id> resolution for every launcher."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

# assigned architecture ids -> module names
_ARCH_MODULES = {
    "mixtral-8x7b": "mixtral_8x7b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "zamba2-1.2b": "zamba2_1_2b",
    "gemma-2b": "gemma_2b",
    "qwen2.5-32b": "qwen2_5_32b",
    "qwen3-8b": "qwen3_8b",
    "olmo-1b": "olmo_1b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "rwkv6-7b": "rwkv6_7b",
    "whisper-tiny": "whisper_tiny",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    try:
        module_name = _ARCH_MODULES[arch]
    except KeyError:
        raise ValueError(f"unknown arch {arch!r}; available: {sorted(_ARCH_MODULES)}"
                         ) from None
    mod = importlib.import_module(f"repro.configs.{module_name}")
    return mod.REDUCED if reduced else mod.CONFIG
