"""zamba2-1.2b — 38 Mamba2 layers d2048 (ssm_state=64) + shared attention
block (32H kv=32, GLU ff8192) every 6 layers with per-invocation LoRA
[arXiv:2411.15242; hf]."""
from repro.models.config import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32000, act="gelu", subquadratic=True,  # SSM state + few attn caches
    # fsdp_sp: sequence-sharded activations beat d_inner-TP for the SSM blocks
    # (30.1 -> 13.5 GB/chip prefill_32k collectives; EXPERIMENTS §Perf cell B)
    sharding_profile="fsdp_sp",
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1),
    hybrid=HybridConfig(period=6, lora_rank=128),
)

REDUCED = ModelConfig(
    name="zamba2-1.2b-reduced", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, act="gelu", subquadratic=True,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1,
                  chunk_size=8),
    hybrid=HybridConfig(period=2, lora_rank=8),
    remat="none", compute_dtype="float32",
)
