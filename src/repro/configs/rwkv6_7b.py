"""rwkv6-7b ("Finch") — 32L d4096 attention-free ff14336 v65536,
data-dependent decay [arXiv:2404.05892; hf]."""
from repro.models.config import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, d_ff=14336,
    vocab_size=65536, norm="layernorm", subquadratic=True,
    rwkv=RWKVConfig(head_dim=64, decay_lora_rank=64),
)

REDUCED = ModelConfig(
    name="rwkv6-7b-reduced", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=224,
    vocab_size=256, norm="layernorm", subquadratic=True,
    rwkv=RWKVConfig(head_dim=16, decay_lora_rank=8),
    remat="none", compute_dtype="float32",
)
