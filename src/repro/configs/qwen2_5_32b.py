"""qwen2.5-32b — 64L d5120 40H (GQA kv=8) ff27648 v152064, QKV bias
[hf:Qwen/Qwen2.5-*; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=27648,
    vocab_size=152064, act="silu", qkv_bias=True, rope_theta=1e6,
    sharding_profile="fsdp_sp",  # 40 heads do not divide the 16-way TP axis
)

REDUCED = ModelConfig(
    name="qwen2.5-32b-reduced", family="dense",
    n_layers=2, d_model=80, n_heads=5, n_kv_heads=1, d_ff=160,
    vocab_size=256, act="silu", qkv_bias=True,
    remat="none", compute_dtype="float32",
)
