"""Normalized model perturbation — the primitive shared by the whole SAM family.

`perturb(params, grad, rho)` implements   w + rho * g / ||g||   (paper Eq. 1-3).
When the fused flat-buffer path is enabled (on-for-TPU default, or an explicit
`fused=` override) the norm and the scale-axpy each run as one single-pass
kernel per dtype bucket (repro.kernels via utils.buckets), halving the HBM
traffic of the per-leaf jnp composition, which stays the CPU and
autodiff-friendly default.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.utils import buckets, trees

Pytree = Any
_EPS = 1e-12


def perturbation_scale(grad: Pytree, rho: float | jax.Array,
                       grad_norm: Optional[jax.Array] = None) -> jax.Array:
    """Scalar rho/||g|| with a zero-safe denominator."""
    if grad_norm is None:
        grad_norm = trees.global_norm(grad)
    return jnp.asarray(rho, jnp.float32) / (grad_norm + _EPS)


def perturb(params: Pytree, grad: Pytree, rho: float | jax.Array,
            grad_norm: Optional[jax.Array] = None, *,
            fused: Optional[bool] = None) -> Pytree:
    """Return w + rho * g/||g|| without modifying dtypes of `params`.

    `fused=None` defers to the platform default (utils.buckets); True/False
    force the flat-buffer kernel path / the per-leaf jnp composition.
    Bucket-resident `params` (utils.buckets.BucketedState) always take the
    flat-buffer path — the buffers are already resident, so the axpy runs
    buffer -> buffer with no gather/scatter, and the result stays resident.
    """
    if buckets.is_bucketed(params) or buckets.fused_path_enabled(fused):
        layout = (params.layout if buckets.is_bucketed(params)
                  else buckets.bucket_layout(params))
        if grad_norm is None:
            grad_norm = jnp.sqrt(buckets.bucketed_sq_norm(grad, layout))
        scale = jnp.asarray(rho, jnp.float32) / (grad_norm + _EPS)
        return buckets.bucketed_axpy(scale, grad, params, layout=layout)
    scale = perturbation_scale(grad, rho, grad_norm)
    return jax.tree.map(
        lambda p, g: (p.astype(jnp.float32)
                      + scale * g.astype(jnp.float32)).astype(p.dtype),
        params, grad)


def perturb_masked(params: Pytree, grad: Pytree, rho: float | jax.Array,
                   mask: Pytree, *, fused: Optional[bool] = None) -> Pytree:
    """ESAM-style partial perturbation: only leaves elements where mask==1.

    The norm is taken over the *masked* gradient so the realized perturbation
    radius stays rho (matches ESAM's 1/sqrt(beta) rescaling intent).
    """
    masked = jax.tree.map(lambda g, m: g * m, grad, mask)
    return perturb(params, masked, rho, fused=fused)


def gradient_norm_penalty_direction(grad_w: Pytree, grad_pert: Pytree,
                                    alpha: float) -> Pytree:
    """Generalized-SAM mixing  (1-alpha)*∇L(w) + alpha*∇L(ŵ)  (Zhao et al. 22)."""
    return jax.tree.map(
        lambda gw, gp: ((1.0 - alpha) * gw.astype(jnp.float32)
                        + alpha * gp.astype(jnp.float32)).astype(gw.dtype),
        grad_w, grad_pert)
