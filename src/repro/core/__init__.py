"""repro.core — the paper's contribution (AsyncSAM) plus the SAM family."""
from __future__ import annotations

from repro.core.api import (  # noqa: F401
    LossFn,
    Method,
    MethodConfig,
    TrainState,
    init_train_state,
    step_rng,
)
from repro.core.ascent import (  # noqa: F401
    Compressor,
    StalenessLedger,
    slice_ascent_batch,
    split_batch,
    system_aware_ascent_fraction,
)
from repro.core.async_sam import (  # noqa: F401
    AsyncSamState,
    make_ascent_fn,
    make_async_sam,
    make_descent_fn,
)
from repro.core.perturb import perturb, perturb_masked, perturbation_scale  # noqa: F401
from repro.core.sam import make_gsam, make_sam, make_sgd  # noqa: F401
from repro.core.variants import make_aesam, make_esam, make_looksam, make_mesa  # noqa: F401

_REGISTRY = {
    "sgd": make_sgd,
    "sam": make_sam,
    "gsam": make_gsam,
    "async_sam": make_async_sam,
    "looksam": make_looksam,
    "esam": make_esam,
    "aesam": make_aesam,
    "mesa": make_mesa,
}


def available_methods() -> list[str]:
    return sorted(_REGISTRY)


def make_method(cfg: MethodConfig) -> Method:
    """Instantiate a training method from its config (name-dispatched)."""
    import dataclasses

    try:
        factory = _REGISTRY[cfg.name]
    except KeyError:
        raise ValueError(
            f"unknown method {cfg.name!r}; available: {available_methods()}") from None
    return dataclasses.replace(factory(cfg), cfg=cfg)
