"""AsyncSAM — the paper's contribution (Algorithm 1), in two executable forms.

Form A ("fused", pod-scale default): because tau=1 removes the ascent->descent
dependency, one jitted SPMD step computes BOTH

    g_t = ∇L^b ( w_t + r * a_{t-1} / ||a_{t-1}|| )     (descent, perturbed)
    a_t = ∇L^{b'} ( w_t )                               (next ascent)

The two gradient computations are independent dataflow nodes, so XLA's
scheduler overlaps the small collective-free ascent compute with the descent
gradient's reduce-scatter — the TPU-native realization of "hide the
perturbation time" (DESIGN.md §2 A1). The carried state a_{t-1} is exactly the
asynchrony of paper Eq. 2 with tau=1.

Form B ("split", faithful heterogeneous executor): `ascent_fn` and
`descent_fn` are exposed separately so repro.runtime.async_executor can run
them on two different compute resources with a depth-1 queue, reproducing the
paper's MPI two-process scheme including system-aware b' calibration and
straggler fallback.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.perturb import (gradient_norm_penalty_direction,
                                perturb as _perturb, perturb_masked as _perturb_masked)
from repro.core.api import (LossFn, Method, MethodConfig, TrainState, _finish,
                            step_rng, value_and_grad_acc)
from repro.core.ascent import Compressor, CompressionState, slice_ascent_batch, split_batch
from repro.core.sam import _m
from repro.optim import GradientTransform
from repro.utils import buckets, trees

Pytree = Any


class AsyncSamState(NamedTuple):
    """Carry across steps: the (possibly compressed) ascent gradient a_{t-tau}."""
    ascent_grad: Pytree            # a_{t-1}; zeros before the first refresh
    ascent_norm: jax.Array         # ||a_{t-1}|| (fp32 scalar)
    have_ascent: jax.Array         # bool scalar: a valid gradient is held
    staleness: jax.Array           # int32: age of the held gradient (tau)
    compression: CompressionState  # error-feedback residual ((), when disabled)


def _init_state(params: Pytree, compressor: Compressor) -> AsyncSamState:
    return AsyncSamState(
        ascent_grad=trees.tree_zeros_like(params, jnp.float32),
        ascent_norm=jnp.zeros((), jnp.float32),
        have_ascent=jnp.zeros((), jnp.bool_),
        staleness=jnp.zeros((), jnp.int32),
        compression=compressor.init(params),
    )


def make_async_sam(cfg: MethodConfig) -> Method:
    compressor = Compressor(kind=cfg.compressor, topk_fraction=cfg.topk_fraction)

    def init(params, rng):
        return _init_state(params, compressor)

    def make_step(loss_fn: LossFn, optimizer: GradientTransform):
        vg = value_and_grad_acc(loss_fn, cfg.n_microbatches)

        def step(state: TrainState, batch):
            batch, ascent_batch = split_batch(batch)
            if ascent_batch is None:
                ascent_batch = slice_ascent_batch(batch, cfg.ascent_fraction)
            ms: AsyncSamState = state.method_state
            rng = step_rng(state)
            rng_d, rng_a = jax.random.split(rng)

            # --- perturb with the STALE gradient a_{t-1} (Algorithm 1, line 5).
            # At t=0 no ascent gradient exists: rho_eff=0 degrades to SGD
            # (Algorithm 1, line 8) without a traced branch.
            rho_eff = jnp.where(ms.have_ascent, cfg.rho, 0.0)
            w_hat = _perturb(state.params, ms.ascent_grad, rho_eff,
                              grad_norm=ms.ascent_norm,
                              fused=cfg.fused_update)

            # --- descent gradient at the perturbed point (line 6).
            (loss, aux), grads = vg(w_hat, batch, rng_d)

            # --- NEXT ascent gradient at the *unperturbed* current params
            # (line 3; independent of the descent computation above).
            # ascent_interval > 1 (beyond-paper "AsyncSAM-k") refreshes only
            # every k-th step: average extra compute drops to f/k while tau
            # grows to at most k — EXPERIMENTS §Perf validates the accuracy.
            if cfg.ascent_interval <= 1:
                (loss_asc, _), a_new = vg(state.params, ascent_batch, rng_a)
                staleness = jnp.ones((), jnp.int32)
                reused = jnp.zeros((), jnp.float32)
            else:
                def fresh(_):
                    (la, _), a = vg(state.params, ascent_batch, rng_a)
                    return trees.tree_cast(a, jnp.float32), la, jnp.int32(1)

                def reuse(_):
                    # ascent_loss is a NaN SENTINEL here (no ascent pass ran,
                    # there is no loss to report); the explicit ascent_reused
                    # flag below is what disambiguates it from a genuine NaN
                    return (ms.ascent_grad, jnp.float32(jnp.nan),
                            ms.staleness + 1)

                refresh = (state.step % cfg.ascent_interval) == 0
                a_new, loss_asc, staleness = jax.lax.cond(refresh, fresh,
                                                          reuse, None)
                reused = (~refresh).astype(jnp.float32)

            # --- ascent-state refresh. On the fused path the cosine metric
            # and the carried norm come from ONE pass over (a_t, a_{t-1})
            # (kernels.fused_dot_norms) instead of three per-leaf reductions;
            # lossless only, since compression changes the stored gradient.
            # With bucket-resident state both operands already ARE buffers
            # (a_new differentiated through the params view, ascent_grad
            # carried resident), so the refresh is buffer -> buffer.
            resident = buckets.is_bucketed(state.params)
            if ((resident or buckets.fused_path_enabled(cfg.fused_update))
                    and cfg.compressor == "none"):
                a32 = trees.tree_cast(a_new, jnp.float32)
                layout = (state.params.layout if resident
                          else buckets.bucket_layout(a32))
                dot, sq_new, sq_old = buckets.bucketed_dot_norms(
                    a32, ms.ascent_grad, layout=layout)
                cos = dot / (jnp.sqrt(sq_new) * jnp.sqrt(sq_old) + 1e-12)
                comp_state = ms.compression
                new_ms = AsyncSamState(
                    ascent_grad=a32,
                    ascent_norm=jnp.sqrt(sq_new),
                    have_ascent=jnp.ones((), jnp.bool_),
                    staleness=staleness,
                    compression=comp_state,
                )
            else:
                cos = trees.tree_cosine_similarity(a_new, ms.ascent_grad)
                a_lossy, comp_state = compressor.compress(a_new, ms.compression)
                new_ms = AsyncSamState(
                    ascent_grad=trees.tree_cast(a_lossy, jnp.float32),
                    ascent_norm=trees.global_norm(a_lossy),
                    have_ascent=jnp.ones((), jnp.bool_),
                    staleness=staleness,
                    compression=comp_state,
                )
            if cfg.guard_update:
                # keep a non-finite ascent refresh out of the CARRIED state:
                # a NaN a_t held across steps poisons every later perturbation
                # (0 * NaN is still NaN), so the refresh is guarded by its own
                # finiteness, independent of the descent verdict in _finish
                ok_a = jnp.isfinite(new_ms.ascent_norm)
                new_ms = jax.tree.map(lambda n, o: jnp.where(ok_a, n, o),
                                      new_ms, ms)
            metrics = {"loss": loss, "ascent_loss": loss_asc,
                       "ascent_norm": new_ms.ascent_norm,
                       "ascent_cosine": cos,
                       "ascent_reused": reused,
                       "perturbed": ms.have_ascent.astype(jnp.float32),
                       **_m(aux)}
            return _finish(state, optimizer, grads, new_ms, metrics,
                           guard=cfg.guard_update)

        return step

    return Method("async_sam", init, make_step)


# ---------------------------------------------------------------------------
# Split-phase API (Form B) — used by the heterogeneous async executor.
# ---------------------------------------------------------------------------

def make_ascent_fn(loss_fn: LossFn) -> Callable:
    """Jittable ascent phase: params, batch, rng -> (grad fp32, norm, loss).

    Runs on the *slow* resource (paper: CPU). Collective-free. Params arrive
    pytree-shaped (the lane hand-off / wire contract; the executor converts a
    bucket-resident snapshot at the edge).
    """
    def ascent(params, batch, rng):
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch, rng)
        g = trees.tree_cast(g, jnp.float32)
        return g, trees.global_norm(g), loss

    return ascent


def make_descent_fn(cfg: MethodConfig, loss_fn: LossFn,
                    optimizer: GradientTransform) -> Callable:
    """Jittable descent phase: one model update given a held ascent gradient.

    (state, batch, a, a_norm, have_a) -> (state, metrics). `have_a=False`
    (straggler fallback past max staleness) degrades the step to plain SGD.
    With bucket-resident state, `a` still arrives pytree-shaped from the lane
    (the cross-resource hand-off); perturb gathers it once against the
    resident layout and everything downstream stays buffer -> buffer.
    """
    vg = value_and_grad_acc(loss_fn, 1)

    def descent(state: TrainState, batch, a: Pytree, a_norm: jax.Array,
                have_a: jax.Array):
        batch, _ = split_batch(batch)
        rho_eff = jnp.where(have_a, cfg.rho, 0.0)
        w_hat = _perturb(state.params, a, rho_eff, grad_norm=a_norm,
                         fused=cfg.fused_update)
        (loss, aux), grads = vg(w_hat, batch, step_rng(state))
        return _finish(state, optimizer, grads, state.method_state,
                       {"loss": loss, **_m(aux)}, guard=cfg.guard_update)

    return descent
