"""Ascent-gradient channel: batch slicing, sync modes, and lossy compression.

The asynchronous ascent gradient is the piece of state AsyncSAM carries across
steps. This module owns:

* how the b'-sized ascent batch is derived from (or supplied with) the step
  batch (paper §3.3, system-aware b'),
* how the ascent gradient is synchronized across data-parallel workers
  (`local` / `global` semantics — see DESIGN.md §2), and
* lossy compression for the exchange (int8 / top-k with error feedback) —
  the perturbation *direction* tolerates quantization noise by the same
  argument (Theorem 3.1's sigma^2/b' term) that tolerates b' < b.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.utils import trees

Pytree = Any


# ---------------------------------------------------------------------------
# Ascent batch derivation
# ---------------------------------------------------------------------------

def slice_ascent_batch(batch: Pytree, fraction: float) -> Pytree:
    """Take the leading `fraction` of the batch axis as the ascent batch.

    Used when the data pipeline does not supply a dedicated `ascent` sub-batch.
    Sizes are rounded up so fraction>0 always yields >=1 sample, and to the
    data-parallel-friendly multiple handled upstream by the pipeline.
    """
    def f(x):
        b = x.shape[0]
        bp = max(1, int(round(b * fraction)))
        return x[:bp]

    return jax.tree.map(f, batch)


def split_batch(batch: dict) -> tuple[dict, Optional[dict]]:
    """Split a pipeline batch into (descent, ascent-or-None)."""
    if isinstance(batch, dict) and "ascent" in batch:
        descent = {k: v for k, v in batch.items() if k != "ascent"}
        return descent, batch["ascent"]
    return batch, None


def system_aware_ascent_fraction(t_fast: float, t_slow: float,
                                 floor: float = 0.05, cap: float = 1.0) -> float:
    """Paper §3.3:  b' = (T_f / T_s) * b  from measured per-sample grad times.

    `t_fast` is the per-sample gradient time on the resource running descent,
    `t_slow` the per-sample time on the resource running ascent. Clipped to
    [floor, cap] so a pathological measurement never stalls training.
    """
    if t_slow <= 0 or t_fast <= 0:
        return cap
    return float(min(cap, max(floor, t_fast / t_slow)))


# ---------------------------------------------------------------------------
# Compression (error-feedback quantizers for the ascent exchange)
# ---------------------------------------------------------------------------

class CompressionState(NamedTuple):
    """Residual error-feedback memory, one leaf per parameter leaf."""
    error: Pytree


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Lossy pytree compressor with error feedback.

    kind: "none" | "int8" | "topk"
    topk_fraction: fraction of elements kept per leaf for kind="topk".
    """
    kind: str = "none"
    topk_fraction: float = 0.01

    def init(self, params: Pytree) -> CompressionState:
        if self.kind == "none":
            return CompressionState(error=())
        return CompressionState(error=trees.tree_zeros_like(params, jnp.float32))

    def compress(self, grad: Pytree, state: CompressionState
                 ) -> tuple[Pytree, CompressionState]:
        """Return (decompressed lossy gradient, new residual state).

        The returned tree is the value the *receiver* reconstructs; callers use
        it in place of the exact gradient. Residual (g - Q(g+e)) is carried so
        the quantization error is unbiased over time (error feedback / EF21).
        """
        if self.kind == "none":
            return grad, state
        corrected = jax.tree.map(
            lambda g, e: g.astype(jnp.float32) + e, grad, state.error)
        if self.kind == "int8":
            quant = jax.tree.map(_int8_roundtrip, corrected)
        elif self.kind == "topk":
            quant = jax.tree.map(
                lambda x: _topk_roundtrip(x, self.topk_fraction), corrected)
        else:
            raise ValueError(f"unknown compressor kind {self.kind!r}")
        new_err = jax.tree.map(jnp.subtract, corrected, quant)
        quant = jax.tree.map(lambda q, g: q.astype(g.dtype), quant, grad)
        return quant, CompressionState(error=new_err)

    def wire_bytes(self, grad: Pytree) -> int:
        """Exact *payload* bytes for one exchange (roofline/collective term).

        This models the compressed payload only — per-leaf, matching what
        `service.protocol.encode_grad` actually serializes leaf by leaf.
        Frame overhead (header, shape metadata) is accounted separately by
        `service.protocol.grad_frame_bytes`.
        """
        n = trees.tree_size(grad)
        if self.kind == "none":
            return 4 * n
        if self.kind == "int8":
            return n + 8 * len(jax.tree.leaves(grad))  # payload + per-leaf scale
        if self.kind == "topk":
            # per-leaf k (the compressor keeps top-k per leaf, not globally);
            # 8 bytes per kept entry: (u32 index, fp32 value)
            return sum(8 * max(1, int(x.size * self.topk_fraction))
                       for x in jax.tree.leaves(grad))
        raise ValueError(self.kind)


def _int8_roundtrip(x: jax.Array) -> jax.Array:
    """Symmetric per-leaf int8 quantize->dequantize."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _topk_roundtrip(x: jax.Array, fraction: float) -> jax.Array:
    """Keep the top-|fraction| magnitude entries, zero the rest."""
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * fraction))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    out = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return out.reshape(x.shape)


# ---------------------------------------------------------------------------
# Staleness ledger (host-side bookkeeping for the hetero executor)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StalenessLedger:
    """Tracks the age (tau) of the ascent gradient currently in use.

    The paper fixes tau=1; the executor lets tau grow up to `max_staleness`
    under stragglers, after which the step degrades gracefully to SGD
    (no perturbation) — an AsyncSAM-specific straggler-mitigation policy.
    """
    max_staleness: int = 4
    tau: int = 0            # age of the held ascent gradient, in steps
    refreshes: int = 0      # how many fresh ascent grads were consumed
    stale_reuses: int = 0   # steps that reused an old gradient (tau grew)
    sgd_fallbacks: int = 0  # steps that ran without perturbation

    def on_fresh(self) -> None:
        self.tau = 1
        self.refreshes += 1

    def on_reuse(self) -> bool:
        """Advance age; return True if the gradient is still usable."""
        self.tau += 1
        if self.tau > self.max_staleness:
            self.sgd_fallbacks += 1
            return False
        self.stale_reuses += 1
        return True

    def summary(self) -> dict:
        return dict(tau=self.tau, refreshes=self.refreshes,
                    stale_reuses=self.stale_reuses,
                    sgd_fallbacks=self.sgd_fallbacks)
