"""Training-method API: a uniform step interface over the SAM family.

Every method (SGD, SAM, AsyncSAM, GSAM, LookSAM, ESAM, AE-SAM, MESA) is exposed
as a `Method` with

    init(params, rng)                  -> method_state pytree
    step(state, batch)                 -> (state, metrics)     [built by make_step]

where `state` is the framework-wide `TrainState`. The step functions are pure
and jit/pjit-friendly: under pjit with sharded batches the mini-batch mean loss
autodiffs to globally-reduced gradients, so the same code runs on 1 CPU device
and on the 512-chip production mesh.

The loss callback protocol is

    loss_fn(params, batch, rng) -> (scalar_loss, aux_dict)

aux may contain "logits" (used by MESA's trajectory loss) and arbitrary
metrics that are passed through to the step metrics.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.optim import GradientTransform, apply_updates
from repro.optim.fused import fused_apply
from repro.utils import buckets, trees

Pytree = Any
LossFn = Callable[[Pytree, Any, jax.Array], tuple[jax.Array, dict]]


class TrainState(NamedTuple):
    step: jax.Array          # int32 scalar
    rng: jax.Array           # PRNG key threaded through data-order-independent noise
    params: Pytree
    opt_state: Pytree
    method_state: Pytree     # method-specific carry (e.g. AsyncSAM's a_{t-1})


@dataclasses.dataclass(frozen=True)
class MethodConfig:
    """One config object for the whole family; irrelevant fields are ignored.

    name: sgd | sam | async_sam | gsam | looksam | esam | aesam | mesa
    rho: perturbation radius r (paper Table A.2 uses 0.05~0.1).
    ascent_fraction: b'/b for AsyncSAM (paper: {25,50,75,100}%).
    same_batch_ascent: SAM convention — ascent uses the same minibatch as
        descent (Foret et al.); AsyncSAM uses *different* samples by design.
    alpha: GSAM mixing coefficient (0.7~0.9).
    looksam_k: gradient-ascent reuse interval (paper fixes 2).
    esam_beta: fraction of parameters perturbed by ESAM's SWP.
    aesam_lambda_hi: z-score threshold above which AE-SAM takes a SAM step.
    mesa_decay / mesa_lambda / mesa_temp / mesa_start_step: MESA EMA-distill.
    compressor / topk_fraction: lossy ascent-exchange compression (DESIGN §2).
    """
    name: str = "async_sam"
    rho: float = 0.1
    ascent_fraction: float = 0.25
    same_batch_ascent: bool = True
    alpha: float = 0.8
    looksam_k: int = 2
    esam_beta: float = 0.6
    aesam_lambda_hi: float = 1.0
    aesam_ema: float = 0.9
    mesa_decay: float = 0.995
    mesa_lambda: float = 0.8
    mesa_temp: float = 1.5
    mesa_start_step: int = 200
    compressor: str = "none"
    topk_fraction: float = 0.01
    n_microbatches: int = 1   # gradient accumulation (activation-memory lever)
    ascent_interval: int = 1  # refresh a_t every k steps (beyond-paper; tau<=k)
    # In-step numerics guard (runtime.guard): a non-finite loss or gradient
    # discards the whole update by tree-select inside the jitted step
    # (params/opt_state/method_state carried unchanged, step/rng advance so
    # the batch is consumed), and the step emits update_skipped /
    # nonfinite_count. Honored by sgd, sam, gsam and async_sam — the methods
    # the guard ladder drives; the long-tail variants ignore it.
    guard_update: bool = False
    # Flat-buffer fused weight-space path (perturb axpy, ascent-refresh
    # dot/norms). None defers to the platform default: on for TPU, off
    # elsewhere (utils.buckets.fused_path_enabled). Executors resolve and pin
    # this; the matching optimizer-epilogue switch lives on FusedSpec.enabled.
    fused_update: Optional[bool] = None


@dataclasses.dataclass(frozen=True)
class Method:
    """A named pair of (state init, step builder).

    `cfg` is the MethodConfig the factory closed over (attached by
    `core.make_method`); executors use it to rebuild the method with a
    resolved `fused_update` flag. None for hand-constructed Methods.
    """
    name: str
    init: Callable[[Pytree, jax.Array], Pytree]
    make_step: Callable[[LossFn, GradientTransform], Callable]
    cfg: Optional[MethodConfig] = None


def init_train_state(params: Pytree, optimizer: GradientTransform,
                     method: Method, rng: jax.Array) -> TrainState:
    init_rng, state_rng = jax.random.split(rng)
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        rng=state_rng,
        params=params,
        opt_state=optimizer.init(params),
        method_state=method.init(params, init_rng),
    )


def _finish(state: TrainState, optimizer: GradientTransform, grads: Pytree,
            method_state: Pytree, metrics: dict, *,
            guard: bool = False) -> tuple[TrainState, dict]:
    """Shared tail: inner-optimizer update + state threading.

    Canonical sgd/adamw chains take the fused flat-buffer path when enabled
    (optim.fused): one single-pass kernel per dtype bucket instead of the
    per-leaf update + apply_updates passes, with identical opt_state layout.

    guard=True (MethodConfig.guard_update) adds the in-step numerics check:
    a non-finite loss or global gradient norm discards the update — params /
    opt_state / method_state are tree-selected back to their previous values
    INSIDE the jit (a post-hoc host-side skip is impossible: executors donate
    the input state buffers), while step and rng still advance so the
    anomalous batch is consumed, not replayed. The step then carries
    `update_skipped` (1.0 on a skip) and `nonfinite_count` (non-finite
    gradient elements) for the host-side guard ladder (runtime.guard).
    """
    metrics = dict(metrics)
    fused = fused_apply(optimizer, grads, state.opt_state, state.params)
    if fused is not None:
        params, opt_state, gnorm = fused
        metrics.setdefault("grad_norm", gnorm)
    else:
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = apply_updates(state.params, updates)
        metrics.setdefault("grad_norm", trees.global_norm(grads))
    if guard:
        # a single non-finite element makes the global norm non-finite, so
        # the ok verdict needs no extra pass; the element count is one more
        # reduction over grads, paid only when the guard is on
        ok = (jnp.isfinite(metrics["grad_norm"])
              & jnp.isfinite(metrics.get("loss", jnp.float32(0.0))))
        keep = lambda n, o: jnp.where(ok, n, o)  # noqa: E731
        params = jax.tree.map(keep, params, state.params)
        opt_state = jax.tree.map(keep, opt_state, state.opt_state)
        method_state = jax.tree.map(keep, method_state, state.method_state)
        nonfinite = sum(jnp.sum(~jnp.isfinite(g)).astype(jnp.int32)
                        for g in jax.tree.leaves(grads))
        metrics["update_skipped"] = (~ok).astype(jnp.float32)
        metrics["nonfinite_count"] = jnp.asarray(nonfinite, jnp.float32)
    rng, _ = jax.random.split(state.rng)
    new_state = TrainState(step=state.step + 1, rng=rng, params=params,
                           opt_state=opt_state, method_state=method_state)
    return new_state, metrics


def step_rng(state: TrainState) -> jax.Array:
    """Per-step PRNG derived from (rng, step): restart-stable."""
    return jax.random.fold_in(state.rng, state.step)


def view_loss(loss_fn: LossFn) -> LossFn:
    """Make a loss callback accept bucket-resident parameters.

    When params arrive as a `buckets.BucketedState`, the model sees the
    zero-copy pytree view; differentiating through the view transposes to
    cotangent accumulation straight into the buffers, so `jax.grad` of the
    wrapped loss returns gradients already bucket-shaped — no gather pass
    between autodiff and the fused weight-space kernels. Plain pytrees pass
    through untouched.
    """
    def fn(params, batch, rng):
        return loss_fn(buckets.tree_view(params), batch, rng)

    return fn


def value_and_grad_acc(loss_fn: LossFn, n_micro: int):
    """jax.value_and_grad(has_aux=True) with microbatch gradient accumulation.

    With n_micro > 1 the batch's leading dim is split into n_micro chunks
    scanned sequentially; activations live one chunk at a time (the standard
    pod-scale activation-memory lever). aux is reduced to its scalar metrics
    (mean over chunks) — methods needing full aux tensors (MESA) keep
    n_micro == 1.

    Bucket-resident params work transparently: the loss is view-wrapped, and
    the accumulation arithmetic (`tree_zeros_like`, leafwise adds/casts) maps
    over the buffers themselves.
    """
    loss_fn = view_loss(loss_fn)
    if n_micro <= 1:
        return jax.value_and_grad(loss_fn, has_aux=True)

    def fn(params, batch, rng):
        def chunked(x):
            b = x.shape[0]
            assert b % n_micro == 0, (b, n_micro)
            return x.reshape(n_micro, b // n_micro, *x.shape[1:])

        chunks = jax.tree.map(chunked, batch)

        def body(carry, chunk):
            loss_sum, grad_sum = carry
            (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, chunk, rng)
            scal = {k: v for k, v in aux.items()
                    if isinstance(v, jax.Array) and v.ndim == 0}
            grad_sum = jax.tree.map(
                lambda a, gi: a + gi.astype(jnp.float32), grad_sum, g)
            return (loss_sum + l, grad_sum), scal

        init = (jnp.float32(0.0), trees.tree_zeros_like(params, jnp.float32))
        (loss_sum, grad_sum), auxs = jax.lax.scan(body, init, chunks)
        grads = jax.tree.map(lambda g, p: (g / n_micro).astype(p.dtype),
                             grad_sum, params)
        aux = jax.tree.map(lambda v: jnp.mean(v, axis=0), auxs)
        return (loss_sum / n_micro, aux), grads

    return fn
