"""SGD, SAM (Foret et al. 21) and Generalized SAM (Zhao et al. 22) baselines.

These are the synchronous references AsyncSAM is compared against in paper
Tables 4.1/4.2 and Figures 3/4. They share the framework step protocol defined
in repro.core.api.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.perturb import (gradient_norm_penalty_direction,
                                perturb as _perturb, perturb_masked as _perturb_masked)
from repro.core.api import (LossFn, Method, MethodConfig, TrainState, _finish,
                            step_rng, value_and_grad_acc)
from repro.core.ascent import split_batch
from repro.optim import GradientTransform
from repro.utils import trees


def make_sgd(cfg: MethodConfig) -> Method:
    def init(params, rng):
        return ()

    def make_step(loss_fn: LossFn, optimizer: GradientTransform):
        vg = value_and_grad_acc(loss_fn, cfg.n_microbatches)

        def step(state: TrainState, batch):
            batch, _ = split_batch(batch)
            rng = step_rng(state)
            (loss, aux), grads = vg(state.params, batch, rng)
            return _finish(state, optimizer, grads, (), {"loss": loss, **_m(aux)},
                           guard=cfg.guard_update)

        return step

    return Method("sgd", init, make_step)


def make_sam(cfg: MethodConfig) -> Method:
    """Vanilla SAM: two sequential gradient evaluations per step (Eq. 1)."""

    def init(params, rng):
        return ()

    def make_step(loss_fn: LossFn, optimizer: GradientTransform):
        vg = value_and_grad_acc(loss_fn, cfg.n_microbatches)

        def step(state: TrainState, batch):
            batch, ascent_batch = split_batch(batch)
            if cfg.same_batch_ascent or ascent_batch is None:
                ascent_batch = batch
            rng = step_rng(state)
            # --- gradient ascent (perturbation) ---
            (loss_w, _), g_ascent = vg(state.params, ascent_batch, rng)
            w_hat = _perturb(state.params, g_ascent, cfg.rho,
                             fused=cfg.fused_update)
            # --- gradient descent at the perturbed point ---
            (loss, aux), grads = vg(w_hat, batch, rng)
            metrics = {"loss": loss, "loss_at_w": loss_w,
                       "ascent_norm": trees.global_norm(g_ascent), **_m(aux)}
            return _finish(state, optimizer, grads, (), metrics,
                           guard=cfg.guard_update)

        return step

    return Method("sam", init, make_step)


def make_gsam(cfg: MethodConfig) -> Method:
    """Generalized SAM / gradient-norm penalty: mix ∇L(w) and ∇L(ŵ) by alpha."""

    def init(params, rng):
        return ()

    def make_step(loss_fn: LossFn, optimizer: GradientTransform):
        vg = value_and_grad_acc(loss_fn, cfg.n_microbatches)

        def step(state: TrainState, batch):
            batch, ascent_batch = split_batch(batch)
            if cfg.same_batch_ascent or ascent_batch is None:
                ascent_batch = batch
            rng = step_rng(state)
            (loss_w, _), g_w = vg(state.params, ascent_batch, rng)
            w_hat = _perturb(state.params, g_w, cfg.rho, fused=cfg.fused_update)
            (loss, aux), g_hat = vg(w_hat, batch, rng)
            grads = gradient_norm_penalty_direction(g_w, g_hat, cfg.alpha)
            metrics = {"loss": loss, "loss_at_w": loss_w, **_m(aux)}
            return _finish(state, optimizer, grads, (), metrics,
                           guard=cfg.guard_update)

        return step

    return Method("gsam", init, make_step)


def _m(aux: dict) -> dict:
    """Pass through scalar aux metrics only."""
    return {k: v for k, v in aux.items()
            if isinstance(v, jax.Array) and v.ndim == 0}
