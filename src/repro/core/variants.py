"""Computation-efficient SAM baselines the paper compares against (Table 4.1).

LookSAM (Liu et al. 22)  — reuse the ascent direction's novel component for k steps.
ESAM    (Du et al. 22a)  — stochastic partial-parameter perturbation (SWP).
AE-SAM  (Jiang et al. 23) — adaptively take SAM steps only in sharp regions.
MESA    (Du et al. 22b)  — sharpness-aware-for-free via an EMA trajectory loss.

Each follows the repro.core.api step protocol so every benchmark harness can
swap methods with one flag.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.perturb import (gradient_norm_penalty_direction,
                                perturb as _perturb, perturb_masked as _perturb_masked)
from repro.core.api import (LossFn, Method, MethodConfig, TrainState, _finish,
                            step_rng, value_and_grad_acc)
from repro.core.ascent import split_batch
from repro.core.sam import _m
from repro.optim import GradientTransform
from repro.utils import trees

Pytree = Any


# ---------------------------------------------------------------------------
# LookSAM
# ---------------------------------------------------------------------------

class LookSamState(NamedTuple):
    g_v: Pytree          # component of ∇L(ŵ) orthogonal to ∇L(w), reused k-1 steps
    have_gv: jax.Array


def make_looksam(cfg: MethodConfig) -> Method:
    k = max(1, cfg.looksam_k)

    def init(params, rng):
        return LookSamState(g_v=trees.tree_zeros_like(params, jnp.float32),
                            have_gv=jnp.zeros((), jnp.bool_))

    def make_step(loss_fn: LossFn, optimizer: GradientTransform):
        vg = value_and_grad_acc(loss_fn, cfg.n_microbatches)

        def fresh_step(params, batch, rng):
            """SAM-style refresh: returns (grads, new g_v, loss, aux)."""
            (_, _), g_w = vg(params, batch, rng)
            w_hat = _perturb(params, g_w, cfg.rho, fused=cfg.fused_update)
            (loss, aux), g_s = vg(w_hat, batch, rng)
            # decompose g_s into the component parallel to g_w and the rest
            denom = trees.tree_sq_norm(g_w) + 1e-12
            coef = trees.tree_dot(g_s, g_w) / denom
            g_v = jax.tree.map(
                lambda gs, gw: gs.astype(jnp.float32) - coef * gw.astype(jnp.float32),
                g_s, g_w)
            return g_s, g_v, loss, aux

        def reuse_step(params, batch, rng, g_v):
            """Cheap step: g + alpha * ||g||/||g_v|| * g_v  (LookSAM Eq. 5)."""
            (loss, aux), g = vg(params, batch, rng)
            scale = cfg.alpha * trees.global_norm(g) / (trees.global_norm(g_v) + 1e-12)
            grads = jax.tree.map(
                lambda gi, gv: (gi.astype(jnp.float32) + scale * gv).astype(gi.dtype),
                g, g_v)
            return grads, loss, aux

        def step(state: TrainState, batch):
            batch, _ = split_batch(batch)
            ms: LookSamState = state.method_state
            rng = step_rng(state)
            is_fresh = jnp.logical_or(state.step % k == 0,
                                      jnp.logical_not(ms.have_gv))

            def do_fresh(_):
                grads, g_v, loss, aux = fresh_step(state.params, batch, rng)
                return trees.tree_cast(grads, jnp.float32), g_v, loss
            def do_reuse(_):
                grads, loss, aux = reuse_step(state.params, batch, rng, ms.g_v)
                return trees.tree_cast(grads, jnp.float32), ms.g_v, loss

            grads, g_v, loss = jax.lax.cond(is_fresh, do_fresh, do_reuse, None)
            grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, state.params)
            new_ms = LookSamState(g_v=g_v, have_gv=jnp.ones((), jnp.bool_))
            return _finish(state, optimizer, grads, new_ms,
                           {"loss": loss, "fresh": is_fresh.astype(jnp.float32)})

        return step

    return Method("looksam", init, make_step)


# ---------------------------------------------------------------------------
# ESAM (stochastic weight perturbation)
# ---------------------------------------------------------------------------

def make_esam(cfg: MethodConfig) -> Method:
    """ESAM-SWP: perturb a Bernoulli(beta) random subset of parameters.

    The data-selection half (SDS) relies on per-example loss bookkeeping that
    injects estimator bias (as the paper notes); we implement the SWP half,
    which carries the efficiency claim, and document the omission in DESIGN.md.
    """

    def init(params, rng):
        return ()

    def make_step(loss_fn: LossFn, optimizer: GradientTransform):
        vg = value_and_grad_acc(loss_fn, cfg.n_microbatches)

        def step(state: TrainState, batch):
            batch, _ = split_batch(batch)
            rng = step_rng(state)
            rng_mask, rng_loss = jax.random.split(rng)
            (_, _), g_w = vg(state.params, batch, rng_loss)
            # Bernoulli(beta) element mask over every leaf
            leaves, treedef = jax.tree.flatten(g_w)
            keys = jax.random.split(rng_mask, len(leaves))
            mask = jax.tree.unflatten(treedef, [
                jax.random.bernoulli(k, cfg.esam_beta, x.shape).astype(x.dtype)
                for k, x in zip(keys, leaves)])
            w_hat = _perturb_masked(state.params, g_w, cfg.rho, mask,
                                    fused=cfg.fused_update)
            (loss, aux), grads = vg(w_hat, batch, rng_loss)
            return _finish(state, optimizer, grads, (), {"loss": loss, **_m(aux)})

        return step

    return Method("esam", init, make_step)


# ---------------------------------------------------------------------------
# AE-SAM (adaptive SAM employment)
# ---------------------------------------------------------------------------

class AeSamState(NamedTuple):
    mean: jax.Array   # EMA of ||g||^2
    var: jax.Array    # EMA of (||g||^2 - mean)^2
    count: jax.Array


def make_aesam(cfg: MethodConfig) -> Method:
    """AE-SAM: take a SAM step only when ||g||^2 is high relative to its EMA
    (z-score > lambda_hi), otherwise plain SGD — sharp regions get SAM."""

    def init(params, rng):
        return AeSamState(mean=jnp.zeros((), jnp.float32),
                          var=jnp.ones((), jnp.float32),
                          count=jnp.zeros((), jnp.int32))

    def make_step(loss_fn: LossFn, optimizer: GradientTransform):
        vg = value_and_grad_acc(loss_fn, cfg.n_microbatches)

        def step(state: TrainState, batch):
            batch, _ = split_batch(batch)
            ms: AeSamState = state.method_state
            rng = step_rng(state)
            (loss_w, aux_w), g_w = vg(state.params, batch, rng)
            sq = trees.tree_sq_norm(g_w)
            z = (sq - ms.mean) / (jnp.sqrt(ms.var) + 1e-12)
            take_sam = jnp.logical_or(z > cfg.aesam_lambda_hi, ms.count < 8)

            def sam_branch(_):
                w_hat = _perturb(state.params, g_w, cfg.rho,
                                 fused=cfg.fused_update)
                (loss, _), grads = vg(w_hat, batch, rng)
                return trees.tree_cast(grads, jnp.float32), loss
            def sgd_branch(_):
                return trees.tree_cast(g_w, jnp.float32), loss_w

            grads, loss = jax.lax.cond(take_sam, sam_branch, sgd_branch, None)
            grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, state.params)
            d = cfg.aesam_ema
            new_ms = AeSamState(mean=d * ms.mean + (1 - d) * sq,
                                var=d * ms.var + (1 - d) * jnp.square(sq - ms.mean),
                                count=ms.count + 1)
            return _finish(state, optimizer, grads, new_ms,
                           {"loss": loss, "sam_step": take_sam.astype(jnp.float32),
                            "gnorm_sq": sq})

        return step

    return Method("aesam", init, make_step)


# ---------------------------------------------------------------------------
# MESA (memory-efficient sharpness-aware training for free)
# ---------------------------------------------------------------------------

class MesaState(NamedTuple):
    ema_params: Pytree


def make_mesa(cfg: MethodConfig) -> Method:
    """MESA: single gradient pass on  L(w) + lambda * KL(f_w || f_ema)  where
    f_ema is the EMA-parameter model (the trajectory provides the sharpness
    signal). Requires the loss callback to expose aux["logits"]."""

    def init(params, rng):
        return MesaState(ema_params=trees.tree_cast(params, jnp.float32))

    def make_step(loss_fn: LossFn, optimizer: GradientTransform):
        def mesa_loss(params, ema_params, batch, rng, active):
            loss, aux = loss_fn(params, batch, rng)
            if "logits" not in aux:
                raise ValueError("MESA requires loss_fn aux to include 'logits'")
            _, ema_aux = loss_fn(jax.lax.stop_gradient(ema_params), batch, rng)
            t = cfg.mesa_temp
            p_ema = jax.nn.softmax(ema_aux["logits"].astype(jnp.float32) / t, axis=-1)
            logq = jax.nn.log_softmax(aux["logits"].astype(jnp.float32) / t, axis=-1)
            kl = -jnp.mean(jnp.sum(p_ema * logq, axis=-1)) * t * t
            return loss + active * cfg.mesa_lambda * kl, (aux, kl)

        def step(state: TrainState, batch):
            batch, _ = split_batch(batch)
            ms: MesaState = state.method_state
            rng = step_rng(state)
            active = (state.step >= cfg.mesa_start_step).astype(jnp.float32)
            (loss, (aux, kl)), grads = jax.value_and_grad(mesa_loss, has_aux=True)(
                state.params, ms.ema_params, batch, rng, active)
            d = cfg.mesa_decay
            ema = jax.tree.map(lambda e, p: d * e + (1 - d) * p.astype(jnp.float32),
                               ms.ema_params, state.params)
            return _finish(state, optimizer, grads, MesaState(ema_params=ema),
                           {"loss": loss, "mesa_kl": kl, **_m(aux)})

        return step

    return Method("mesa", init, make_step)
