"""Model/shape configuration schema for the architecture zoo.

One frozen dataclass describes every assigned architecture; family-specific
sub-configs (MoE, MLA, SSM, RWKV, enc-dec, vision-stub) are attached where the
arch needs them. `ShapeSpec` describes the assigned input-shape cells
(train_4k / prefill_32k / decode_32k / long_500k).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int                 # routed experts
    top_k: int
    expert_d_ff: int               # per-expert intermediate size
    n_shared_experts: int = 0      # deepseek-style always-on experts
    first_dense_layers: int = 0    # leading layers that use a dense MLP
    dense_d_ff: int = 0            # d_ff of those dense layers (0 -> expert_d_ff)
    capacity_factor: float = 1.25  # dense-dispatch buffer slack
    router_aux_weight: float = 0.01  # load-balance auxiliary loss weight


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention (compressed KV)."""
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block parameters."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64             # SSD head dimension P
    n_groups: int = 1
    chunk_size: int = 128


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64             # K/V head size of the wkv state
    decay_lora_rank: int = 64      # data-dependent decay LoRA (RWKV6 "Finch")
    ffn_mult: float = 3.5


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """zamba2-style shared attention block applied every `period` SSM layers."""
    period: int = 6
    lora_rank: int = 128           # per-invocation LoRA on the shared block


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int = 4
    # encoder input: precomputed frame embeddings (conv frontend is a stub per
    # the assignment); enc_len(seq_len) below maps the cell seq to frames.
    enc_len_ratio: float = 1.0


@dataclasses.dataclass(frozen=True)
class VisionStubConfig:
    """phi-3-vision: CLIP frontend stubbed; projector consumes patch embeds."""
    n_image_tokens: int = 576
    clip_dim: int = 1024


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    act: str = "silu"              # silu | gelu  (gated MLP unless mlp_gated=False)
    mlp_gated: bool = True
    norm: str = "rmsnorm"          # rmsnorm | layernorm | nonparam_ln
    qk_norm: bool = False          # qwen3
    qkv_bias: bool = False         # qwen2.5
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # mixtral SWA
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # family-specific
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vision: Optional[VisionStubConfig] = None
    # long-context eligibility: True when attention cost/cache is sub-quadratic
    subquadratic: bool = False
    # execution knobs (hillclimbed in EXPERIMENTS §Perf)
    # "tp": TP activations (heads/d_ff on the model axis);
    # "fsdp_sp": pure FSDP weights + sequence-sharded activations — used when
    # head/ff counts do not divide the model axis (qwen2.5's 40 heads on 16).
    sharding_profile: str = "tp"
    # cast weights to bf16 BEFORE the FSDP all-gathers (shard-local cast) —
    # halves weight-streaming collective bytes; grads cross the cast boundary
    # in bf16 too (EXPERIMENTS §Perf measures the delta per cell)
    weight_stream_bf16: bool = False
    scan_layers: bool = True
    remat: str = "full"            # none | full | dots
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline sanity)."""
        from repro.models.registry import analytic_param_count
        return analytic_param_count(self)

    def active_param_count(self) -> int:
        from repro.models.registry import analytic_param_count
        return analytic_param_count(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The assigned LM-family shape set (identical across the 10 archs).
SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Cell applicability per the assignment rules (skips recorded, not hidden)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full quadratic attention — long_500k skipped per assignment"
    return True, ""
