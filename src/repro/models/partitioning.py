"""Activation + parameter sharding with logical axis names.

Models call `constrain(x, ("batch", None, "model"))` at layer boundaries and
`constrain_param_tree(blk)` on scanned per-layer parameter slices; launchers
opt in with `activation_sharding(mesh)` which maps the logical axes onto mesh
axes ("batch" -> the dp axes, "model" -> the TP axis). Without an active
mapping (unit tests, single-device runs) everything is a no-op, so model code
stays mesh-agnostic.

`constrain_param_tree` exists for a specific pod-scale reason: with
scan-over-layers + FSDP, XLA's loop-invariant code motion hoists the weight
all-gather of the *stacked* (n_layers, ...) parameters out of the loop,
materializing every layer's gathered weights at once (observed 300+GB/device
on qwen2.5-32b). Re-constraining the per-layer slice inside the body makes the
gather depend on the loop index, forcing per-layer gathers — ZeRO-3 semantics.

The parameter rules live here (not in launch/) so both the model bodies and
the launcher-side `launch.sharding` derive specs from one table.
"""
from __future__ import annotations

import contextlib
from typing import Any, Optional, Sequence

import jax
from jax.sharding import PartitionSpec as P

Pytree = Any
_RULES: Optional[dict] = None


def make_rules(mesh) -> dict:
    from repro.launch.mesh import dp_axes

    dp = dp_axes(mesh)
    model = ("model",)

    def size(axes):
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return n

    pod = ("pod",) if "pod" in mesh.axis_names else ()
    return {
        "batch": (dp, size(dp)),
        "model": (model, size(model)),
        "batch_model": (dp + model, size(dp + model)),
        # expert dim: span pods too so EP groups do not replicate per pod
        "pod_model": (pod + model, size(pod + model)),
        "data_only": (("data",), size(("data",))),
    }


@contextlib.contextmanager
def activation_sharding(mesh):
    """Enable logical-axis constraints for code traced inside this context."""
    global _RULES
    prev = _RULES
    _RULES = make_rules(mesh)
    try:
        yield
    finally:
        _RULES = prev


def constrain(x: jax.Array, dims: Sequence[Optional[str]]) -> jax.Array:
    """Apply with_sharding_constraint mapping logical dims onto mesh axes.

    A logical axis whose dimension does not divide its mesh axes is dropped
    (replicated) — e.g. batch=1 long-context decode, or gemma's single KV head
    on a 16-way model axis.
    """
    if _RULES is None:
        return x
    spec = []
    for dim_size, logical in zip(x.shape, dims):
        if logical is None:
            spec.append(None)
            continue
        axes, n = _RULES[logical]
        spec.append(axes if dim_size % n == 0 else None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain_first_fit(x: jax.Array, candidates) -> jax.Array:
    """Apply the first candidate whose every named axis divides its dim.

    Used for attention activations: prefer head-sharding (TP); else spread the
    batch over dp x model (pure-DP attention); else query-sequence (context)
    parallelism — covers head counts that do not divide the model axis
    (e.g. qwen2.5's 40 heads on a 16-way axis).
    """
    if _RULES is None:
        return x
    for dims in candidates:
        ok = True
        for dim_size, logical in zip(x.shape, dims):
            if logical is not None and dim_size % _RULES[logical][1] != 0:
                ok = False
                break
        if ok:
            return constrain(x, dims)
    return x


# ---------------------------------------------------------------------------
# Parameter rules (FSDP x TP; see DESIGN.md §5)
# ---------------------------------------------------------------------------

# leaf names whose (d_in, d_out) orientation is output-projection-like
_OUT_PROJ = {"wo", "wo_mlp", "w_out", "wv_c"}
# leaf names replicated outright (norm scales / tiny vectors / adapters)
_REPLICATED = {"scale", "bias", "kv_norm_scale", "gate_norm_scale", "ln_scale",
               "w0", "mix_r", "mix_k", "mix_v", "mix_w", "mix_g",
               "a_log", "d_skip", "dt_bias", "bonus_u",
               "attn_a", "attn_b", "mlp_a", "mlp_b",
               "decay_a", "decay_b"}
_BIAS_MODEL = {"bq", "bk", "bv", "conv_x_b", "conv_bc_b"}
_CONV_MODEL = {"conv_x_w", "conv_bc_w"}


def param_partition_spec(path: str, shape: tuple[int, ...], rules: dict) -> P:
    """PartitionSpec for one parameter (or mirrored optimizer-state) leaf."""
    dp, dp_n = rules["batch"]
    model, model_n = rules["model"]
    name = path.split("/")[-1]

    def fit(axes, n, dim):
        return axes if dim % n == 0 else None

    if name in _REPLICATED or len(shape) == 0:
        return P()
    if name == "embed":
        v, d = shape[-2], shape[-1]
        lead = (None,) * (len(shape) - 2)
        return P(*lead, fit(model, model_n, v), fit(dp, dp_n, d))
    if name in _BIAS_MODEL or name in _CONV_MODEL:
        lead = (None,) * (len(shape) - 1)
        return P(*lead, fit(model, model_n, shape[-1]))
    if name in ("we_in", "we_gate", "we_out"):
        lead = (None,) * (len(shape) - 3)
        e, di, do = shape[-3], shape[-2], shape[-1]
        # NOTE: pod-spanning EP (experts over pod x model) was measured and
        # REFUTED — cross-pod expert all-to-alls cost more than per-pod
        # expert replication saves (deepseek 2x16x16: 27 -> 40 GB temp,
        # 11 -> 31 GB collectives). Experts stay intra-pod.
        if e % model_n == 0:
            return P(*lead, model, fit(dp, dp_n, di), None)   # EP + FSDP
        if name == "we_out":  # TP over the contraction (f) dim
            return P(*lead, None, fit(model, model_n, di), fit(dp, dp_n, do))
        return P(*lead, None, fit(dp, dp_n, di), fit(model, model_n, do))
    if name == "router":
        lead = (None,) * (len(shape) - 2)
        return P(*lead, fit(dp, dp_n, shape[-2]), None)
    if len(shape) >= 2:
        di, do = shape[-2], shape[-1]
        lead = (None,) * (len(shape) - 2)
        if name in _OUT_PROJ:
            return P(*lead, fit(model, model_n, di), fit(dp, dp_n, do))
        return P(*lead, fit(dp, dp_n, di), fit(model, model_n, do))
    return P(*((None,) * (len(shape) - 1)), fit(model, model_n, shape[-1]))


def constrain_param_tree(tree: Pytree) -> Pytree:
    """Re-pin per-layer parameter slices to their FSDP x TP spec inside scan
    bodies (keeps weight all-gathers per-layer; see module docstring)."""
    if _RULES is None:
        return tree

    def f(path, leaf):
        entries = []
        for k in path:
            if hasattr(k, "key"):
                entries.append(str(k.key))
            elif hasattr(k, "name"):
                entries.append(str(k.name))
            else:
                entries.append(str(getattr(k, "idx", k)))
        spec = param_partition_spec("/".join(entries), leaf.shape, _RULES)
        return jax.lax.with_sharding_constraint(leaf, spec)

    return jax.tree_util.tree_map_with_path(f, tree)


def stream_cast(tree: Pytree, cfg) -> Pytree:
    """Cast >=2-D fp32 weights to the compute dtype BEFORE sharded use.

    The cast is elementwise (shard-local), so every downstream FSDP
    all-gather and gradient reduction moves bf16 instead of fp32 — half the
    wire bytes. 1-D leaves (norm scales, biases) stay fp32 for accuracy.
    """
    import jax.numpy as jnp

    if not getattr(cfg, "weight_stream_bf16", False):
        return tree
    dt = jnp.dtype(cfg.compute_dtype)

    def f(x):
        if x.ndim >= 2 and x.dtype == jnp.float32:
            return x.astype(dt)
        return x

    return jax.tree.map(f, tree)
