from repro.models.config import (  # noqa: F401
    SHAPES,
    EncDecConfig,
    HybridConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    ShapeSpec,
    SSMConfig,
    VisionStubConfig,
    shape_applicable,
)
from repro.models.registry import (  # noqa: F401
    ModelBundle,
    analytic_param_count,
    batch_spec,
    build_model,
    cache_spec,
    cross_entropy,
    decode_batch_spec,
    synth_batch,
)
