"""Unified decoder-LM assembly for the dense / moe / hybrid / ssm / vlm families.

Layers are stacked (leading L dim) and executed with lax.scan so 64-layer
configs compile one block body; `cfg.remat` wraps the body with jax.checkpoint.
Each family provides three entry points used by launch/steps.py:

    forward(params, batch)              -> (logits, aux_loss)      [train]
    prefill(params, batch)              -> (last_logits, cache)    [serving]
    decode(params, cache, tokens)       -> (logits, cache)         [serving]
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import rwkv as RWKV
from repro.models import ssm as SSM
from repro.models.config import ModelConfig
from repro.models.partitioning import (constrain, constrain_param_tree,
                                       stream_cast)

Pytree = Any


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "full": save only block boundaries


def _stack_layers(key, n: int, init_one):
    return jax.vmap(init_one)(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Block definitions (dense / moe attention blocks; rwkv / mamba mixers)
# ---------------------------------------------------------------------------

def attn_block_init(key, cfg: ModelConfig, use_moe: bool) -> Pytree:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln1": L.norm_init(cfg, cfg.d_model), "ln2": L.norm_init(cfg, cfg.d_model)}
    if cfg.mla is not None:
        p["attn"] = MLA.mla_init(k1, cfg)
    else:
        p["attn"] = L.attention_init(k1, cfg)
    if use_moe:
        p["moe"] = MOE.moe_init(k2, cfg)
    else:
        p["mlp"] = L.mlp_init(k2, cfg)
    return p


def _carry_dims(cfg: ModelConfig):
    return (("batch", "model", None) if cfg.sharding_profile == "fsdp_sp"
            else ("batch", None, None))


def attn_block_apply(p: Pytree, x: jax.Array, cfg: ModelConfig, *,
                     positions, cache: Optional[dict] = None
                     ) -> tuple[jax.Array, jax.Array, Optional[dict]]:
    x = constrain(x, _carry_dims(cfg))
    if cfg.mla is not None:
        h, new_cache = MLA.mla_apply(p["attn"], L.norm_apply(p["ln1"], x, cfg), cfg,
                                     positions=positions, cache=cache)
    else:
        h, new_cache = L.attention_apply(p["attn"], L.norm_apply(p["ln1"], x, cfg),
                                         cfg, positions=positions, cache=cache)
    x = x + h
    h2in = L.norm_apply(p["ln2"], x, cfg)
    if "moe" in p:
        h2, aux = MOE.moe_apply(p["moe"], h2in, cfg)
    else:
        h2, aux = L.mlp_apply(p["mlp"], h2in, cfg), jnp.float32(0.0)
    return x + h2, aux, new_cache


def rwkv_block_init(key, cfg: ModelConfig) -> Pytree:
    k1, k2 = jax.random.split(key)
    return {"ln1": L.norm_init(cfg, cfg.d_model), "ln2": L.norm_init(cfg, cfg.d_model),
            "tm": RWKV.timemix_init(k1, cfg), "cm": RWKV.channelmix_init(k2, cfg)}


def rwkv_block_apply(p: Pytree, x: jax.Array, cfg: ModelConfig, *,
                     cache: Optional[dict] = None
                     ) -> tuple[jax.Array, Optional[dict]]:
    tm_cache = None if cache is None else {"shift": cache["tm_shift"], "wkv": cache["wkv"]}
    h, tm_new = RWKV.timemix_apply(p["tm"], L.norm_apply(p["ln1"], x, cfg), cfg,
                                   cache=tm_cache)
    x = x + h
    cm_cache = None if cache is None else {"shift": cache["cm_shift"]}
    h2, cm_new = RWKV.channelmix_apply(p["cm"], L.norm_apply(p["ln2"], x, cfg), cfg,
                                       cache=cm_cache)
    new_cache = {"tm_shift": tm_new["shift"], "wkv": tm_new["wkv"],
                 "cm_shift": cm_new["shift"]}
    return x + h2, new_cache


def mamba_block_init(key, cfg: ModelConfig) -> Pytree:
    return {"ln": L.norm_init(cfg, cfg.d_model), "mixer": SSM.mamba2_init(key, cfg)}


def mamba_block_apply(p: Pytree, x: jax.Array, cfg: ModelConfig, *,
                      cache: Optional[dict] = None
                      ) -> tuple[jax.Array, Optional[dict]]:
    h, new_cache = SSM.mamba2_apply(p["mixer"], L.norm_apply(p["ln"], x, cfg), cfg,
                                    cache=cache)
    return x + h, new_cache


# zamba2 shared attention block with per-invocation LoRA on wq / wo ------------

def shared_block_init(key, cfg: ModelConfig) -> Pytree:
    k1, k2 = jax.random.split(key)
    return {"ln1": L.norm_init(cfg, cfg.d_model), "ln2": L.norm_init(cfg, cfg.d_model),
            "attn": L.attention_init(k1, cfg), "mlp": L.mlp_init(k2, cfg)}


def shared_lora_init(key, cfg: ModelConfig, n_invocations: int) -> Pytree:
    """Per-invocation low-rank adapters on the shared block's attn and mlp
    branches (zamba2's depth-specialization of the shared weights; DESIGN.md
    notes the simplified adapter placement)."""
    r = cfg.hybrid.lora_rank
    d = cfg.d_model

    def one(k):
        k1, k2 = jax.random.split(k)
        return {"attn_a": L.dense_init(k1, d, r, L.pdtype(cfg)),
                "attn_b": jnp.zeros((r, d), L.pdtype(cfg)),
                "mlp_a": L.dense_init(k2, d, r, L.pdtype(cfg)),
                "mlp_b": jnp.zeros((r, d), L.pdtype(cfg))}

    return _stack_layers(key, n_invocations, one)


def shared_block_apply(shared: Pytree, lora: Pytree, x: jax.Array,
                       cfg: ModelConfig, *, positions,
                       cache: Optional[dict] = None
                       ) -> tuple[jax.Array, Optional[dict]]:
    dt = L.cdtype(cfg)
    xn = L.norm_apply(shared["ln1"], x, cfg)
    h, new_cache = L.attention_apply(shared["attn"], xn, cfg,
                                     positions=positions, cache=cache)
    h = h + jnp.einsum("...d,dr,re->...e", xn, lora["attn_a"].astype(dt),
                       lora["attn_b"].astype(dt))
    x = x + h
    x2n = L.norm_apply(shared["ln2"], x, cfg)
    h2 = L.mlp_apply(shared["mlp"], x2n, cfg)
    h2 = h2 + jnp.einsum("...d,dr,re->...e", x2n, lora["mlp_a"].astype(dt),
                         lora["mlp_b"].astype(dt))
    return x + h2, new_cache


# ===========================================================================
# Model-level assembly
# ===========================================================================

def init_params(key, cfg: ModelConfig) -> Pytree:
    """Full parameter pytree for the decoder-LM families (not enc-dec)."""
    k_embed, k_blocks, k_extra = jax.random.split(key, 3)
    params: dict = {"embedding": L.embedding_init(k_embed, cfg),
                    "final_norm": L.norm_init(cfg, cfg.d_model)}

    if cfg.family in ("dense", "vlm"):
        params["blocks"] = _stack_layers(
            k_blocks, cfg.n_layers, lambda k: attn_block_init(k, cfg, use_moe=False))
    elif cfg.family == "moe":
        nd = cfg.moe.first_dense_layers
        if nd:
            dense_cfg = cfg
            keys = jax.random.split(k_extra, nd)
            # leading dense layers use dense_d_ff
            import dataclasses as _dc
            dcfg = _dc.replace(cfg, d_ff=cfg.moe.dense_d_ff or cfg.d_ff)
            params["dense_blocks"] = [attn_block_init(k, dcfg, use_moe=False)
                                      for k in keys]
        params["blocks"] = _stack_layers(
            k_blocks, cfg.n_layers - nd, lambda k: attn_block_init(k, cfg, use_moe=True))
    elif cfg.family == "ssm":  # rwkv6
        params["blocks"] = _stack_layers(
            k_blocks, cfg.n_layers, lambda k: rwkv_block_init(k, cfg))
    elif cfg.family == "hybrid":  # zamba2
        params["blocks"] = _stack_layers(
            k_blocks, cfg.n_layers, lambda k: mamba_block_init(k, cfg))
        k_sh, k_lora = jax.random.split(k_extra)
        n_inv = _n_shared_invocations(cfg)
        params["shared"] = shared_block_init(k_sh, cfg)
        params["lora"] = shared_lora_init(k_lora, cfg, n_inv)
    else:
        raise ValueError(f"init_params does not handle family {cfg.family!r}")

    if cfg.vision is not None:
        params["projector"] = L.dense_init(
            jax.random.fold_in(k_extra, 7), cfg.vision.clip_dim, cfg.d_model,
            L.pdtype(cfg))
    return params


def _n_shared_invocations(cfg: ModelConfig) -> int:
    return (cfg.n_layers + cfg.hybrid.period - 1) // cfg.hybrid.period


def _embed_inputs(params: Pytree, batch: dict, cfg: ModelConfig) -> jax.Array:
    x = L.embed_tokens(params["embedding"], batch["tokens"], cfg)
    if cfg.vision is not None and "patch_embeds" in batch:
        patches = jnp.einsum("bnc,cd->bnd", batch["patch_embeds"].astype(L.cdtype(cfg)),
                             params["projector"].astype(L.cdtype(cfg)))
        x = jax.lax.dynamic_update_slice(x, patches.astype(x.dtype), (0, 0, 0))
    return x


# --- train/prefill forward --------------------------------------------------

def forward(params: Pytree, batch: dict, cfg: ModelConfig,
            return_caches: bool = False, cache_len: int = 0):
    """Full-sequence forward. Returns (logits, aux_loss[, caches])."""
    params = {**params, "blocks": stream_cast(params["blocks"], cfg)}
    x = _embed_inputs(params, batch, cfg)
    x = constrain(x, _carry_dims(cfg))
    B, S, D = x.shape
    positions = jnp.arange(S)[None, :]
    aux_total = jnp.float32(0.0)
    caches = {}

    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.family == "moe" and cfg.moe.first_dense_layers:
            for p in params["dense_blocks"]:
                import dataclasses as _dc
                dcfg = _dc.replace(cfg, d_ff=cfg.moe.dense_d_ff or cfg.d_ff)
                x, aux, kv = attn_block_apply(p, x, dcfg, positions=positions,
                                              cache=None)
                aux_total += aux

        def body(carry, blk):
            xc, auxc = carry
            blk = constrain_param_tree(blk)  # keep FSDP gathers per-layer
            y, aux, _ = attn_block_apply(blk, xc, cfg, positions=positions)
            return (y, auxc + aux), None

        if cfg.scan_layers:
            (x, aux_total), _ = jax.lax.scan(_remat(body, cfg), (x, aux_total),
                                             constrain_param_tree(params["blocks"]))
        else:
            # unrolled: exact per-layer HLO (roofline cost analysis mode)
            n = jax.tree.leaves(params["blocks"])[0].shape[0]
            for i in range(n):
                blk = jax.tree.map(lambda a: a[i], params["blocks"])
                (x, aux_total), _ = _remat(body, cfg)((x, aux_total), blk)
    elif cfg.family == "ssm":
        def body(carry, blk):
            blk = constrain_param_tree(blk)
            y, _ = rwkv_block_apply(blk, carry, cfg)
            return y, None

        if cfg.scan_layers:
            x, _ = jax.lax.scan(_remat(body, cfg), x,
                                constrain_param_tree(params["blocks"]))
        else:
            n = jax.tree.leaves(params["blocks"])[0].shape[0]
            for i in range(n):
                blk = jax.tree.map(lambda a: a[i], params["blocks"])
                x, _ = _remat(body, cfg)(x, blk)
    elif cfg.family == "hybrid":
        x = _hybrid_forward(params, x, cfg, positions)
    else:
        raise ValueError(cfg.family)

    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.logits_apply(params["embedding"], x, cfg)
    logits = constrain(logits, ("batch", None, "model"))
    return logits, aux_total


def _hybrid_forward(params: Pytree, x: jax.Array, cfg: ModelConfig, positions):
    period = cfg.hybrid.period
    n_inv = _n_shared_invocations(cfg)

    def mamba_body(carry, blk):
        blk = constrain_param_tree(blk)
        y, _ = mamba_block_apply(blk, carry, cfg)
        return y, None

    body = _remat(mamba_body, cfg)
    for g in range(n_inv):
        lora_g = jax.tree.map(lambda a: a[g], params["lora"])
        x, _ = shared_block_apply(params["shared"], lora_g, x, cfg,
                                  positions=positions)
        lo, hi = g * period, min((g + 1) * period, cfg.n_layers)
        seg = jax.tree.map(lambda a: a[lo:hi], params["blocks"])
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, constrain_param_tree(seg))
        else:
            for i in range(hi - lo):
                blk = jax.tree.map(lambda a: a[i], seg)
                x, _ = body(x, blk)
    return x


# --- serving: prefill + single-token decode ---------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, pos: int = 0) -> Pytree:
    """Concrete zero cache (tests / serving). Structure mirrors what prefill
    emits; launch.input_specs builds the abstract twin for the dry-run."""
    cdt = jnp.dtype(cfg.compute_dtype)
    hd = cfg.resolved_head_dim

    def attn_kv():
        return {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), cdt),
                "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), cdt)}

    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.mla is not None:
            m = cfg.mla
            layer = {"c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), cdt),
                     "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), cdt)}
        else:
            layer = attn_kv()
        n_scan = cfg.n_layers - (cfg.moe.first_dense_layers if cfg.moe else 0)
        layers = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_scan, *a.shape)), layer)
        cache = {"layers": layers, "pos": jnp.asarray(pos, jnp.int32)}
        if cfg.moe and cfg.moe.first_dense_layers:
            # the leading dense layers share the attention kind (MLA for
            # deepseek), so their cache mirrors the scanned-layer structure
            cache["dense_layers"] = [jax.tree.map(jnp.copy, layer)
                                     for _ in range(cfg.moe.first_dense_layers)]
        return cache
    if cfg.family == "ssm":
        from repro.models.rwkv import rwkv_cache_shape
        layer = rwkv_cache_shape(cfg, batch)
        layers = jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)),
                              layer)
        return {"layers": layers, "pos": jnp.asarray(pos, jnp.int32)}
    if cfg.family == "hybrid":
        from repro.models.ssm import mamba2_cache_shape
        layer = mamba2_cache_shape(cfg, batch)
        layers = jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)),
                              layer)
        n_inv = _n_shared_invocations(cfg)
        shared = jax.tree.map(lambda a: jnp.broadcast_to(a, (n_inv, *a.shape)),
                              attn_kv())
        return {"layers": layers, "shared": shared, "pos": jnp.asarray(pos, jnp.int32)}
    raise ValueError(cfg.family)



def prefill(params: Pytree, batch: dict, cfg: ModelConfig, pad_to: int = 0):
    """Run the prompt; return (logits, cache) with cache length max(S, pad_to).

    Mixers always emit their cache material on the no-cache path (k/v, latent,
    ssm/conv state, shift states); prefill pads attention k/v into max_len
    buffers and stamps pos = S.
    """
    params = {**params, "blocks": stream_cast(params["blocks"], cfg)}
    x = _embed_inputs(params, batch, cfg)
    B, S, D = x.shape
    max_len = max(S, pad_to)
    positions = jnp.arange(S)[None, :]

    def pad_seq(kv):
        """(B, S, ...) -> (B, max_len, ...) zero-padded on the seq axis."""
        if max_len == S:
            return kv
        pad = [(0, 0)] * kv.ndim
        pad[1] = (0, max_len - S)
        return jnp.pad(kv, pad)

    cache: dict = {"pos": jnp.asarray(S, jnp.int32)}

    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.family == "moe" and cfg.moe.first_dense_layers:
            import dataclasses as _dc
            dcfg = _dc.replace(cfg, d_ff=cfg.moe.dense_d_ff or cfg.d_ff)
            dense_caches = []
            for p in params["dense_blocks"]:
                x, _, kv = attn_block_apply(p, x, dcfg, positions=positions)
                dense_caches.append(jax.tree.map(pad_seq, kv))
            cache["dense_layers"] = dense_caches

        def body(xc, blk):
            blk = constrain_param_tree(blk)
            y, _, kv = attn_block_apply(blk, xc, cfg, positions=positions)
            return y, jax.tree.map(pad_seq, kv)

        x, layer_caches = jax.lax.scan(body, x,
                                       constrain_param_tree(params["blocks"]))
        cache["layers"] = layer_caches
    elif cfg.family == "ssm":
        def body(xc, blk):
            y, c = rwkv_block_apply(blk, xc, cfg)
            return y, c

        x, layer_caches = jax.lax.scan(body, x, params["blocks"])
        cache["layers"] = layer_caches
    elif cfg.family == "hybrid":
        period = cfg.hybrid.period
        n_inv = _n_shared_invocations(cfg)

        def body(xc, blk):
            y, c = mamba_block_apply(blk, xc, cfg)
            return y, c

        seg_caches, shared_caches = [], []
        for g in range(n_inv):
            lora_g = jax.tree.map(lambda a: a[g], params["lora"])
            x, kv = shared_block_apply(params["shared"], lora_g, x, cfg,
                                       positions=positions)
            shared_caches.append(jax.tree.map(pad_seq, kv))
            lo, hi = g * period, min((g + 1) * period, cfg.n_layers)
            seg = jax.tree.map(lambda a: a[lo:hi], params["blocks"])
            x, cseg = jax.lax.scan(body, x, seg)
            seg_caches.append(cseg)
        cache["layers"] = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *seg_caches)
        cache["shared"] = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *shared_caches)
    else:
        raise ValueError(cfg.family)

    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.logits_apply(params["embedding"], x[:, -1:], cfg)
    return logits, cache


def decode(params: Pytree, cache: Pytree, batch: dict, cfg: ModelConfig):
    """One decode step: batch["tokens"] (B, 1) -> (logits (B,1,V), new cache)."""
    params = {**params, "blocks": stream_cast(params["blocks"], cfg)}
    x = L.embed_tokens(params["embedding"], batch["tokens"], cfg)
    B, S_new, D = x.shape
    pos = cache["pos"]
    positions = pos + jnp.arange(S_new)[None, :]
    new_cache: dict = {"pos": pos + S_new}

    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.family == "moe" and cfg.moe.first_dense_layers:
            import dataclasses as _dc
            dcfg = _dc.replace(cfg, d_ff=cfg.moe.dense_d_ff or cfg.d_ff)
            new_dense = []
            for p, c in zip(params["dense_blocks"], cache["dense_layers"]):
                x, _, cn = attn_block_apply(p, x, dcfg, positions=positions,
                                            cache={**c, "pos": pos})
                new_dense.append({k: cn[k] for k in c})
            new_cache["dense_layers"] = new_dense

        def body(xc, scanned):
            blk, c = scanned
            blk = constrain_param_tree(blk)
            y, _, cn = attn_block_apply(blk, xc, cfg, positions=positions,
                                        cache={**c, "pos": pos})
            return y, {k: cn[k] for k in c}

        x, layers = jax.lax.scan(
            body, x, (constrain_param_tree(params["blocks"]), cache["layers"]))
        new_cache["layers"] = layers
    elif cfg.family == "ssm":
        def body(xc, scanned):
            blk, c = scanned
            y, cn = rwkv_block_apply(blk, xc, cfg, cache=c)
            return y, cn

        x, layers = jax.lax.scan(body, x, (params["blocks"], cache["layers"]))
        new_cache["layers"] = layers
    elif cfg.family == "hybrid":
        period = cfg.hybrid.period
        n_inv = _n_shared_invocations(cfg)

        def body(xc, scanned):
            blk, c = scanned
            y, cn = mamba_block_apply(blk, xc, cfg, cache=c)
            return y, cn

        seg_caches, shared_caches = [], []
        for g in range(n_inv):
            lora_g = jax.tree.map(lambda a: a[g], params["lora"])
            shc = jax.tree.map(lambda a: a[g], cache["shared"])
            x, shn = shared_block_apply(params["shared"], lora_g, x, cfg,
                                        positions=positions,
                                        cache={**shc, "pos": pos})
            shared_caches.append({k: shn[k] for k in shc})
            lo, hi = g * period, min((g + 1) * period, cfg.n_layers)
            seg_p = jax.tree.map(lambda a: a[lo:hi], params["blocks"])
            seg_c = jax.tree.map(lambda a: a[lo:hi], cache["layers"])
            x, cseg = jax.lax.scan(body, x, (seg_p, seg_c))
            seg_caches.append(cseg)
        new_cache["layers"] = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *seg_caches)
        new_cache["shared"] = jax.tree.map(lambda *xs: jnp.stack(xs, 0),
                                           *shared_caches)
    else:
        raise ValueError(cfg.family)

    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.logits_apply(params["embedding"], x, cfg)
    return logits, new_cache
