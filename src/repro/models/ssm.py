"""Mamba2 (SSD) block for the zamba2 hybrid architecture.

Sequence mixing delegates to repro.kernels.ops.mamba2_mix (chunked SSD —
Pallas on TPU, jnp mirror elsewhere). The input projection is split per
segment (z / x / BC / dt) so tensor-parallel sharding never straddles segment
boundaries; the depthwise causal conv uses explicit shifts so the decode path
can carry a (width-1)-deep conv cache.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import cdtype, dense_init, pdtype
from repro.models.partitioning import constrain

Pytree = Any


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    bc_dim = 2 * s.n_groups * s.d_state
    return s, d_inner, n_heads, bc_dim


def mamba2_init(key, cfg: ModelConfig) -> Pytree:
    s, d_inner, n_heads, bc_dim = _dims(cfg)
    keys = jax.random.split(key, 6)
    return {
        "wz": dense_init(keys[0], cfg.d_model, d_inner, pdtype(cfg)),
        "wx": dense_init(keys[1], cfg.d_model, d_inner, pdtype(cfg)),
        "wbc": dense_init(keys[2], cfg.d_model, bc_dim, pdtype(cfg)),
        "wdt": dense_init(keys[3], cfg.d_model, n_heads, pdtype(cfg)),
        "conv_x_w": (jax.random.normal(keys[4], (s.d_conv, d_inner), jnp.float32)
                     / math.sqrt(s.d_conv)).astype(pdtype(cfg)),
        "conv_x_b": jnp.zeros((d_inner,), pdtype(cfg)),
        "conv_bc_w": (jax.random.normal(jax.random.fold_in(keys[4], 1),
                                        (s.d_conv, bc_dim), jnp.float32)
                      / math.sqrt(s.d_conv)).astype(pdtype(cfg)),
        "conv_bc_b": jnp.zeros((bc_dim,), pdtype(cfg)),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(pdtype(cfg)),
        "d_skip": jnp.ones((n_heads,), pdtype(cfg)),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((n_heads,), 0.01))).astype(pdtype(cfg)),
        "gate_norm_scale": jnp.ones((d_inner,), pdtype(cfg)),
        "w_out": dense_init(keys[5], d_inner, cfg.d_model, pdtype(cfg),
                            scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }


def _causal_conv(xin: jax.Array, w: jax.Array, b: jax.Array,
                 conv_state: Optional[jax.Array] = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv via explicit shifts. xin (B,S,C); w (W,C).

    conv_state (B,W-1,C) holds the previous W-1 inputs (decode). Returns
    (silu(conv(x)+b), new_conv_state)."""
    W = w.shape[0]
    B, S, C = xin.shape
    if conv_state is None:
        conv_state = jnp.zeros((B, W - 1, C), xin.dtype)
    padded = jnp.concatenate([conv_state, xin], axis=1)      # (B, S+W-1, C)
    y = jnp.zeros((B, S, C), jnp.float32)
    for i in range(W):
        y = y + padded[:, i:i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    y = jax.nn.silu(y + b.astype(jnp.float32)).astype(xin.dtype)
    new_state = padded[:, S:]                                # last W-1 inputs
    return y, new_state


def mamba2_apply(params: Pytree, x: jax.Array, cfg: ModelConfig, *,
                 cache: Optional[dict] = None
                 ) -> tuple[jax.Array, dict]:
    """x (B,S,D) -> (y, cache'). cache: {"conv_x","conv_bc","ssm"}."""
    from repro.kernels import ops

    s, d_inner, n_heads, bc_dim = _dims(cfg)
    dt_c = cdtype(cfg)
    B, S, D = x.shape
    sp = cfg.sharding_profile == "fsdp_sp"
    # fsdp_sp: sequence-sharded activations, full channels (weights gathered
    # per layer); tp: d_inner/channel tensor parallelism (Megatron-style)
    x = constrain(x, ("batch", "model", None) if sp else ("batch", None, None))
    wide = ("batch", "model", None) if sp else ("batch", None, "model")
    z = constrain(jnp.einsum("bsd,dk->bsk", x, params["wz"].astype(dt_c)), wide)
    xs = constrain(jnp.einsum("bsd,dk->bsk", x, params["wx"].astype(dt_c)), wide)
    bc = constrain(jnp.einsum("bsd,dk->bsk", x, params["wbc"].astype(dt_c)), wide)
    dt_raw = constrain(jnp.einsum("bsd,dk->bsk", x, params["wdt"].astype(dt_c)),
                       wide)

    xs, new_conv_x = _causal_conv(xs, params["conv_x_w"], params["conv_x_b"],
                                  cache["conv_x"] if cache else None)
    bc, new_conv_bc = _causal_conv(bc, params["conv_bc_w"], params["conv_bc_b"],
                                   cache["conv_bc"] if cache else None)
    b, c = jnp.split(bc, 2, axis=-1)
    b = b.reshape(B, S, s.n_groups, s.d_state)
    c = c.reshape(B, S, s.n_groups, s.d_state)
    xh = xs.reshape(B, S, n_heads, s.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))

    if cache is None:
        y, final_state = ops.mamba2_mix(xh, dt, a, b, c,
                                        params["d_skip"].astype(jnp.float32),
                                        chunk=s.chunk_size)
    else:
        y, final_state = ops.mamba2_decode_step(
            xh, dt, a, b, c, params["d_skip"].astype(jnp.float32),
            state=cache["ssm"])
    # final state + conv tails double as the prefill cache (DCE'd in training)
    new_cache = {"conv_x": new_conv_x, "conv_bc": new_conv_bc, "ssm": final_state}

    y = y.reshape(B, S, d_inner)
    # gated RMSNorm (Mamba2's norm-before-out-proj)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    yf = yf * jax.lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + 1e-6)
    y = (yf * params["gate_norm_scale"].astype(jnp.float32)).astype(dt_c)
    out = jnp.einsum("bsk,kd->bsd", y, params["w_out"].astype(dt_c))
    return out, new_cache


def mamba2_cache_shape(cfg: ModelConfig, batch: int) -> dict:
    """Abstract zero-cache spec for one mamba layer."""
    s, d_inner, n_heads, bc_dim = _dims(cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    return {
        "conv_x": jnp.zeros((batch, s.d_conv - 1, d_inner), cdt),
        "conv_bc": jnp.zeros((batch, s.d_conv - 1, bc_dim), cdt),
        "ssm": jnp.zeros((batch, n_heads, s.head_dim, s.d_state), jnp.float32),
    }
