"""Model bundles: uniform (init / loss / forward / prefill / decode / specs)
surface consumed by launch/steps.py, the dry-run, tests and benchmarks.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.config import ModelConfig, ShapeSpec
from repro.utils import trees

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init: Callable[[jax.Array], Pytree]
    forward: Callable[[Pytree, dict], tuple[jax.Array, jax.Array]]
    prefill: Callable[..., tuple[jax.Array, Pytree]]
    decode: Callable[[Pytree, Pytree, dict], tuple[jax.Array, Pytree]]
    init_cache: Callable[..., Pytree]

    def loss_fn(self, params: Pytree, batch: dict, rng: jax.Array
                ) -> tuple[jax.Array, dict]:
        """Next-token cross entropy + MoE aux loss (the repro.core protocol)."""
        logits, aux_loss = self.forward(params, batch)
        ce = cross_entropy(logits, batch["labels"])
        return ce + aux_loss, {"ce": ce, "moe_aux": aux_loss, "logits": logits}


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Stable CE over a (possibly vocab-sharded) logits tensor; labels<0 masked.

    The label logit is picked with an iota==label masked sum instead of
    take_along_axis: elementwise ops preserve the vocab ("model"-axis) sharding
    under pjit, where a gather would all-gather the full-vocab logits per
    device (observed 80+GB/device in the dry-run).
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    onehot = (vocab_iota == jnp.maximum(labels, 0)[..., None])
    picked = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - picked) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def build_model(cfg: ModelConfig) -> ModelBundle:
    if cfg.family == "audio":
        return ModelBundle(
            cfg=cfg,
            init=lambda key: encdec.init_params(key, cfg),
            forward=lambda p, b: encdec.forward(p, b, cfg),
            prefill=lambda p, b, pad_to=0: encdec.prefill(p, b, cfg, pad_to=pad_to),
            decode=lambda p, c, b: encdec.decode(p, c, b, cfg),
            init_cache=lambda batch, max_len, pos=0: _encdec_cache(cfg, batch,
                                                                   max_len, pos),
        )
    return ModelBundle(
        cfg=cfg,
        init=lambda key: transformer.init_params(key, cfg),
        forward=lambda p, b: transformer.forward(p, b, cfg),
        prefill=lambda p, b, pad_to=0: transformer.prefill(p, b, cfg, pad_to=pad_to),
        decode=lambda p, c, b: transformer.decode(p, c, b, cfg),
        init_cache=lambda batch, max_len, pos=0: transformer.init_cache(
            cfg, batch, max_len, pos),
    )


def _encdec_cache(cfg: ModelConfig, batch: int, max_len: int, pos: int) -> Pytree:
    cdt = jnp.dtype(cfg.compute_dtype)
    hd = cfg.resolved_head_dim
    enc_len = whisper_enc_len(cfg, max_len)
    layer = {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), cdt),
             "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), cdt),
             "cross_k": jnp.zeros((batch, enc_len, cfg.n_kv_heads, hd), cdt),
             "cross_v": jnp.zeros((batch, enc_len, cfg.n_kv_heads, hd), cdt)}
    layers = jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)),
                          layer)
    return {"layers": layers, "pos": jnp.asarray(pos, jnp.int32)}


def whisper_enc_len(cfg: ModelConfig, dec_len: int) -> int:
    """Encoder frames per cell: whisper's native 1500 for decode cells, the
    cell's seq_len for train/prefill stress shapes (DESIGN.md §4)."""
    return min(int(dec_len * cfg.encdec.enc_len_ratio), dec_len)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs for the dry-run) and concrete batch synthesis
# ---------------------------------------------------------------------------

def batch_spec(cfg: ModelConfig, shape: ShapeSpec,
               ascent_fraction: float = 0.0) -> dict:
    """Abstract train/prefill batch (ShapeDtypeStruct leaves)."""
    b, s = shape.global_batch, shape.seq_len
    spec = _one_batch_spec(cfg, b, s)
    if shape.kind == "train" and ascent_fraction > 0:
        bp = max(1, int(round(b * ascent_fraction)))
        spec["ascent"] = _one_batch_spec(cfg, bp, s)
    return spec


def _one_batch_spec(cfg: ModelConfig, b: int, s: int) -> dict:
    i32 = jnp.int32
    cdt = jnp.dtype(cfg.compute_dtype)
    spec = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32)}
    if cfg.vision is not None:
        spec["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vision.n_image_tokens, cfg.vision.clip_dim), cdt)
    if cfg.family == "audio":
        spec["enc_frames"] = jax.ShapeDtypeStruct(
            (b, whisper_enc_len(cfg, s), cfg.d_model), cdt)
    return spec


def decode_batch_spec(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    return {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)}


def cache_spec(cfg: ModelConfig, shape: ShapeSpec) -> Pytree:
    """Abstract decode cache with pos = seq_len - 1 (one slot left)."""
    bundle_cache = jax.eval_shape(
        lambda: build_model(cfg).init_cache(shape.global_batch, shape.seq_len,
                                            pos=shape.seq_len - 1))
    return bundle_cache


def synth_batch(cfg: ModelConfig, b: int, s: int, key: jax.Array,
                ascent_fraction: float = 0.0) -> dict:
    """Concrete random batch matching batch_spec (smoke tests, benchmarks)."""
    k1, k2, k3 = jax.random.split(key, 3)
    batch = _synth_one(cfg, b, s, k1)
    if ascent_fraction > 0:
        bp = max(1, int(round(b * ascent_fraction)))
        batch["ascent"] = _synth_one(cfg, bp, s, k2)
    return batch


def _synth_one(cfg: ModelConfig, b: int, s: int, key: jax.Array) -> dict:
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (b, s), 0, cfg.vocab_size, jnp.int32)
    labels = jnp.roll(tokens, -1, axis=1).at[:, -1].set(-1)
    batch = {"tokens": tokens, "labels": labels}
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.vision is not None:
        batch["patch_embeds"] = jax.random.normal(
            k2, (b, cfg.vision.n_image_tokens, cfg.vision.clip_dim), cdt)
    if cfg.family == "audio":
        batch["enc_frames"] = jax.random.normal(
            k2, (b, whisper_enc_len(cfg, s), cfg.d_model), cdt)
    return batch


# ---------------------------------------------------------------------------
# Analytic parameter counts (roofline 6ND sanity)
# ---------------------------------------------------------------------------

def analytic_param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact count via abstract init; `active_only` subtracts inactive experts."""
    bundle = build_model(cfg)
    shapes = jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0)))
    total = trees.tree_size(shapes)
    if active_only and cfg.moe is not None:
        e, k = cfg.moe.n_experts, cfg.moe.top_k
        expert_params = 3 * cfg.d_model * cfg.moe.expert_d_ff
        n_moe_layers = cfg.n_layers - cfg.moe.first_dense_layers
        total -= n_moe_layers * (e - k) * expert_params
    return int(total)
