"""Core neural-net layers (pure-functional, dict-parameterized).

Conventions:
* every module is an (init, apply) pair of free functions;
* parameter leaves are named so `repro.launch.sharding` can assign
  PartitionSpecs by path suffix (wq/wk/wv/wo/wi/wg/wo_mlp/embed/...);
* compute runs in `cfg.compute_dtype` (bf16 on TPU), parameters are stored in
  `cfg.param_dtype` (fp32 master copies) and cast at use.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.partitioning import constrain, constrain_first_fit

Pytree = Any


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0) -> jax.Array:
    """Truncated-normal fan-in init (0.02-style for embeddings handled separately)."""
    std = scale / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out), jnp.float32)
            * std).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, d: int) -> Pytree:
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((d,), pdtype(cfg))}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), pdtype(cfg)),
                "bias": jnp.zeros((d,), pdtype(cfg))}
    if cfg.norm == "nonparam_ln":      # OLMo: non-parametric LayerNorm
        return {}
    raise ValueError(cfg.norm)


def norm_apply(params: Pytree, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + 1e-6)
        y = y * params["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        if cfg.norm == "layernorm":
            y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_headwise(x: jax.Array) -> jax.Array:
    """Parameter-free RMS over the trailing (head) dim — qwen3 qk_norm."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + 1e-6)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embedding_init(key, cfg: ModelConfig) -> Pytree:
    p = {"embed": (jax.random.normal(key, (cfg.vocab_size, cfg.d_model), jnp.float32)
                   * 0.02).astype(pdtype(cfg))}
    if not cfg.tie_embeddings:
        key2 = jax.random.fold_in(key, 1)
        p["unembed"] = dense_init(key2, cfg.d_model, cfg.vocab_size, pdtype(cfg))
    return p


def embed_tokens(params: Pytree, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = params["embed"].astype(cdtype(cfg))[tokens]
    return x


def logits_apply(params: Pytree, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embed"].astype(cdtype(cfg)).T
    else:
        w = params["unembed"].astype(cdtype(cfg))
    logits = jnp.einsum("...d,dv->...v", x, w)
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits.astype(jnp.float32) / c).astype(logits.dtype)
    return logits


# ---------------------------------------------------------------------------
# Gated / plain MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Pytree:
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"wi": dense_init(k1, cfg.d_model, d_ff, pdtype(cfg)),
         "wo_mlp": dense_init(k2, d_ff, cfg.d_model, pdtype(cfg))}
    if cfg.mlp_gated:
        p["wg"] = dense_init(k3, cfg.d_model, d_ff, pdtype(cfg))
    return p


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "relu2":
        return jnp.square(jax.nn.relu(x))
    raise ValueError(kind)


def mlp_apply(params: Pytree, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = cdtype(cfg)
    h = jnp.einsum("...d,df->...f", x, params["wi"].astype(dt))
    h = constrain(h, ("batch", "model", None) if cfg.sharding_profile == "fsdp_sp"
                  else ("batch", None, "model"))
    h = _act(h, cfg.act)
    if cfg.mlp_gated:
        h = h * jnp.einsum("...d,df->...f", x, params["wg"].astype(dt))
    return jnp.einsum("...f,fd->...d", h, params["wo_mlp"].astype(dt))


# ---------------------------------------------------------------------------
# Attention (MHA / GQA / MQA) with optional cache
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig, d_model: Optional[int] = None,
                   cross: bool = False) -> Pytree:
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"wq": dense_init(k1, d, cfg.n_heads * hd, pdtype(cfg)),
         "wk": dense_init(k2, d, cfg.n_kv_heads * hd, pdtype(cfg)),
         "wv": dense_init(k3, d, cfg.n_kv_heads * hd, pdtype(cfg)),
         "wo": dense_init(k4, cfg.n_heads * hd, d, pdtype(cfg),
                          scale=1.0 / math.sqrt(2 * cfg.n_layers))}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), pdtype(cfg))
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), pdtype(cfg))
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), pdtype(cfg))
    return p


def _project_qkv(params: Pytree, xq: jax.Array, xkv: jax.Array, cfg: ModelConfig):
    dt = cdtype(cfg)
    hd = cfg.resolved_head_dim
    q = jnp.einsum("...d,dh->...h", xq, params["wq"].astype(dt))
    k = jnp.einsum("...d,dh->...h", xkv, params["wk"].astype(dt))
    v = jnp.einsum("...d,dh->...h", xkv, params["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    # prefer head TP; fall back to query-sequence (context) parallelism when
    # the head count does not divide the model axis (e.g. 40 heads on 16)
    q = q.reshape(*q.shape[:-1], cfg.n_heads, hd)
    k = k.reshape(*k.shape[:-1], cfg.n_kv_heads, hd)
    v = v.reshape(*v.shape[:-1], cfg.n_kv_heads, hd)
    if cfg.sharding_profile == "fsdp_sp":
        # context parallelism: queries sharded on seq, kv full-seq (flash
        # streams all kv blocks); kv gathers are one layer at a time
        q = constrain(q, ("batch", "model", None, None))
        k = constrain(k, ("batch", None, None, None))
        v = constrain(v, ("batch", None, None, None))
    else:
        q = constrain(q, ("batch", None, "model", None))
        k = constrain(k, ("batch", None, "model", None))
        v = constrain(v, ("batch", None, "model", None))
    return q, k, v


def attention_apply(params: Pytree, x: jax.Array, cfg: ModelConfig, *,
                    positions: jax.Array,
                    causal: bool = True,
                    use_rope: bool = True,
                    cache: Optional[dict] = None,
                    x_cross: Optional[jax.Array] = None) -> tuple[jax.Array, Optional[dict]]:
    """Self- or cross-attention.

    x: (B, S, D). `cache` (decode): {"k": (B, S_max, K, hd), "v": ..., "pos": scalar}
    — new k/v are written at `pos`, attention runs over the full cache with a
    validity mask. Returns (out, updated_cache_or_None).
    """
    from repro.kernels import ops  # local import to avoid cycles

    xkv = x if x_cross is None else x_cross
    q, k, v = _project_qkv(params, x, xkv, cfg)
    if cfg.qk_norm:
        q, k = rms_norm_headwise(q), rms_norm_headwise(k)
    if use_rope and x_cross is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if cache is not None and x_cross is None:
        # decode: append new kv at cache["pos"], attend over cache
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype),
                                                 cache["pos"], axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype),
                                                 cache["pos"], axis=1)
        out = ops.decode_attention(q, kc, vc, cache["pos"] + x.shape[1],
                                   window=cfg.sliding_window)
        new_cache = {"k": kc, "v": vc, "pos": cache["pos"] + x.shape[1]}
    else:
        out = ops.flash_attention(q, k, v, causal=causal and x_cross is None,
                                  window=cfg.sliding_window)
        # expose this segment's k/v so prefill can build the decode cache
        # (dead-code-eliminated by XLA in the train path)
        new_cache = {"k": k, "v": v}

    if cfg.sharding_profile == "fsdp_sp":
        out = constrain(out, ("batch", "model", None, None))
    else:
        out = constrain(out, ("batch", None, "model", None))
    out = out.reshape(*out.shape[:-2], cfg.n_heads * cfg.resolved_head_dim)
    out = jnp.einsum("...h,hd->...d", out, params["wo"].astype(cdtype(cfg)))
    return out, new_cache
