"""Whisper-style encoder-decoder backbone (audio family).

The conv/mel frontend is a stub per the assignment: `input_specs` supplies
precomputed frame embeddings (B, S_enc, d_model); a learned adapter keeps a
parameterized frontend boundary. Positions are sinusoidal (no rope), norms are
LayerNorm, MLPs are plain GELU — whisper's layout. The decoder carries a self-
attention KV cache plus per-layer cross-attention K/V computed once at prefill.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.partitioning import constrain_param_tree
from repro.models.transformer import _remat, _stack_layers

Pytree = Any


def _sinusoid(seq: int, d: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10000.0) * dim / (d // 2))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_block_init(key, cfg: ModelConfig) -> Pytree:
    k1, k2 = jax.random.split(key)
    return {"ln1": L.norm_init(cfg, cfg.d_model), "ln2": L.norm_init(cfg, cfg.d_model),
            "attn": L.attention_init(k1, cfg), "mlp": L.mlp_init(k2, cfg)}


def _dec_block_init(key, cfg: ModelConfig) -> Pytree:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": L.norm_init(cfg, cfg.d_model), "ln2": L.norm_init(cfg, cfg.d_model),
            "ln3": L.norm_init(cfg, cfg.d_model),
            "self_attn": L.attention_init(k1, cfg),
            "cross_attn": L.attention_init(k2, cfg),
            "mlp": L.mlp_init(k3, cfg)}


def init_params(key, cfg: ModelConfig) -> Pytree:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "frontend_adapter": L.dense_init(k1, cfg.d_model, cfg.d_model, L.pdtype(cfg)),
        "enc_blocks": _stack_layers(k2, cfg.encdec.n_encoder_layers,
                                    lambda k: _enc_block_init(k, cfg)),
        "enc_norm": L.norm_init(cfg, cfg.d_model),
        "embedding": L.embedding_init(k3, cfg),
        "dec_blocks": _stack_layers(k4, cfg.n_layers,
                                    lambda k: _dec_block_init(k, cfg)),
        "final_norm": L.norm_init(cfg, cfg.d_model),
    }


def encode(params: Pytree, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = L.cdtype(cfg)
    x = jnp.einsum("bsd,de->bse", frames.astype(dt),
                   params["frontend_adapter"].astype(dt))
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(dt)[None]
    positions = jnp.arange(x.shape[1])[None, :]

    def body(xc, blk):
        blk = constrain_param_tree(blk)
        h, _ = L.attention_apply(blk["attn"], L.norm_apply(blk["ln1"], xc, cfg),
                                 cfg, positions=positions, causal=False,
                                 use_rope=False)
        xc = xc + h
        xc = xc + L.mlp_apply(blk["mlp"], L.norm_apply(blk["ln2"], xc, cfg), cfg)
        return xc, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(_remat(body, cfg), x,
                            constrain_param_tree(params["enc_blocks"]))
    else:
        n = jax.tree.leaves(params["enc_blocks"])[0].shape[0]
        for i in range(n):
            blk = jax.tree.map(lambda a: a[i], params["enc_blocks"])
            x, _ = _remat(body, cfg)(x, blk)
    return L.norm_apply(params["enc_norm"], x, cfg)


def _dec_block_apply(blk: Pytree, x: jax.Array, enc_out: Optional[jax.Array],
                     cfg: ModelConfig, *, positions,
                     cache: Optional[dict] = None):
    """Returns (y, new_self_kv, cross_kv). `cache` holds {"k","v","pos",
    "cross_k","cross_v"} in decode; None at train/prefill (cross kv derived)."""
    self_cache = None if cache is None else {
        "k": cache["k"], "v": cache["v"], "pos": cache["pos"]}
    h, self_kv = L.attention_apply(blk["self_attn"],
                                   L.norm_apply(blk["ln1"], x, cfg), cfg,
                                   positions=positions, cache=self_cache,
                                   use_rope=False)
    x = x + h
    xn = L.norm_apply(blk["ln2"], x, cfg)
    if cache is None:
        h, cross_kv = L.attention_apply(blk["cross_attn"], xn, cfg,
                                        positions=positions, causal=False,
                                        use_rope=False, x_cross=enc_out)
    else:
        # decode: attend over the stored cross k/v (no growth, no mask)
        from repro.kernels import ops
        q, _, _ = L._project_qkv(blk["cross_attn"], xn, xn, cfg)
        kx, vx = cache["cross_k"], cache["cross_v"]
        h = ops.decode_attention(q, kx, vx, jnp.asarray(kx.shape[1], jnp.int32))
        h = h.reshape(*h.shape[:-2], cfg.n_heads * cfg.resolved_head_dim)
        h = jnp.einsum("...h,hd->...d", h,
                       blk["cross_attn"]["wo"].astype(L.cdtype(cfg)))
        cross_kv = None
    x = x + h
    x = x + L.mlp_apply(blk["mlp"], L.norm_apply(blk["ln3"], x, cfg), cfg)
    return x, self_kv, cross_kv


def forward(params: Pytree, batch: dict, cfg: ModelConfig):
    """Training forward: (logits over decoder positions, aux=0)."""
    enc_out = encode(params, batch["enc_frames"], cfg)
    x = L.embed_tokens(params["embedding"], batch["tokens"], cfg)
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    positions = jnp.arange(x.shape[1])[None, :]

    def body(xc, blk):
        blk = constrain_param_tree(blk)
        y, _, _ = _dec_block_apply(blk, xc, enc_out, cfg, positions=positions)
        return y, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(_remat(body, cfg), x,
                            constrain_param_tree(params["dec_blocks"]))
    else:
        n = jax.tree.leaves(params["dec_blocks"])[0].shape[0]
        for i in range(n):
            blk = jax.tree.map(lambda a: a[i], params["dec_blocks"])
            x, _ = _remat(body, cfg)(x, blk)
    x = L.norm_apply(params["final_norm"], x, cfg)
    return L.logits_apply(params["embedding"], x, cfg), jnp.float32(0.0)


def prefill(params: Pytree, batch: dict, cfg: ModelConfig, pad_to: int = 0):
    enc_out = encode(params, batch["enc_frames"], cfg)
    x = L.embed_tokens(params["embedding"], batch["tokens"], cfg)
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    B, S, D = x.shape
    max_len = max(S, pad_to)
    positions = jnp.arange(S)[None, :]

    def pad_seq(kv):
        if max_len == S:
            return kv
        pad = [(0, 0)] * kv.ndim
        pad[1] = (0, max_len - S)
        return jnp.pad(kv, pad)

    def body(xc, blk):
        y, self_kv, cross_kv = _dec_block_apply(blk, xc, enc_out, cfg,
                                                positions=positions)
        return y, {"k": pad_seq(self_kv["k"]), "v": pad_seq(self_kv["v"]),
                   "cross_k": cross_kv["k"], "cross_v": cross_kv["v"]}

    x, layers = jax.lax.scan(body, x, params["dec_blocks"])
    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.logits_apply(params["embedding"], x[:, -1:], cfg)
    return logits, {"layers": layers, "pos": jnp.asarray(S, jnp.int32)}


def decode(params: Pytree, cache: Pytree, batch: dict, cfg: ModelConfig):
    x = L.embed_tokens(params["embedding"], batch["tokens"], cfg)
    B, S_new, D = x.shape
    pos = cache["pos"]
    # sinusoidal position of the new token
    x = x + _sinusoid_at(pos, cfg.d_model, S_new).astype(x.dtype)
    positions = pos + jnp.arange(S_new)[None, :]

    def body(xc, scanned):
        blk, c = scanned
        y, self_kv, _ = _dec_block_apply(blk, xc, None, cfg, positions=positions,
                                         cache={**c, "pos": pos})
        return y, {"k": self_kv["k"], "v": self_kv["v"],
                   "cross_k": c["cross_k"], "cross_v": c["cross_v"]}

    x, layers = jax.lax.scan(body, x, (params["dec_blocks"], cache["layers"]))
    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.logits_apply(params["embedding"], x, cfg)
    return logits, {"layers": layers, "pos": pos + S_new}


def _sinusoid_at(pos: jax.Array, d: int, n: int) -> jax.Array:
    p = (pos + jnp.arange(n))[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10000.0) * dim / (d // 2))
    ang = p * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[None]
