"""RWKV6 ("Finch") blocks: time-mix with data-dependent decay + channel-mix.

The wkv recurrence delegates to repro.kernels.ops.rwkv6_mix. Token-shift
lerps use static per-channel mix coefficients (RWKV5 form); the decay w is
data-dependent through a low-rank MLP — the RWKV6 signature feature called out
in the assignment. Decode carries shift states and the per-head wkv state.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import cdtype, dense_init, pdtype
from repro.models.partitioning import constrain

Pytree = Any


def _dims(cfg: ModelConfig):
    r = cfg.rwkv
    n_heads = cfg.d_model // r.head_dim
    return r, n_heads


def timemix_init(key, cfg: ModelConfig) -> Pytree:
    r, n_heads = _dims(cfg)
    d = cfg.d_model
    keys = jax.random.split(key, 8)
    return {
        "mix_r": jnp.full((d,), 0.5, pdtype(cfg)),
        "mix_k": jnp.full((d,), 0.5, pdtype(cfg)),
        "mix_v": jnp.full((d,), 0.5, pdtype(cfg)),
        "mix_w": jnp.full((d,), 0.5, pdtype(cfg)),
        "mix_g": jnp.full((d,), 0.5, pdtype(cfg)),
        "wr": dense_init(keys[0], d, d, pdtype(cfg)),
        "wk": dense_init(keys[1], d, d, pdtype(cfg)),
        "wv": dense_init(keys[2], d, d, pdtype(cfg)),
        "wg": dense_init(keys[3], d, d, pdtype(cfg)),
        "wo": dense_init(keys[4], d, d, pdtype(cfg),
                         scale=1.0 / math.sqrt(2 * cfg.n_layers)),
        # data-dependent decay: w = exp(-exp(w0 + tanh(x @ A) @ B))
        "w0": jnp.full((d,), -2.0, pdtype(cfg)),
        "decay_a": dense_init(keys[5], d, r.decay_lora_rank, pdtype(cfg)),
        "decay_b": dense_init(keys[6], r.decay_lora_rank, d, pdtype(cfg), scale=0.1),
        "bonus_u": (jax.random.normal(keys[7], (n_heads, r.head_dim), jnp.float32)
                    * 0.1).astype(pdtype(cfg)),
        "ln_scale": jnp.ones((d,), pdtype(cfg)),  # per-head groupnorm scale
    }


def channelmix_init(key, cfg: ModelConfig) -> Pytree:
    d = cfg.d_model
    f = cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mix_k": jnp.full((d,), 0.5, pdtype(cfg)),
        "mix_r": jnp.full((d,), 0.5, pdtype(cfg)),
        "wk_c": dense_init(k1, d, f, pdtype(cfg)),
        "wv_c": dense_init(k2, f, d, pdtype(cfg)),
        "wr_c": dense_init(k3, d, d, pdtype(cfg)),
    }


def _token_shift(x: jax.Array, shift_state: Optional[jax.Array]
                 ) -> tuple[jax.Array, jax.Array]:
    """Previous-token tensor; shift_state (B,1,D) is the last token of the
    previous segment (decode). Returns (x_prev, new_shift_state)."""
    if shift_state is None:
        shift_state = jnp.zeros((x.shape[0], 1, x.shape[-1]), x.dtype)
    prev = jnp.concatenate([shift_state, x[:, :-1]], axis=1)
    return prev, x[:, -1:]


def _lerp(x, prev, mix):
    return x + (prev - x) * mix.astype(x.dtype)


def timemix_apply(params: Pytree, x: jax.Array, cfg: ModelConfig, *,
                  cache: Optional[dict] = None
                  ) -> tuple[jax.Array, Optional[dict]]:
    """cache: {"shift": (B,1,D), "wkv": (B,H,K,V)}."""
    from repro.kernels import ops

    r, n_heads = _dims(cfg)
    dt = cdtype(cfg)
    B, S, D = x.shape
    prev, new_shift = _token_shift(x, cache["shift"] if cache else None)

    xr = _lerp(x, prev, params["mix_r"])
    xk = _lerp(x, prev, params["mix_k"])
    xv = _lerp(x, prev, params["mix_v"])
    xw = _lerp(x, prev, params["mix_w"])
    xg = _lerp(x, prev, params["mix_g"])

    sp = cfg.sharding_profile == "fsdp_sp"
    wide = ("batch", "model", None) if sp else ("batch", None, "model")
    rr = constrain(jnp.einsum("bsd,dk->bsk", xr, params["wr"].astype(dt)), wide)
    kk = constrain(jnp.einsum("bsd,dk->bsk", xk, params["wk"].astype(dt)), wide)
    vv = constrain(jnp.einsum("bsd,dk->bsk", xv, params["wv"].astype(dt)), wide)
    gg = constrain(jnp.einsum("bsd,dk->bsk", xg, params["wg"].astype(dt)), wide)
    # data-dependent log decay (<0): -exp(w0 + tanh(xw A) B)
    dd = jnp.einsum("bsr,rd->bsd", jnp.tanh(
        jnp.einsum("bsd,dr->bsr", xw.astype(jnp.float32),
                   params["decay_a"].astype(jnp.float32))),
        params["decay_b"].astype(jnp.float32))
    logw = -jnp.exp(params["w0"].astype(jnp.float32) + dd)       # (B,S,D)

    hs = r.head_dim
    rr = rr.reshape(B, S, n_heads, hs)
    kk = kk.reshape(B, S, n_heads, hs)
    vv = vv.reshape(B, S, n_heads, hs)
    ww = logw.reshape(B, S, n_heads, hs)

    y, new_wkv = ops.rwkv6_mix(rr, kk, vv, ww, params["bonus_u"].astype(jnp.float32),
                               init_state=cache["wkv"] if cache else None)
    # per-head groupnorm then silu(g) gate
    yf = y.astype(jnp.float32)
    mu = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(yf - mu), axis=-1, keepdims=True)
    yf = (yf - mu) * jax.lax.rsqrt(var + 1e-5)
    yf = yf.reshape(B, S, D) * params["ln_scale"].astype(jnp.float32)
    y = (yf * jax.nn.silu(gg.astype(jnp.float32))).astype(dt)
    out = jnp.einsum("bsd,dk->bsk", y, params["wo"].astype(dt))
    return out, {"shift": new_shift, "wkv": new_wkv}


def channelmix_apply(params: Pytree, x: jax.Array, cfg: ModelConfig, *,
                     cache: Optional[dict] = None
                     ) -> tuple[jax.Array, Optional[dict]]:
    """cache: {"shift": (B,1,D)}."""
    dt = cdtype(cfg)
    prev, new_shift = _token_shift(x, cache["shift"] if cache else None)
    xk = _lerp(x, prev, params["mix_k"])
    xr = _lerp(x, prev, params["mix_r"])
    sp = cfg.sharding_profile == "fsdp_sp"
    k = constrain(jnp.einsum("bsd,df->bsf", xk, params["wk_c"].astype(dt)),
                  ("batch", "model", None) if sp else ("batch", None, "model"))
    k = jnp.square(jax.nn.relu(k))
    v = jnp.einsum("bsf,fd->bsd", k, params["wv_c"].astype(dt))
    rgate = jax.nn.sigmoid(jnp.einsum("bsd,dk->bsk", xr,
                                      params["wr_c"].astype(dt)).astype(jnp.float32))
    out = (rgate * v.astype(jnp.float32)).astype(dt)
    return out, {"shift": new_shift}


def rwkv_cache_shape(cfg: ModelConfig, batch: int) -> dict:
    r, n_heads = _dims(cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    return {
        "tm_shift": jnp.zeros((batch, 1, cfg.d_model), cdt),
        "wkv": jnp.zeros((batch, n_heads, r.head_dim, r.head_dim), jnp.float32),
        "cm_shift": jnp.zeros((batch, 1, cfg.d_model), cdt),
    }
