"""Mixture-of-Experts layer: top-k router + capacity-based grouped dispatch.

Dispatch follows the t5x/MaxText "dropping" scheme: tokens are grouped (group =
batch row), each group routes into per-expert capacity buffers via one-hot
einsums. This form shards cleanly under pjit — with the expert dimension on the
"model" mesh axis the dispatch/combine einsums lower to all-to-alls (EP), and
with experts replicated the expert GEMMs are plain TP over d_ff (mixtral's
8 experts cannot split 16 ways; see launch/sharding.py).

The dispatch einsums cost ~S/(3*d_ff) of the expert GEMM FLOPs (~10-20%);
EXPERIMENTS.md §Roofline reports this overhead and §Perf tracks the capacity
factor as a tuning knob.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, MoEConfig
from repro.models.layers import _act, cdtype, dense_init, pdtype

Pytree = Any


def moe_init(key, cfg: ModelConfig) -> Pytree:
    moe = cfg.moe
    keys = jax.random.split(key, 5)
    d, f, e = cfg.d_model, moe.expert_d_ff, moe.n_experts
    p = {
        "router": dense_init(keys[0], d, e, pdtype(cfg)),
        # stacked expert weights: (E, d, f) / (E, f, d)
        "we_in": _stack_init(keys[1], e, d, f, pdtype(cfg)),
        "we_gate": _stack_init(keys[2], e, d, f, pdtype(cfg)),
        "we_out": _stack_init(keys[3], e, f, d, pdtype(cfg)),
    }
    if moe.n_shared_experts:
        fs = moe.expert_d_ff * moe.n_shared_experts
        ks = jax.random.split(keys[4], 3)
        p["shared"] = {"wi": dense_init(ks[0], d, fs, pdtype(cfg)),
                       "wg": dense_init(ks[1], d, fs, pdtype(cfg)),
                       "wo_mlp": dense_init(ks[2], fs, d, pdtype(cfg))}
    return p


def _stack_init(key, e, d_in, d_out, dtype):
    keys = jax.random.split(key, e)
    return jnp.stack([dense_init(k, d_in, d_out, dtype) for k in keys])


def _capacity(moe: MoEConfig, group_size: int) -> int:
    c = int(group_size * moe.top_k * moe.capacity_factor / moe.n_experts)
    return max(moe.top_k, min(group_size, (c + 3) // 4 * 4))  # pad to multiple of 4


def moe_apply(params: Pytree, x: jax.Array, cfg: ModelConfig
              ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss). Group == batch row."""
    moe = cfg.moe
    dt = cdtype(cfg)
    B, S, D = x.shape
    E, K = moe.n_experts, moe.top_k
    C = _capacity(moe, S)

    router_logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32),
                               params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(router_logits, axis=-1)                 # (G,S,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                  # (G,S,K)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)

    # position of each (token, slot) inside its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)          # (G,S,K,E)
    slot_flat = onehot.reshape(B, S * K, E)
    pos = jnp.cumsum(slot_flat, axis=1) - slot_flat                # 0-based rank
    pos = pos.reshape(B, S, K, E)
    within = (pos < C) & (onehot > 0)
    pos_onehot = jax.nn.one_hot(jnp.where(within, pos, C), C + 1,
                                dtype=dt)[..., :C]                 # (G,S,K,E,C)

    combine = pos_onehot * gate_vals[..., None, None].astype(dt)   # (G,S,K,E,C)
    combine = jnp.sum(combine, axis=2)                             # (G,S,E,C)
    dispatch = jnp.sum(pos_onehot, axis=2)                         # (G,S,E,C) 0/1

    xe = jnp.einsum("gsec,gsd->gecd", dispatch, x.astype(dt))      # (G,E,C,D)
    h = jnp.einsum("gecd,edf->gecf", xe, params["we_in"].astype(dt))
    h = _act(h, cfg.act)
    h = h * jnp.einsum("gecd,edf->gecf", xe, params["we_gate"].astype(dt))
    ye = jnp.einsum("gecf,efd->gecd", h, params["we_out"].astype(dt))
    y = jnp.einsum("gsec,gecd->gsd", combine, ye)

    if moe.n_shared_experts:
        sp = params["shared"]
        hs = _act(jnp.einsum("gsd,df->gsf", x, sp["wi"].astype(dt)), cfg.act)
        hs = hs * jnp.einsum("gsd,df->gsf", x, sp["wg"].astype(dt))
        y = y + jnp.einsum("gsf,fd->gsd", hs, sp["wo_mlp"].astype(dt))

    # load-balance auxiliary loss (Switch-style): E * sum_e f_e * p_e
    assign_frac = jnp.mean(jnp.sum(onehot, axis=2).astype(jnp.float32), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = moe.router_aux_weight * E * jnp.sum(assign_frac / K * mean_prob)
    return y.astype(x.dtype), aux
