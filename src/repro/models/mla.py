"""Multi-head Latent Attention (DeepSeek-V2) with compressed-KV decode.

Training/prefill decompress the latent into full per-head K/V and run flash
attention (FLOP-dominant path). Decode uses the *absorbed* form: queries are
projected into the latent space so the cache stays (S, kv_lora + rope_dim)
per token — the memory win MLA exists for — and attention runs directly
against the compressed cache.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, cdtype, dense_init, pdtype

Pytree = Any


def mla_init(key, cfg: ModelConfig) -> Pytree:
    m = cfg.mla
    d = cfg.d_model
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    keys = jax.random.split(key, 6)
    return {
        "wq": dense_init(keys[0], d, cfg.n_heads * qk_dim, pdtype(cfg)),
        "w_dkv": dense_init(keys[1], d, m.kv_lora_rank + m.qk_rope_head_dim, pdtype(cfg)),
        "kv_norm_scale": jnp.ones((m.kv_lora_rank,), pdtype(cfg)),
        "w_uk": dense_init(keys[2], m.kv_lora_rank, cfg.n_heads * m.qk_nope_head_dim,
                           pdtype(cfg)),
        "w_uv": dense_init(keys[3], m.kv_lora_rank, cfg.n_heads * m.v_head_dim,
                           pdtype(cfg)),
        "wo": dense_init(keys[4], cfg.n_heads * m.v_head_dim, d, pdtype(cfg),
                         scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }


def _latent(params: Pytree, x: jax.Array, cfg: ModelConfig):
    """Project to (normalized compressed kv latent, rope key)."""
    m = cfg.mla
    dt = cdtype(cfg)
    ckv = jnp.einsum("...d,dr->...r", x, params["w_dkv"].astype(dt))
    c_kv, k_rope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    cf = c_kv.astype(jnp.float32)
    cf = cf * jax.lax.rsqrt(jnp.mean(jnp.square(cf), -1, keepdims=True) + 1e-6)
    c_kv = (cf * params["kv_norm_scale"].astype(jnp.float32)).astype(dt)
    return c_kv, k_rope


def _queries(params: Pytree, x: jax.Array, positions, cfg: ModelConfig):
    m = cfg.mla
    dt = cdtype(cfg)
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = jnp.einsum("...d,dh->...h", x, params["wq"].astype(dt))
    q = q.reshape(*q.shape[:-1], cfg.n_heads, qk_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(params: Pytree, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array,
              cache: Optional[dict] = None) -> tuple[jax.Array, Optional[dict]]:
    from repro.kernels import ops

    m = cfg.mla
    dt = cdtype(cfg)
    H = cfg.n_heads
    q_nope, q_rope = _queries(params, x, positions, cfg)
    c_kv, k_rope_raw = _latent(params, x, cfg)
    k_rope = apply_rope(k_rope_raw[..., None, :], positions, cfg.rope_theta)

    if cache is None:
        # train/prefill: decompress latent to per-head K/V, run flash attention
        S = x.shape[1]
        k_nope = jnp.einsum("...r,rh->...h", c_kv, params["w_uk"].astype(dt))
        k_nope = k_nope.reshape(*x.shape[:-1], H, m.qk_nope_head_dim)
        v = jnp.einsum("...r,rh->...h", c_kv, params["w_uv"].astype(dt))
        v = v.reshape(*x.shape[:-1], H, m.v_head_dim)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            k_rope, (*k_nope.shape[:-1], m.qk_rope_head_dim))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = ops.flash_attention(q, k, v, causal=True)
        # compressed-latent cache material for prefill (DCE'd in training)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope[:, :, 0, :]}
    else:
        # decode (absorbed): score against the compressed cache directly.
        pos = cache["pos"]
        ckv_c = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), pos, axis=1)
        krope_c = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope[..., 0, :].astype(cache["k_rope"].dtype), pos, axis=1)
        # absorb w_uk into the query: q_lat (B,1,H,R)
        wuk = params["w_uk"].astype(dt).reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
        q_lat = jnp.einsum("bthn,rhn->bthr", q_nope, wuk)
        scores = (jnp.einsum("bthr,bsr->bhts", q_lat.astype(jnp.float32),
                             ckv_c.astype(jnp.float32))
                  + jnp.einsum("bthn,bsn->bhts", q_rope.astype(jnp.float32),
                               krope_c.astype(jnp.float32)))
        scores = scores / jnp.sqrt(jnp.float32(m.qk_nope_head_dim + m.qk_rope_head_dim))
        valid = jnp.arange(ckv_c.shape[1])[None, :] < pos + x.shape[1]
        scores = jnp.where(valid[:, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhts,bsr->bthr", probs, ckv_c.astype(jnp.float32))
        wuv = params["w_uv"].astype(dt).reshape(m.kv_lora_rank, H, m.v_head_dim)
        out = jnp.einsum("bthr,rhv->bthv", o_lat.astype(dt), wuv)
        new_cache = {"c_kv": ckv_c, "k_rope": krope_c, "pos": pos + x.shape[1]}

    out = out.reshape(*x.shape[:-1], H * m.v_head_dim)
    out = jnp.einsum("...h,hd->...d", out, params["wo"].astype(dt))
    return out, new_cache
