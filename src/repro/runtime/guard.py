"""Numerics guard: anomaly detection, SAM de-escalation ladder, poison rollback.

PRs 7 and 9 made the system survive process crashes, mesh loss, wire faults
and checkpoint corruption; this module guards the TRAINING DYNAMICS — the
failure mode that actually kills long SAM runs. AsyncSAM applies *stale*
perturbations (paper §3), and staleness-amplified ascent steps are exactly
the regime where loss spikes and NaN/Inf gradients appear. The response
mirrors the lane ladder (runtime.health), one layer up the stack:

detection
    * on-device, fused into the step: `MethodConfig.guard_update` makes
      `core.api._finish` tree-select the whole update away when the loss or
      global grad-norm is non-finite (the norm is already computed by the
      existing bucket reductions — the verdict is free; the per-element
      `nonfinite_count` is one extra pass, paid only when the guard is on);
    * host-side: a rolling median/MAD loss-spike detector (`SpikeDetector`)
      and a stale-ascent check that drops a held ascent gradient whose norm
      or tau exceeds bounds calibrated from the run's own history.

escalation ladder (`GuardedExecutor`, reusing `health.LaneLadder` verbatim —
    the hysteresis problem is identical)
    skip-step (in-step, state kept) -> SAM de-escalation (rho scaled down
    rung by rung until async_sam degrades to plain descent, with probation +
    cooldown-doubling so a flapping anomaly source cannot oscillate) ->
    rollback.

diverge-proof rollback
    at the bottom rung with anomalies still firing, the step raises
    `fault_tolerance.PoisonBatch`: `run_resilient` restores the checkpoint
    but does NOT rewind the pipeline cursor, so the restarted run trains on
    fresh data instead of bitwise-replaying the poison window into the same
    NaN until the restart budget is gone.

`NumericChaos` is the `FaultSchedule`-style injector giving the chaos
harness a numerics dimension (`--numchaos "nan_grad:nth=40,spike:prob=0.01"`).
Unlike mesh/wire chaos it is NOT fire-once: poison is a property of the
data, keyed on the pipeline cursor, so a rollback that replays the stream
re-poisons the same batches — which is precisely the livelock `PoisonBatch`
exists to break.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import random
import statistics
from typing import Any, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import current_tracker
from repro.runtime.fault_tolerance import PoisonBatch
from repro.runtime.health import LaneLadder

Pytree = Any


@dataclasses.dataclass
class GuardConfig:
    """Knobs for detection, the de-escalation ladder, and rollback."""

    # --- loss-spike detector (rolling median/MAD, signed: only loss ABOVE
    # the median is anomalous, so a fast-improving loss never false-positives)
    spike_window: int = 32
    spike_zscore: float = 8.0
    spike_min_samples: int = 8
    # --- stale-ascent bounds (both calibrated/relative; 0 disables)
    stale_tau_max: int = 0          # drop the held gradient past this age
    stale_norm_mult: float = 10.0   # ... or past mult x rolling median norm
    stale_norm_window: int = 64
    stale_norm_min_samples: int = 16
    # --- ladder: one rho scale per rung; 0.0 = plain descent (bottom)
    rho_scales: tuple = (1.0, 0.5, 0.25, 0.0)
    demote_after: int = 2           # anomalies within anomaly_window
    anomaly_window: int = 8
    probation_steps: int = 16
    cooldown_steps: int = 16
    max_cooldown_steps: int = 256
    # --- rollback: PoisonBatch may only be raised when a checkpoint-restart
    # loop is there to catch it (run_resilient); without one the guard stays
    # at the bottom rung and keeps skipping — params stay finite either way
    rollback: bool = False


class SpikeDetector:
    """Rolling median/MAD loss-spike detector (host-side, O(window))."""

    def __init__(self, *, window: int = 32, min_samples: int = 8):
        self.min_samples = min_samples
        self._vals: collections.deque = collections.deque(maxlen=window)

    def score(self, x: float) -> Optional[float]:
        """Signed robust z-score of `x` against the window (None until the
        window holds `min_samples`). The 5%-of-median sigma floor keeps a
        dead-flat window (MAD 0) from flagging numeric jitter as a spike."""
        if len(self._vals) < self.min_samples:
            return None
        med = statistics.median(self._vals)
        mad = statistics.median(abs(v - med) for v in self._vals)
        sigma = 1.4826 * mad + 0.05 * abs(med) + 1e-8
        return (x - med) / sigma

    def observe(self, x: float) -> None:
        """Admit a NON-anomalous loss (spikes are kept out of the window so
        a spike train cannot teach the detector that spikes are normal)."""
        self._vals.append(x)

    def reset(self) -> None:
        self._vals.clear()


# ---------------------------------------------------------------------------
# NumericChaos — deterministic batch-poisoning injector
# ---------------------------------------------------------------------------

NUMCHAOS_KINDS = ("nan_grad", "inf_grad", "spike")


@dataclasses.dataclass(frozen=True)
class NumericRule:
    """One poisoning rule, a pure function of the data-stream index.

    kind: nan_grad (NaN-fill float leaves) | inf_grad (Inf-fill) |
          spike (scale float leaves by `scale` — a loss-spike batch).
    Selectors (any may combine): `nth` fires on indices [nth, nth+span);
    `every` fires on every multiple; `prob` fires pseudo-randomly but
    deterministically per index — replaying an index re-fires identically,
    because poison lives in the data, not in wall time.
    """
    kind: str
    nth: int = -1
    span: int = 1
    every: int = 0
    prob: float = 0.0
    scale: float = 1e4

    def __post_init__(self):
        if self.kind not in NUMCHAOS_KINDS:
            raise ValueError(f"numchaos kind must be one of {NUMCHAOS_KINDS}, "
                             f"got {self.kind!r}")


class NumericChaos:
    """Deterministic numerics-chaos schedule over a batch stream."""

    def __init__(self, rules, seed: int = 0):
        self.rules = list(rules)
        self.seed = seed
        self.fired: collections.Counter = collections.Counter()
        #: rules that matched a batch with no float leaves (token-only
        #: batches cannot carry NaN payloads — the injection is a no-op)
        self.skipped_no_float = 0

    def _fires(self, rule: NumericRule, ridx: int, idx: int) -> bool:
        if rule.nth >= 0 and rule.nth <= idx < rule.nth + rule.span:
            return True
        if rule.every > 0 and idx > 0 and idx % rule.every == 0:
            return True
        if rule.prob > 0.0:
            mixed = (self.seed * 1_000_003 + ridx) * 1_000_003 + idx
            return random.Random(mixed).random() < rule.prob
        return False

    def inject(self, idx: int, batch: Pytree) -> Pytree:
        for ridx, rule in enumerate(self.rules):
            if self._fires(rule, ridx, idx):
                batch, hit = _poison_batch(batch, rule)
                if hit:
                    self.fired[rule.kind] += 1
                else:
                    self.skipped_no_float += 1
        return batch


def _poison_batch(batch: Pytree, rule: NumericRule) -> tuple[Pytree, bool]:
    hit = False

    def fn(x):
        nonlocal hit
        dt = getattr(x, "dtype", None)
        if dt is None or not jnp.issubdtype(jnp.dtype(dt), jnp.floating):
            return x
        hit = True
        if rule.kind == "nan_grad":
            return jnp.full_like(x, jnp.nan)
        if rule.kind == "inf_grad":
            return jnp.full_like(x, jnp.inf)
        return x * jnp.asarray(rule.scale, jnp.dtype(dt))

    return jax.tree.map(fn, batch), hit


def parse_numchaos(spec: str, seed: int = 0) -> NumericChaos:
    """Parse a launcher-friendly schedule, netchaos-grammar style.

    Comma-separated rules, each `kind[:key=val...]`:

        "nan_grad:nth=40,nan_grad:nth=60:span=8,spike:prob=0.01:scale=1e4"

    poisons batch 40, the whole window [60, 68), and ~1% of batches.
    """
    rules = []
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        parts = item.split(":")
        kw: dict = {}
        for p in parts[1:]:
            k, eq, v = p.partition("=")
            k = k.strip()
            if not eq:
                raise ValueError(f"numchaos rule {item!r}: expected key=val, "
                                 f"got {p!r}")
            if k in ("nth", "span", "every"):
                kw[k] = int(v)
            elif k in ("prob", "scale"):
                kw[k] = float(v)
            else:
                raise ValueError(f"numchaos rule {item!r}: unknown key {k!r}")
        rules.append(NumericRule(kind=parts[0].strip(), **kw))
    if not rules:
        raise ValueError(f"empty numchaos spec: {spec!r}")
    return NumericChaos(rules, seed=seed)


class NumericChaosPipeline:
    """Pipeline wrapper injecting NumericChaos per drawn batch.

    Carries its own cursor in `state()`/`restore()` (alongside the inner
    pipeline's) so a node-loss rollback replays the SAME poison — the
    injector is part of the data for restart-determinism purposes — while a
    `PoisonBatch` rollback, which skips the cursor restore entirely, runs
    past it.
    """

    def __init__(self, inner, chaos: NumericChaos):
        self.inner = inner
        self.chaos = chaos
        self._cursor = 0

    def state(self) -> dict:
        st = {"cursor": self._cursor}
        if hasattr(self.inner, "state"):
            st["inner"] = self.inner.state()
        return st

    def restore(self, state: dict) -> None:
        self._cursor = int(state["cursor"])
        if "inner" in state and hasattr(self.inner, "restore"):
            self.inner.restore(state["inner"])

    def peek(self) -> dict:
        """UNinjected: calibration probes must not calibrate on poison."""
        return self.inner.peek()

    def __iter__(self) -> Iterator[dict]:
        return self._gen(iter(self.inner))

    def _gen(self, it) -> Iterator[dict]:
        try:
            while True:
                try:
                    batch = next(it)
                except StopIteration:
                    return
                idx = self._cursor
                self._cursor += 1
                yield self.chaos.inject(idx, batch)
        finally:
            if hasattr(it, "close"):
                it.close()


# ---------------------------------------------------------------------------
# GuardedExecutor — the escalation ladder as a StepExecutor wrapper
# ---------------------------------------------------------------------------

class GuardedExecutor:
    """StepExecutor wrapper running the numerics-guard escalation ladder.

    Wraps ANY executor (fused / hetero / remote / elastic — outermost, so
    the verdict covers everything below). Per step it classifies the
    metrics the inner step emitted:

        skip            the in-step guard discarded the update
        nonfinite_state non-finite dynamics reached the host with the
                        update APPLIED (in-step guard off, or the params
                        were already poisoned) — the severe class
        spike           loss spiked past the rolling median/MAD band
        stale_ascent    the held ascent gradient aged or grew past bounds
                        (dropped via the executor's `drop_ascent` hook)

    and drives a `LaneLadder` over `GuardConfig.rho_scales`: each demotion
    scales rho one rung down (through the executor's `set_rho_scale` hook
    when the chain has one — the hetero/remote lanes — or by rescaling the
    fused form's carried `ascent_norm`, which changes the effective rho
    without touching the jitted program). The bottom rung is plain descent.
    Anomalies persisting there raise `PoisonBatch` (when `cfg.rollback`),
    handing the run to `run_resilient`'s diverge-proof rollback.

    The `ascent_loss` NaN-on-reuse sentinel of the fused async form is
    ignored whenever the step carries `ascent_reused=1` — the explicit flag
    that disambiguates it from a genuine NaN.
    """

    name = "guarded"

    def __init__(self, inner, cfg: Optional[GuardConfig] = None):
        self.inner = inner
        self.cfg = cfg or GuardConfig()
        assert len(self.cfg.rho_scales) >= 2, "need at least two rungs"
        assert self.cfg.rho_scales[0] == 1.0, "rung 0 is the undegraded state"
        self.ladder = LaneLadder(
            n_levels=len(self.cfg.rho_scales),
            probation_steps=self.cfg.probation_steps,
            cooldown_steps=self.cfg.cooldown_steps,
            max_cooldown_steps=self.cfg.max_cooldown_steps)
        self.spikes = SpikeDetector(window=self.cfg.spike_window,
                                    min_samples=self.cfg.spike_min_samples)
        self._norms: collections.deque = collections.deque(
            maxlen=self.cfg.stale_norm_window)
        self._anomalies: collections.deque = collections.deque(
            maxlen=self.cfg.anomaly_window)
        self.steps_skipped = 0
        self.poison_rollbacks = 0
        self._scale = 1.0
        self._pending_poison = False
        self._pending_drop = False
        self._announce = False
        self._rho_hook = self._find_hook("set_rho_scale")
        self._drop_hook = self._find_hook("drop_ascent")

    # --- hook resolution over the wrapper chain -----------------------------
    def _find_hook(self, name: str):
        """Walk inner/._inner wrappers (elastic -> hetero -> executor) for a
        lane-level hook; None means the fused state-transform path."""
        obj, seen = self.inner, set()
        while obj is not None and id(obj) not in seen:
            seen.add(id(obj))
            fn = getattr(obj, name, None)
            if callable(fn):
                return fn
            obj = getattr(obj, "inner", None) or getattr(obj, "_inner", None)
        return None

    # --- rho scaling --------------------------------------------------------
    def _apply_scale(self) -> None:
        self._scale = float(self.cfg.rho_scales[self.ladder.level])
        if self._rho_hook is not None:
            self._rho_hook(self._scale)

    def _pre_step(self, state):
        """Fused Form A has no lane to hand the scale to — the carried
        AsyncSamState is where rho acts, so de-escalation rescales its norm
        (perturb computes rho/||a||: norm/scale <=> rho*scale) and the
        bottom rung clears have_ascent; the norm is recomputed from the
        gradient every refresh, so the rescale cannot compound. Dropping a
        stale gradient goes through the `drop_ascent` hook when the chain
        has one, else the same state transform."""
        ms = getattr(state, "method_state", None)
        from repro.core.async_sam import AsyncSamState
        is_async = isinstance(ms, AsyncSamState)
        if self._pending_drop:
            self._pending_drop = False
            if self._drop_hook is not None:
                self._drop_hook()
            elif is_async:
                ms = ms._replace(have_ascent=jnp.zeros((), jnp.bool_),
                                 staleness=jnp.zeros((), jnp.int32))
                state = state._replace(method_state=ms)
        if self._rho_hook is not None or not is_async:
            return state
        if self._scale <= 0.0:
            state = state._replace(method_state=ms._replace(
                have_ascent=jnp.zeros((), jnp.bool_)))
        elif self._scale != 1.0:
            state = state._replace(method_state=ms._replace(
                ascent_norm=ms.ascent_norm / np.float32(self._scale)))
        return state

    # --- classification -----------------------------------------------------
    def _classify(self, m: dict) -> set:
        kinds: set = set()
        if float(m.get("update_skipped", 0.0)) > 0.5:
            kinds.add("skip")
        # severe: non-finite loss/grad reached the host with the update
        # APPLIED — in-step guard off for this method, or the params were
        # already poisoned. A non-finite ASCENT side (loss or norm; the
        # ascent_loss NaN sentinel doesn't count when ascent_reused says so)
        # is NOT severe — the carried state is guarded/dropped and the params
        # are fine — it classifies as a stale-ascent drop instead.
        bad = any(k in m and not math.isfinite(float(m[k]))
                  for k in ("loss", "grad_norm"))
        if bad and "skip" not in kinds:
            kinds.add("nonfinite_state")
        reused = float(m.get("ascent_reused", 0.0)) > 0.5
        asc_watch = ["ascent_norm"] + ([] if reused else ["ascent_loss"])
        if any(k in m and not math.isfinite(float(m[k])) for k in asc_watch):
            kinds.add("stale_ascent")
        loss = m.get("loss")
        if loss is not None and math.isfinite(float(loss)):
            z = self.spikes.score(float(loss))
            if z is not None and z > self.cfg.spike_zscore:
                kinds.add("spike")
            else:
                self.spikes.observe(float(loss))
        if self.cfg.stale_tau_max and \
                float(m.get("tau", 0.0)) > self.cfg.stale_tau_max:
            kinds.add("stale_ascent")
        an = m.get("ascent_norm")
        if (an is not None and self.cfg.stale_norm_mult
                and math.isfinite(float(an)) and float(an) > 0.0):
            an = float(an)
            if (len(self._norms) >= self.cfg.stale_norm_min_samples
                    and an > self.cfg.stale_norm_mult
                    * statistics.median(self._norms)):
                kinds.add("stale_ascent")
            else:
                self._norms.append(an)
        return kinds

    # --- the ladder decision ------------------------------------------------
    def _act(self, kinds: set) -> None:
        trk = current_tracker()
        self.ladder.tick()
        if "skip" in kinds:
            self.steps_skipped += 1
            self._announce = True
            trk.event("guard_skip", lane="guard", skips=self.steps_skipped)
        if "stale_ascent" in kinds:
            self._pending_drop = True
            trk.event("guard_stale_drop", lane="guard")
        if "nonfinite_state" in kinds and self.cfg.rollback:
            # the params themselves are (or may be) non-finite: no rung of
            # the ladder can repair corrupted state — straight to rollback
            self._poison("non-finite training state reached the host")
        self._anomalies.append(bool(kinds))
        if kinds:
            if sum(self._anomalies) >= self.cfg.demote_after:
                self._anomalies.clear()   # the next verdict needs fresh evidence
                if self.ladder.demote():
                    self._apply_scale()
                    trk.event("guard_deescalate", lane="guard",
                              level=self.ladder.level, rho_scale=self._scale,
                              kinds=sorted(kinds))
                elif self.cfg.rollback:
                    self._poison("anomalies persist at the bottom rung "
                                 f"({sorted(kinds)})")
                # else: nothing left to de-escalate and no rollback target —
                # keep skipping; the in-step guard keeps the params finite
        elif self.ladder.can_promote() and not any(self._anomalies):
            self.ladder.promote()
            self._apply_scale()
            trk.event("guard_recovery", lane="guard",
                      level=self.ladder.level, rho_scale=self._scale)

    def _poison(self, why: str):
        self._pending_poison = True
        current_tracker().event("guard_poison", lane="guard",
                                level=self.ladder.level)
        raise PoisonBatch(f"numerics guard: {why}")

    # --- StepExecutor -------------------------------------------------------
    def step(self, state, batch):
        state = self._pre_step(state)
        state, metrics = self.inner.step(state, batch)
        metrics = dict(metrics)
        self._act(self._classify(metrics))   # may raise PoisonBatch
        # rung + scale every step (lane_state pattern); cumulative counters
        # only on the step at/after a transition, so summing a jsonl column
        # never double-counts (the resize_events emission pattern)
        metrics["guard_state"] = float(self.ladder.level)
        metrics["rho_scale"] = float(self._scale)
        if self._announce:
            self._announce = False
            metrics["steps_skipped"] = float(self.steps_skipped)
            metrics["poison_rollbacks"] = float(self.poison_rollbacks)
        return state, metrics

    def on_restore(self, state):
        """Rollback hook: chain the inner executor's (lane resets, elastic
        re-placement — its adopted state is forwarded), account a pending
        poison rollback, and reset the detectors — the restored timeline's
        dynamics are not the failed one's. The ladder keeps its rung: the
        run re-enters still de-escalated and earns its way back up through
        the normal cooldown/probation path (= observable guard recoveries).
        """
        hook = getattr(self.inner, "on_restore", None)
        adopted = hook(state) if hook is not None else None
        if self._pending_poison:
            self._pending_poison = False
            self.poison_rollbacks += 1
            self._announce = True
            current_tracker().event("poison_rollback", lane="guard",
                                    rollbacks=self.poison_rollbacks)
        self.spikes.reset()
        self._norms.clear()
        self._anomalies.clear()
        return adopted

    def close(self) -> None:
        self.inner.close()

    def __getattr__(self, name: str):
        # everything else (init_state, pre_fit, wants_pre_fit, attach_events,
        # mesh, resize, calibrate ...) delegates to the wrapped executor
        if name == "inner":
            raise AttributeError(name)
        return getattr(self.inner, name)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
