"""Fault-tolerant training driver: checkpoint-restart with failure injection.

`run_resilient` wraps any framework step function with the production loop:
periodic async checkpoints (model state + data-pipeline cursor), automatic
restore-and-continue on step failure, bounded restart budget, and a pluggable
failure injector used by the chaos tests (tests/test_fault_tolerance.py
asserts bitwise-identical final states with and without injected crashes).

At pod scale the same loop runs per controller; a real deployment adds a
cluster watchdog that re-schedules dead hosts and re-enters `run_resilient`
with the surviving (or re-sized — see runtime/elastic.py) mesh.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Iterator, Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.core import TrainState
from repro.obs import scalar_metrics
from repro.utils import buckets

log = logging.getLogger("repro.fault_tolerance")

Pytree = Any


class InjectedFailure(RuntimeError):
    """Raised by failure injectors (stands in for a lost node / preemption)."""


class PoisonBatch(RuntimeError):
    """A NaN-class training-dynamics failure pinned to the DATA, not a node.

    Raised by the numerics guard (runtime.guard) when its de-escalation
    ladder bottoms out and the anomalies persist: the step stream itself is
    poisoned. `run_resilient` treats it differently from a node loss — the
    model state rolls back to the last checkpoint, but the pipeline cursor
    is NOT rolled back, so the restarted run trains on fresh data instead of
    bitwise-replaying the poison window into the same NaN (the livelock that
    would otherwise eat the whole restart budget).
    """


@dataclasses.dataclass
class ResilienceConfig:
    save_every: int = 50
    #: restarts tolerated; counted over the whole run when
    #: `restart_window_s` is None, else within that rolling window (a
    #: week-long spot job survives any number of preemptions as long as
    #: no `restart_window_s`-second span holds more than `max_restarts`)
    max_restarts: int = 5
    async_save: bool = True
    restart_window_s: Optional[float] = None
    #: refuse rollback targets whose params contain non-finite values —
    #: a checkpoint saved by an unguarded run after the numerics already
    #: went bad is a diverged target, not a recovery point (restore falls
    #: back to the newest finite older step). On for --guard runs.
    require_finite_restore: bool = False


class RestartBudget:
    """Bounded restart/resize accounting: lifetime or rolling-window.

    `spend()` records one event and raises RuntimeError once more than
    `limit` events land inside `window_s` seconds (every event ever, when
    `window_s` is None — the legacy lifetime budget). Shared by
    `run_resilient` (checkpoint-restart) and `engine.ElasticExecutor`
    (mesh resizes). `clock` is injectable for deterministic tests.
    """

    def __init__(self, limit: int, window_s: Optional[float] = None, *,
                 what: str = "restart", clock: Callable[[], float] = time.monotonic):
        self.limit = limit
        self.window_s = window_s
        self.what = what
        self.clock = clock
        self.total = 0
        self._times: list[float] = []

    def in_window(self) -> int:
        if self.window_s is not None:
            now = self.clock()
            self._times = [t for t in self._times
                           if now - t <= self.window_s]
        return len(self._times)

    def spend(self, cause: Optional[BaseException] = None) -> int:
        self.total += 1
        self._times.append(self.clock())
        used = self.in_window()
        if used > self.limit:
            scope = (f"within {self.window_s:g}s window"
                     if self.window_s is not None else "lifetime")
            raise RuntimeError(
                f"exceeded {self.what} budget ({self.limit} {scope})"
            ) from cause
        return used


@dataclasses.dataclass
class RunReport:
    final_state: TrainState
    steps_done: int
    restarts: int
    metrics_history: list
    wall_time_s: float
    #: restarts classified as PoisonBatch (data advanced past the window)
    poison_rollbacks: int = 0


def run_resilient(step_fn: Callable[[TrainState, dict], tuple[TrainState, dict]],
                  state: TrainState,
                  pipeline,
                  manager: CheckpointManager,
                  n_steps: int,
                  rcfg: Optional[ResilienceConfig] = None,
                  failure_injector: Optional[Callable[[int], None]] = None,
                  shardings: Optional[Pytree] = None,
                  on_restore: Optional[Callable[[TrainState], None]] = None
                  ) -> RunReport:
    """Run `n_steps` of `step_fn`, surviving crashes via checkpoint-restart.

    `failure_injector(step)` may raise to simulate a node loss. A failed
    ASYNC checkpoint save surfaces the same way: the manager re-raises the
    captured worker exception from the next `save()`/`wait()`, which lands
    in this loop's failure domain — one spent restart and a rollback to the
    last checkpoint that actually made it to disk, never a silent gap in
    the checkpoint history. The pipeline
    must expose state()/restore() (see repro.data.pipeline). `on_restore`
    is called with the restored state after every rollback so stateful
    executors (the hetero lane's held ascent gradient) can reset; when it
    returns a state (not None) that state replaces the restored one — the
    elastic executor uses this to re-place the rollback target onto a
    resized mesh (restore-onto-survivors).

    Checkpoints stay PYTREE-shaped on disk regardless of the live state's
    representation: bucket-resident state (utils.buckets.BucketedState) is
    viewed out (`to_portable`) before every save — the manifest is stamped
    with the bucket layout for provenance — and re-bucketed against the live
    state's layout after every restore. A pre-resident-era checkpoint
    therefore restores into a bucket-resident run unchanged, and vice versa.
    """
    rcfg = rcfg or ResilienceConfig()
    t_start = time.time()
    budget = RestartBudget(rcfg.max_restarts, rcfg.restart_window_s)
    history: list = []
    poison_rollbacks = 0
    resident = buckets.is_resident(state)

    def snapshot_extras() -> dict:
        extras = {"pipeline": pipeline.state()}
        if resident:
            extras["bucket_layout"] = buckets.layout_stamp(state)
        return extras

    # step 0 baseline checkpoint so the first restart always has a target
    manager.save(int(state.step), buckets.to_portable(state),
                 extras=snapshot_extras(), blocking=True)

    while True:
        it = iter(pipeline)
        try:
            step = int(state.step)
            while step < n_steps:
                try:
                    batch = next(it)
                except StopIteration:
                    break   # finite data exhausted: clean partial run,
                            # not a node failure
                if failure_injector is not None:
                    failure_injector(step)
                state, metrics = step_fn(state, batch)
                step = int(state.step)
                history.append(scalar_metrics(metrics))
                if step % rcfg.save_every == 0 or step == n_steps:
                    manager.save(step, buckets.to_portable(state),
                                 extras=snapshot_extras(),
                                 blocking=not rcfg.async_save)
            manager.wait()
            return RunReport(final_state=state, steps_done=step,
                             restarts=budget.total, metrics_history=history,
                             wall_time_s=time.time() - t_start,
                             poison_rollbacks=poison_rollbacks)
        except Exception as e:  # noqa: BLE001 — the loop IS the failure domain
            poison = isinstance(e, PoisonBatch)
            used = budget.spend(cause=e)   # raises past the (windowed) budget
            log.warning("step failed (%s: %s); restart %d/%d in window "
                        "(%d total)", type(e).__name__, e, used,
                        rcfg.max_restarts, budget.total)
            manager.wait()
            restored, extras = manager.restore(
                jax.eval_shape(lambda: buckets.to_portable(state)),
                shardings=shardings,
                require_finite=rcfg.require_finite_restore)
            state = (buckets.residentize(restored, like=state)
                     if resident else restored)
            if poison:
                # NaN-class failure: the model rolls back, the DATA does not.
                # The live cursor already sits past the poison window, so
                # skipping the cursor restore is exactly "advance past it" —
                # a node-loss rollback keeps replaying the identical stream
                # (bitwise restart determinism), a poison rollback must not.
                poison_rollbacks += 1
                log.warning("poison-batch rollback: model restored, pipeline "
                            "cursor kept at %s (past the poison window)",
                            pipeline.state())
            else:
                pipeline.restore(extras["pipeline"])
            if on_restore is not None:
                adopted = on_restore(state)
                if adopted is not None:
                    state = adopted   # executor re-placed it (elastic resize)
        finally:
            if hasattr(it, "close"):
                it.close()   # stop a prefetching pipeline's worker now
