"""Heterogeneous asynchronous executor — the paper's two-process scheme.

Faithful port of the MPI design (paper §3.3/§3.4) onto host threads + a
depth-1 queue:

* the DESCENT lane (fast resource) runs `descent_fn` — one model update per
  step, perturbing with whatever ascent gradient is currently held;
* the ASCENT lane (slow resource, dedicated thread) runs `ascent_fn` on b'
  samples against a *snapshot* of the parameters — by construction one step
  old when consumed: tau = 1 (Algorithm 1);
* if the ascent lane has not delivered by the time the descent lane needs it,
  the held gradient is reused and its age grows (tau = 2, 3, ...) up to
  `max_staleness`, after which the step degrades to plain SGD — the
  AsyncSAM-specific straggler mitigation (a straggling helper can slow
  convergence but can never stall training);
* `calibrate()` measures per-sample gradient times on both lanes and returns
  the system-aware b' = (T_f / T_s) * b of paper §3.3.

Lanes may live on different jax devices (CPU + accelerator on real machines;
two CPU streams in this container). All queue hand-offs are host arrays, so
the scheme also models the PCIe hop of the paper's CPU<->GPU setup.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core import (Compressor, MethodConfig, StalenessLedger, TrainState,
                        make_ascent_fn, make_descent_fn, split_batch,
                        system_aware_ascent_fraction)
from repro.core.api import LossFn
from repro.optim import GradientTransform
from repro.utils import trees

Pytree = Any


@dataclasses.dataclass
class ExecutorConfig:
    max_staleness: int = 4
    ascent_device: Optional[jax.Device] = None   # the "slow" resource
    descent_device: Optional[jax.Device] = None  # the "fast" resource
    ascent_delay_s: float = 0.0                  # test hook: straggler injection
    # flat-buffer fused perturb + optimizer epilogue on the descent lane;
    # None -> platform default (on for TPU, off for CPU — ops._resolve style)
    fused_update: Optional[bool] = None


class AsyncSamExecutor:
    def __init__(self, loss_fn: LossFn, method_cfg: MethodConfig,
                 optimizer: GradientTransform,
                 exec_cfg: Optional[ExecutorConfig] = None):
        self.xcfg = exec_cfg or ExecutorConfig()
        fused_update = self.xcfg.fused_update
        if fused_update is None:
            fused_update = jax.default_backend() == "tpu"
        from repro.optim import configure_fused
        optimizer = configure_fused(optimizer, fused_update)
        method_cfg = dataclasses.replace(method_cfg, fused_update=fused_update)
        self.cfg = method_cfg
        self.ledger = StalenessLedger(max_staleness=self.xcfg.max_staleness)
        # lossy compression of the cross-resource hand-off (the perturbation
        # direction tolerates quantization by the same sigma^2/b' argument
        # that tolerates b' < b; DESIGN.md §2)
        self._compressor = Compressor(kind=method_cfg.compressor,
                                      topk_fraction=method_cfg.topk_fraction)
        self._comp_state = None
        self.wire_bytes_per_exchange = 0
        self._ascent_raw = jax.jit(make_ascent_fn(loss_fn))
        self._norm = jax.jit(trees.global_norm)
        self._descent = jax.jit(make_descent_fn(method_cfg, loss_fn, optimizer),
                                donate_argnums=(0,))
        self._jobs: queue.Queue = queue.Queue(maxsize=1)
        self._results: queue.Queue = queue.Queue(maxsize=1)
        self._gen = 0            # bumped by reset(): fences off in-flight work
        self._stop = threading.Event()
        self._closed = False
        self._thread = threading.Thread(target=self._ascent_worker, daemon=True)
        self._thread.start()
        # held perturbation direction (host-side fp32 pytree)
        self._held: Optional[tuple[Pytree, jax.Array]] = None
        self.timings = {"ascent": [], "descent": []}

    # --- ascent lane -----------------------------------------------------------
    def _place(self, tree: Pytree, device) -> Pytree:
        if device is None:
            return tree
        return jax.tree.map(lambda x: jax.device_put(x, device), tree)

    def _ascent_worker(self) -> None:
        while not self._stop.is_set():
            try:
                gen, params, batch, rng = self._jobs.get(timeout=0.1)
            except queue.Empty:
                continue
            if self._stop.is_set():   # shutting down: don't start new compute
                break
            t0 = time.perf_counter()
            if self.xcfg.ascent_delay_s:
                time.sleep(self.xcfg.ascent_delay_s)  # injected straggle
            params = self._place(params, self.xcfg.ascent_device)
            batch = self._place(batch, self.xcfg.ascent_device)
            g, norm, _ = self._ascent_raw(params, batch, rng)
            if self._compressor.kind != "none":
                if self._comp_state is None:
                    self._comp_state = self._compressor.init(g)
                g, self._comp_state = self._compressor.compress(g, self._comp_state)
                # one fused on-device reduction, one host sync — not a
                # per-leaf Python float round-trip
                norm = float(self._norm(g))
            else:
                norm = float(norm)
            self.wire_bytes_per_exchange = self._compressor.wire_bytes(g)
            g = jax.device_get(g)           # model the cross-resource hop
            self.timings["ascent"].append(time.perf_counter() - t0)
            try:
                self._results.put((gen, g, norm), timeout=1.0)
            except queue.Full:
                pass                         # consumer lagging: drop (stale anyway)

    # --- step ------------------------------------------------------------------
    def step(self, state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        descent_batch, ascent_batch = split_batch(batch)
        if ascent_batch is None:
            from repro.core import slice_ascent_batch
            ascent_batch = slice_ascent_batch(descent_batch,
                                              self.cfg.ascent_fraction)

        # harvest a finished ascent gradient (fresh => tau resets to 1);
        # results from a pre-reset() generation are discarded
        try:
            gen, g, norm = self._results.get_nowait()
            if gen == self._gen:
                self._held = (g, norm)
                self.ledger.on_fresh()
                have = True
            else:
                have = self._held is not None and self.ledger.on_reuse()
        except queue.Empty:
            have = self._held is not None and self.ledger.on_reuse()

        # submit the next ascent job against the CURRENT params (it will be
        # one step old when used — Algorithm 1 line 3)
        if not self._jobs.full():
            rng = jax.random.fold_in(state.rng, state.step)
            self._jobs.put_nowait((self._gen, jax.device_get(state.params),
                                   ascent_batch, rng))

        t0 = time.perf_counter()
        if self._held is not None:
            g, norm = self._held
        else:
            g, norm = trees.tree_zeros_like(state.params), 0.0
        new_state, metrics = self._descent(
            state, descent_batch, g, np.float32(norm), np.bool_(have))
        jax.block_until_ready(new_state.params)
        self.timings["descent"].append(time.perf_counter() - t0)
        metrics = dict(metrics)
        metrics["tau"] = self.ledger.tau
        metrics["perturbed"] = float(have)
        return new_state, metrics

    def reset(self) -> None:
        """Drop held and in-flight ascent state (e.g. after a checkpoint
        restore rolled the params back): the next step perturbs only with a
        gradient computed against post-reset params. The generation fence
        keeps a result the worker is still computing from being consumed."""
        self._gen += 1
        for q in (self._jobs, self._results):
            try:
                while True:
                    q.get_nowait()
            except queue.Empty:
                pass
        self._held = None
        self.ledger.tau = 0

    # --- system-aware b' (paper §3.3) -------------------------------------------
    def calibrate(self, state: TrainState, batch: dict, probes: int = 3) -> float:
        """Measure per-sample grad times on both lanes; return suggested b'/b."""
        descent_batch, ascent_batch = split_batch(batch)
        if ascent_batch is None:
            ascent_batch = descent_batch
        rng = state.rng
        # warmup + timed runs on the ascent (slow) lane
        a_in = self._place(state.params, self.xcfg.ascent_device)
        b_in = self._place(ascent_batch, self.xcfg.ascent_device)
        jax.block_until_ready(self._ascent_raw(a_in, b_in, rng)[0])
        t0 = time.perf_counter()
        for _ in range(probes):
            if self.xcfg.ascent_delay_s:
                time.sleep(self.xcfg.ascent_delay_s)
            jax.block_until_ready(self._ascent_raw(a_in, b_in, rng)[0])
        n_asc = jax.tree.leaves(ascent_batch)[0].shape[0]
        t_slow = (time.perf_counter() - t0) / probes / n_asc

        # descent lane per-sample time (reuse ascent_fn as the probe kernel)
        d_in = self._place(state.params, self.xcfg.descent_device)
        db_in = self._place(descent_batch, self.xcfg.descent_device)
        jax.block_until_ready(self._ascent_raw(d_in, db_in, rng)[0])
        t0 = time.perf_counter()
        for _ in range(probes):
            jax.block_until_ready(self._ascent_raw(d_in, db_in, rng)[0])
        n_desc = jax.tree.leaves(descent_batch)[0].shape[0]
        t_fast = (time.perf_counter() - t0) / probes / n_desc
        return system_aware_ascent_fraction(t_fast, t_slow)

    def close(self) -> None:
        """Stop the ascent thread. Idempotent: double-close and
        close-after-thread-death are both no-ops.

        The join budget is generous: exiting the interpreter while the worker
        is still inside jitted XLA compute aborts the process (std::terminate
        from native thread teardown), so waiting out an in-flight ascent —
        even one paying a compile — is the cheap option.
        """
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        try:
            self._jobs.get_nowait()       # cancel an unstarted job
        except queue.Empty:
            pass
        if self._thread.is_alive():
            self._thread.join(timeout=30.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
