"""Heterogeneous asynchronous executor — the paper's two-process scheme.

Faithful port of the MPI design (paper §3.3/§3.4) onto host threads + a
depth-1 queue:

* the DESCENT lane (fast resource) runs `descent_fn` — one model update per
  step, perturbing with whatever ascent gradient is currently held;
* the ASCENT lane (slow resource) runs `ascent_fn` on b' samples against a
  *snapshot* of the parameters — by construction one step old when consumed:
  tau = 1 (Algorithm 1);
* if the ascent lane has not delivered by the time the descent lane needs it,
  the held gradient is reused and its age grows (tau = 2, 3, ...) up to
  `max_staleness`, after which the step degrades to plain SGD — the
  AsyncSAM-specific straggler mitigation (a straggling helper can slow
  convergence but can never stall training);
* `calibrate()` measures per-sample gradient times on both lanes and returns
  the system-aware b' = (T_f / T_s) * b of paper §3.3.

The ascent lane is pluggable: the default `ThreadAscentLane` runs on a
dedicated host thread (two jax devices inside one process — CPU + accelerator
on real machines); `repro.service.RemoteAscentClient` satisfies the same lane
protocol over TCP/Unix sockets, moving the ascent resource to another process
or host (`engine.RemoteExecutor`). Both lanes share `ascent_exchange` — the
single function that owns the ascent-worker math (gradient, compression with
error feedback, norm, wire-byte accounting, host hand-off) — so the
in-process worker and the standalone `repro.service.ascent_server` compute
byte-identical exchanges.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Compressor, MethodConfig, StalenessLedger, TrainState,
                        make_ascent_fn, make_descent_fn, split_batch,
                        system_aware_ascent_fraction)
from repro.core.ascent import CompressionState
from repro.core.api import LossFn
from repro.obs import current_tracker, trace_now
from repro.optim import GradientTransform
from repro.utils import buckets, trees

Pytree = Any


@dataclasses.dataclass
class ExecutorConfig:
    max_staleness: int = 4
    ascent_device: Optional[jax.Device] = None   # the "slow" resource
    descent_device: Optional[jax.Device] = None  # the "fast" resource
    ascent_delay_s: float = 0.0                  # test hook: straggler injection
    # flat-buffer fused perturb + optimizer epilogue on the descent lane;
    # None -> platform default (on for TPU, off for CPU — ops._resolve style)
    fused_update: Optional[bool] = None
    # bucket-RESIDENT descent-lane state: params/moments live as persistent
    # dtype buckets and the jitted descent step is buffer -> buffer (donated).
    # None follows fused_update when the chain qualifies (uncompressed
    # exchange + FusedSpec-recognized optimizer). The ascent hand-off stays
    # pytree-shaped either way — the lane/wire contract is unchanged.
    resident: Optional[bool] = None
    # deterministic test mode: block for every submitted ascent result before
    # the next harvest, so the tau schedule is timing-independent (step 0
    # unperturbed, tau=1 thereafter) — the hook parity tests use to compare
    # the in-process and remote lanes step for step
    lockstep: bool = False
    # numerics guard (runtime.guard): None follows MethodConfig.guard_update;
    # True/False overrides it for this executor (the launcher sets True under
    # --guard so the in-step skip protects every lane)
    guard_update: Optional[bool] = None
    # --- remote lane (engine.RemoteExecutor / repro.service) ----------------
    ascent_addr: str = ""          # "host:port" or "unix:/path" of the server
    serve_ascent: bool = False     # loopback: spawn the server as a subprocess
    loss_spec: str = ""            # server-side loss ("module:attr" | "arch:NAME[:reduced]")
    connect_timeout_s: float = 60.0
    reconnect_backoff_s: float = 0.25
    max_server_respawns: int = 1   # loopback only: respawn a server that died
    # JOB-direction (params snapshot out) encoding: "none" ships full fp32
    # snapshots (PR-3 behavior, lockstep remote==hetero parity pinned);
    # "int8"/"topk" + job_delta delta-encode against the server's shadow of
    # the last-synced params (service.delta), cutting the wire's dominant
    # direction ~4x. Degrades to snapshots against a revision-1 server.
    job_compress: str = "none"
    job_delta: bool = True
    # --- multi-client pool (service.pool.AscentPool) ------------------------
    client_id: str = ""            # stable identity; "" -> per-client default
    sync_group: str = ""           # `global` ascent-sync group: same-group
    #                                clients get the pool's shared smoothed
    #                                ascent gradient per (generation, step)
    auth_token: str = ""           # shared secret for non-loopback pools
    pool_workers: int = 0          # loopback spawn only: 0 = server default
    # --- health-driven degradation ladder (runtime.health) ------------------
    # off by default: the ladder swaps lanes at runtime, which is
    # intentionally invisible to the lockstep parity/bitwise tests
    lane_ladder: bool = False
    health_window: int = 16        # rolling exchange-outcome window
    health_error_threshold: float = 0.5
    health_min_samples: int = 4
    health_stall_timeout_s: float = 30.0   # silence-with-outstanding = stall
    ladder_probation_steps: int = 8
    ladder_cooldown_steps: int = 16
    # --- server watchdog (engine.RemoteExecutor loopback) -------------------
    watchdog: bool = False         # scrape STATS; restart dead/wedged server
    watchdog_interval_s: float = 5.0
    watchdog_wedge_scrapes: int = 3
    watchdog_max_restarts: int = 2


# ---------------------------------------------------------------------------
# Shared ascent-worker math (in-process lane AND repro.service.ascent_server)
# ---------------------------------------------------------------------------

def place_tree(tree: Pytree, device) -> Pytree:
    if device is None:
        return tree
    return jax.tree.map(lambda x: jax.device_put(x, device), tree)


def ascent_exchange(ascent_fn: Callable, norm_fn: Callable,
                    compressor: Compressor,
                    comp_state: Optional[CompressionState],
                    params: Pytree, batch: Pytree, rng,
                    *, device=None, delay_s: float = 0.0
                    ) -> tuple[Pytree, float, int, Optional[CompressionState]]:
    """One ascent-lane exchange: gradient -> (lossy) hand-off value.

    Returns (host fp32 gradient tree, float norm, payload wire bytes, new
    compression state). `ascent_fn`/`norm_fn` are jitted `make_ascent_fn` /
    `trees.global_norm`; error feedback accumulates in `comp_state` on
    whichever side runs this (worker thread or ascent server).
    """
    if delay_s:
        time.sleep(delay_s)  # injected straggle (tests/benchmarks)
    params = place_tree(params, device)
    batch = place_tree(batch, device)
    g, norm, _ = ascent_fn(params, batch, rng)
    if compressor.kind != "none":
        if comp_state is None:
            comp_state = compressor.init(g)
        g, comp_state = compressor.compress(g, comp_state)
        # one fused on-device reduction, one host sync — not a
        # per-leaf Python float round-trip
        norm = float(norm_fn(g))
    else:
        norm = float(norm)
    wire = compressor.wire_bytes(g)
    g = jax.device_get(g)           # model the cross-resource hop
    return g, norm, wire, comp_state


# ---------------------------------------------------------------------------
# Ascent-lane protocol + the default in-process thread lane
# ---------------------------------------------------------------------------

def poll_queue(q: queue.Queue, block: bool = False,
               timeout: Optional[float] = None):
    """Shared lane-poll: non-raising get; None when nothing is ready."""
    try:
        if block:
            return q.get(timeout=timeout)
        return q.get_nowait()
    except queue.Empty:
        return None


def drain_queue(q: queue.Queue) -> None:
    try:
        while True:
            q.get_nowait()
    except queue.Empty:
        pass


@runtime_checkable
class AscentLane(Protocol):
    """Where the ascent gradient comes from (thread, or another host).

    Results are (gen, grad_tree, norm, meta) tuples; `meta` carries
    lane-specific telemetry (ascent_time_s, wire_bytes, rtt_s) the executor
    forwards into its step metrics.
    """

    def full(self) -> bool: ...

    def submit(self, gen: int, params: Pytree, batch: Pytree, rng,
               step: int) -> bool: ...

    def poll(self, block: bool = False, timeout: Optional[float] = None
             ) -> Optional[tuple]: ...

    def reset(self) -> None: ...

    def close(self) -> None: ...


class ThreadAscentLane:
    """The PR-1 lane: dedicated worker thread + depth-1 job/result queues."""

    #: trace track this lane's compute spans render on
    lane_name = "ascent-thread"

    def __init__(self, ascent_fn: Callable, norm_fn: Callable,
                 compressor: Compressor, *, device=None, delay_s: float = 0.0):
        self._ascent_fn = ascent_fn
        self._norm_fn = norm_fn
        self._compressor = compressor
        self._comp_state = None
        self._device = device
        self._delay_s = delay_s
        self.wire_bytes_per_exchange = 0
        self.timings: list[float] = []
        self._jobs: queue.Queue = queue.Queue(maxsize=1)
        self._results: queue.Queue = queue.Queue(maxsize=1)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        while not self._stop.is_set():
            try:
                gen, params, batch, rng, _step = self._jobs.get(timeout=0.1)
            except queue.Empty:
                continue
            if self._stop.is_set():   # shutting down: don't start new compute
                break
            t0 = time.perf_counter()
            with current_tracker().span("ascent_compute",
                                        lane=self.lane_name,
                                        gen=gen, step=_step):
                g, norm, wire, self._comp_state = ascent_exchange(
                    self._ascent_fn, self._norm_fn, self._compressor,
                    self._comp_state, params, batch, rng,
                    device=self._device, delay_s=self._delay_s)
            self.wire_bytes_per_exchange = wire
            dt = time.perf_counter() - t0
            self.timings.append(dt)
            try:
                self._results.put((gen, g, norm, {"ascent_time_s": dt}),
                                  timeout=1.0)
            except queue.Full:
                pass                 # consumer lagging: drop (stale anyway)

    def full(self) -> bool:
        return self._jobs.full()

    def submit(self, gen, params, batch, rng, step) -> bool:
        try:
            self._jobs.put_nowait((gen, params, batch, rng, step))
        except queue.Full:
            return False
        return True

    def poll(self, block: bool = False, timeout: Optional[float] = None):
        return poll_queue(self._results, block, timeout)

    def probe(self, params: Pytree, batch: Pytree, rng, probes: int) -> float:
        """Timed inline ascent runs (warmup excluded) for calibrate()."""
        p_in = place_tree(params, self._device)
        b_in = place_tree(batch, self._device)
        jax.block_until_ready(self._ascent_fn(p_in, b_in, rng)[0])
        t0 = time.perf_counter()
        for _ in range(probes):
            if self._delay_s:
                time.sleep(self._delay_s)
            jax.block_until_ready(self._ascent_fn(p_in, b_in, rng)[0])
        return time.perf_counter() - t0

    def reset(self) -> None:
        drain_queue(self._jobs)
        drain_queue(self._results)

    def close(self) -> None:
        """Stop the worker. Shutdown-safe ordering: signal stop, then drain
        BOTH queues (a worker blocked in `results.put` must not wait out its
        timeout against a consumer that already left), then join.

        The join budget is generous: exiting the interpreter while the worker
        is still inside jitted XLA compute aborts the process (std::terminate
        from native thread teardown), so waiting out an in-flight ascent —
        even one paying a compile — is the cheap option.
        """
        self._stop.set()
        self.reset()
        if self._thread.is_alive():
            self._thread.join(timeout=30.0)


class LedgerOnlyLane:
    """The ladder's bottom rung: no ascent source at all.

    `full()` is always True so the executor never submits (and never pays
    the params materialization); `poll()` never delivers. The held gradient
    keeps aging on the staleness ledger and, past max_staleness, every step
    is plain SGD — descent-only training, the AsyncSAM guarantee that a dead
    helper can slow convergence but never stall the run.
    """

    lane_name = "ascent-none"

    def full(self) -> bool:
        return True

    def submit(self, gen, params, batch, rng, step) -> bool:
        return False

    def poll(self, block: bool = False, timeout=None):
        return None

    def reset(self) -> None:
        pass

    def close(self) -> None:
        pass


class AsyncSamExecutor:
    def __init__(self, loss_fn: LossFn, method_cfg: MethodConfig,
                 optimizer: GradientTransform,
                 exec_cfg: Optional[ExecutorConfig] = None,
                 ascent_lane: Optional[AscentLane] = None):
        self.xcfg = exec_cfg or ExecutorConfig()
        fused_update = self.xcfg.fused_update
        if fused_update is None:
            fused_update = jax.default_backend() == "tpu"
        from repro.optim import configure_fused
        optimizer = configure_fused(optimizer, fused_update)
        method_cfg = dataclasses.replace(method_cfg, fused_update=fused_update)
        if self.xcfg.guard_update is not None:
            method_cfg = dataclasses.replace(
                method_cfg, guard_update=self.xcfg.guard_update)
        resident = self.xcfg.resident
        if resident is None:
            resident = (bool(fused_update)
                        and method_cfg.compressor == "none"
                        and getattr(optimizer, "fused_spec", None) is not None)
        self.resident = bool(resident)
        self.cfg = method_cfg
        self.ledger = StalenessLedger(max_staleness=self.xcfg.max_staleness)
        # lossy compression of the cross-resource hand-off (the perturbation
        # direction tolerates quantization by the same sigma^2/b' argument
        # that tolerates b' < b; DESIGN.md §2)
        self._compressor = Compressor(kind=method_cfg.compressor,
                                      topk_fraction=method_cfg.topk_fraction)
        self._ascent_raw = jax.jit(make_ascent_fn(loss_fn))
        self._norm = jax.jit(trees.global_norm)
        self._descent = jax.jit(make_descent_fn(method_cfg, loss_fn, optimizer),
                                donate_argnums=(0,))
        self._lane: AscentLane = ascent_lane if ascent_lane is not None else \
            ThreadAscentLane(self._ascent_raw, self._norm, self._compressor,
                             device=self.xcfg.ascent_device,
                             delay_s=self.xcfg.ascent_delay_s)
        # --- degradation ladder (runtime.health): remote -> local -> ledger.
        # Level 0 is whatever lane was configured above; the local thread
        # lane is built lazily on first failover (it holds a whole extra
        # worker thread), and the demoted primary stays OPEN while degraded —
        # a remote client keeps reconnecting in the background, which is
        # exactly the readiness signal promotion gates on.
        self._ladder = self._health = None
        self._local_lane: Optional[ThreadAscentLane] = None
        self._ledger_lane = LedgerOnlyLane()
        self._announce_ladder = False
        if self.xcfg.lane_ladder:
            from repro.runtime.health import LaneHealth, LaneLadder
            self._ladder = LaneLadder(
                probation_steps=self.xcfg.ladder_probation_steps,
                cooldown_steps=self.xcfg.ladder_cooldown_steps)
            self._health = LaneHealth(
                window=self.xcfg.health_window,
                error_threshold=self.xcfg.health_error_threshold,
                min_samples=self.xcfg.health_min_samples,
                stall_timeout_s=self.xcfg.health_stall_timeout_s)
        self._primary_lane = self._lane
        self._gen = 0            # bumped by reset(): fences off in-flight work
        self._inflight = 0       # results the lane still owes (lockstep gate)
        self._closed = False
        # held perturbation direction (host-side fp32 pytree)
        self._held: Optional[tuple[Pytree, float]] = None
        # numerics-guard lane hooks (runtime.guard drives both); the
        # non-finite-harvest drop below is always on — a NaN norm means the
        # whole gradient is unusable as a perturbation direction (0*NaN=NaN)
        self._rho_scale = 1.0
        self.nonfinite_drops = 0
        # cached pytree-shaped zeros for steps with no held gradient
        self._zeros: Optional[Pytree] = None
        self._exchange_meta: dict = {}
        # submit timestamps of in-flight jobs (FIFO — the lanes are ordered
        # queues), so a harvest can emit its full submit→harvest trace span
        self._submit_t: list[float] = []
        self.timings = {"ascent": getattr(self._lane, "timings", []),
                        "descent": []}

    @property
    def wire_bytes_per_exchange(self) -> int:
        return getattr(self._lane, "wire_bytes_per_exchange", 0)

    # --- step ------------------------------------------------------------------
    def step(self, state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        descent_batch, ascent_batch = split_batch(batch)
        if ascent_batch is None:
            from repro.core import slice_ascent_batch
            ascent_batch = slice_ascent_batch(descent_batch,
                                              self.cfg.ascent_fraction)

        # harvest a finished ascent gradient (fresh => tau resets to 1);
        # results from a pre-reset() generation are discarded
        trk = current_tracker()
        block = self.xcfg.lockstep and self._inflight > 0
        got = self._lane.poll(block=block, timeout=120.0 if block else None)
        self._exchange_meta = {}
        if got is not None:
            self._inflight = max(0, self._inflight - 1)
            t_sub = self._submit_t.pop(0) if self._submit_t else None
            gen, g, norm, meta = got
            if self._health is not None and gen == self._gen:
                # one exchange concluded on the ACTIVE lane: feed the
                # rolling health window (g=None is the lost-exchange
                # sentinel; pre-swap generations don't count against it)
                self._health.record(g is not None, meta.get("rtt_s"))
            if g is not None and gen == self._gen and not np.isfinite(norm):
                # non-finite harvest: treat exactly like a lost exchange —
                # holding it would poison every later perturbation (the
                # carried direction multiplies into w_hat even at rho_eff=0)
                self.nonfinite_drops += 1
                trk.event("ascent_nonfinite_drop", lane="guard",
                          drops=self.nonfinite_drops, step=int(state.step))
                g = None
            if g is not None and gen == self._gen:
                self._held = (g, norm)
                self._exchange_meta = dict(meta)
                self.ledger.on_fresh()
                have = True
                if t_sub is not None:
                    # the whole asynchronous window this exchange lived in:
                    # submit on a past step -> harvested now
                    trk.span_at("ascent_exchange",
                                lane=getattr(self._lane, "lane_name",
                                             "ascent-thread"),
                                t0=t_sub, t1=trace_now(),
                                tau=self.ledger.tau, gen=gen,
                                step=int(state.step))
            else:
                # g is None: the lane's lost-exchange sentinel (server error
                # or dropped connection) — reuse/age like any missed refresh
                have = self._held is not None and self.ledger.on_reuse()
        else:
            if block:
                # the blocking wait timed out: that exchange is lost (dead
                # lane/connection) — stop waiting for it on later steps
                self._inflight = max(0, self._inflight - 1)
                if self._submit_t:
                    self._submit_t.pop(0)
                if self._health is not None:
                    self._health.record(False)
            have = self._held is not None and self.ledger.on_reuse()

        # degradation ladder: verdicts from the window just updated, BEFORE
        # the submit below, so a post-swap lane receives this step's job
        self._evaluate_ladder()

        # submit the next ascent job against the CURRENT params (it will be
        # one step old when used — Algorithm 1 line 3); the full-check comes
        # first so a busy lane never costs the whole-model D2H materialization.
        # The lane/wire hand-off is pytree-shaped: bucket-resident params
        # leave the buffer representation at this edge only — transferred as
        # whole buckets and cut into numpy views on the host (host_portable),
        # so residency adds no device-side view pass to the exchange. A lane
        # that encodes its own jobs (the remote client's delta encoder) gets
        # the raw device params instead: the encode runs here, synchronously,
        # while the donated buffers are still alive, and ships the quantized
        # delta across the host hop instead of the full fp32 snapshot.
        if not self._lane.full():
            rng = jax.random.fold_in(state.rng, state.step)
            lane_params = (state.params
                           if getattr(self._lane, "encodes_jobs", False)
                           else buckets.host_portable(state.params))
            if self._lane.submit(self._gen, lane_params,
                                 ascent_batch, rng, int(state.step)):
                self._inflight += 1
                self._submit_t.append(trace_now())
                if self._health is not None:
                    self._health.note_submit()

        t0 = time.perf_counter()
        if self._held is not None:
            g, norm = self._held
        else:
            # pytree-shaped zeros either way, so the jitted descent keeps ONE
            # input structure for `a` whether it came from the lane or here;
            # built from abstract shapes once (no device view pass) and cached
            if self._zeros is None:
                sds = jax.eval_shape(lambda: buckets.to_portable(state.params))
                self._zeros = jax.tree.map(
                    lambda s: jnp.zeros(s.shape, s.dtype), sds)
            g, norm = self._zeros, 0.0
        # numerics-guard de-escalation: perturb computes rho/||a||, so feeding
        # norm/scale scales the effective rho by `scale` without touching the
        # jitted program; scale 0 is the bottom rung — plain descent
        scale = self._rho_scale
        if scale <= 0.0:
            have = False
        eff_norm = norm / scale if 0.0 < scale != 1.0 else norm
        with trk.span("descent_compute", lane="descent",
                      step=int(state.step), perturbed=bool(have)):
            new_state, metrics = self._descent(
                state, descent_batch, g, np.float32(eff_norm), np.bool_(have))
            jax.block_until_ready(new_state.params)
        self.timings["descent"].append(time.perf_counter() - t0)
        metrics = dict(metrics)
        metrics["tau"] = self.ledger.tau
        metrics["perturbed"] = float(have)
        # the UNscaled held norm, every step — the guard's stale-ascent bound
        # calibrates on this rolling history (0.0 = nothing held, ignored)
        metrics["ascent_norm"] = float(norm)
        # remote-lane telemetry, present only on the step that actually
        # harvested an exchange (summing a jsonl's wire_bytes column then
        # gives true total traffic) and only when the lane reports it, so
        # the in-process lane's metric surface is unchanged; job_bytes /
        # grad_bytes split wire_bytes by direction (job + grad == wire);
        # pool_depth / pool_wait_s / client_id are the pool-lane fleet
        # telemetry (ENGINE_OPTIONAL_METRIC_KEYS mirrors this list)
        for key in ("wire_bytes", "job_bytes", "grad_bytes", "rtt_s",
                    "pool_depth", "pool_wait_s", "client_id"):
            if key in self._exchange_meta:
                metrics[key] = float(self._exchange_meta[key])
        # ladder telemetry: the current rung every step (ladder runs only),
        # cumulative transition counters only on the step right after a
        # transition — the `resize_events` emission pattern, so summing a
        # jsonl column never double-counts
        if self._ladder is not None:
            metrics["lane_state"] = float(self._ladder.level)
            if self._announce_ladder:
                self._announce_ladder = False
                metrics["lane_failovers"] = float(self._ladder.failovers)
                metrics["lane_recoveries"] = float(self._ladder.recoveries)
        return new_state, metrics

    # --- degradation ladder (runtime.health) -----------------------------------
    def _evaluate_ladder(self) -> None:
        """One per-step ladder decision: demote on an unhealthy or stalled
        window, promote one rung after cooldown when the upper lane is
        ready. Transitions fence the generation (a result the old lane still
        owes must not be consumed) but KEEP the held gradient — it is still
        a valid perturbation direction that ages on the staleness ledger."""
        ladder, health = self._ladder, self._health
        if ladder is None:
            return
        ladder.tick()
        if health.unhealthy() or health.stalled():
            if ladder.demote():
                self._swap_lane("lane_failover")
            else:
                health.reset()   # already at the bottom: clear the verdict
        elif ladder.can_promote() and self._upper_ready(ladder.level - 1):
            ladder.promote()
            self._swap_lane("lane_recovery")

    def _upper_ready(self, level: int) -> bool:
        """May the ladder promote INTO `level`? The primary rung requires a
        live connection and no fatal (auth) rejection — a remote client
        keeps reconnecting in the background while demoted, so its
        `connected` event is exactly the recovery signal; lanes without one
        (the in-process thread lane) are always ready."""
        if level == 0:
            lane = self._primary_lane
            if getattr(lane, "fatal_error", ""):
                return False
            conn = getattr(lane, "connected", None)
            return conn.is_set() if conn is not None else True
        return True

    def _lane_for_level(self, level: int):
        if level == 0:
            return self._primary_lane
        if level == 1:
            if self._local_lane is None:
                self._local_lane = ThreadAscentLane(
                    self._ascent_raw, self._norm, self._compressor,
                    device=self.xcfg.ascent_device,
                    delay_s=self.xcfg.ascent_delay_s)
            return self._local_lane
        return self._ledger_lane

    def _swap_lane(self, event: str) -> None:
        from repro.runtime.health import LADDER_LEVELS
        old = self._lane
        self._gen += 1               # fence off the old lane's in-flight work
        self._inflight = 0
        self._submit_t.clear()
        old.reset()
        self._lane = self._lane_for_level(self._ladder.level)
        self._lane.reset()
        self._health.reset()
        self._announce_ladder = True
        current_tracker().event(event, lane="health",
                                level=self._ladder.level,
                                rung=LADDER_LEVELS[self._ladder.level],
                                failovers=self._ladder.failovers,
                                recoveries=self._ladder.recoveries)

    def reset(self) -> None:
        """Drop held and in-flight ascent state (e.g. after a checkpoint
        restore rolled the params back, or after the remote lane reconnected):
        the next step perturbs only with a gradient computed against
        post-reset params. The generation fence keeps a result the lane is
        still computing from being consumed."""
        self._gen += 1
        self._inflight = 0
        self._submit_t.clear()
        self._lane.reset()
        self._held = None
        self.ledger.tau = 0
        if self._health is not None:
            self._health.reset()   # fenced-off exchanges are not evidence

    # --- numerics-guard lane hooks (runtime.guard.GuardedExecutor) --------------
    def set_rho_scale(self, scale: float) -> None:
        """De-escalation rung: scale the effective rho of every later step
        (1.0 = undegraded, 0.0 = plain descent). Applied at perturbation
        time, so it never touches the held gradient or the jitted program."""
        self._rho_scale = float(scale)

    def drop_ascent(self) -> None:
        """Discard the held ascent gradient (stale-ascent verdict) without
        fencing the lane: an in-flight exchange may still deliver a fresh,
        sane replacement next step."""
        self._held = None
        self.ledger.tau = 0

    # --- system-aware b' (paper §3.3) -------------------------------------------
    def calibrate(self, state: TrainState, batch: dict, probes: int = 3) -> float:
        """Measure per-sample grad times on both lanes; return suggested b'/b.

        The ascent probe goes through the lane (`AscentLane.probe`), so for a
        remote lane it measures the real thing: server compute + the wire.
        """
        descent_batch, ascent_batch = split_batch(batch)
        if ascent_batch is None:
            ascent_batch = descent_batch
        rng = state.rng
        # probes run the raw (pytree) ascent fn — view resident params out
        params = jax.device_get(buckets.to_portable(state.params))
        elapsed = self._lane.probe(params, jax.device_get(ascent_batch),
                                   rng, probes)
        n_asc = jax.tree.leaves(ascent_batch)[0].shape[0]
        t_slow = elapsed / probes / n_asc

        # descent lane per-sample time (reuse ascent_fn as the probe kernel)
        d_in = place_tree(buckets.to_portable(state.params),
                          self.xcfg.descent_device)
        db_in = place_tree(descent_batch, self.xcfg.descent_device)
        jax.block_until_ready(self._ascent_raw(d_in, db_in, rng)[0])
        t0 = time.perf_counter()
        for _ in range(probes):
            jax.block_until_ready(self._ascent_raw(d_in, db_in, rng)[0])
        n_desc = jax.tree.leaves(descent_batch)[0].shape[0]
        t_fast = (time.perf_counter() - t0) / probes / n_desc
        return system_aware_ascent_fraction(t_fast, t_slow)

    def close(self) -> None:
        """Stop the ascent lane. Idempotent: double-close and
        close-after-thread-death are both no-ops."""
        if self._closed:
            return
        self._closed = True
        # the ladder may have built extra lanes; close every distinct one
        lanes = [self._lane, self._primary_lane]
        if self._local_lane is not None:
            lanes.append(self._local_lane)
        seen: list = []
        for lane in lanes:
            if not any(lane is s for s in seen):
                seen.append(lane)
                lane.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
