"""Elastic scaling: move a training state between meshes of different size.

A checkpoint written on one mesh restores onto another because the manager
stores full (unsharded) host arrays; this module provides the in-memory
equivalent — `reshard_state(state, cfg, new_mesh)` re-device_puts every leaf
against the sharding rules evaluated on the new mesh. Combined with the
fault-tolerant driver this implements shrink/grow recovery: lose a pod ->
restore the last checkpoint onto the surviving 16x16 mesh and keep training
(global batch is preserved; per-device batch grows).

tests/test_elastic.py round-trips 1-device -> 8-device(2x4) -> 4-device(2x2)
and asserts loss-trajectory equality against an unresharded run.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.launch.sharding import state_spec_tree, to_named
from repro.models.config import ModelConfig

Pytree = Any


def state_shardings(state_like: Pytree, cfg: ModelConfig, mesh) -> Pytree:
    """NamedShardings for a TrainState(-like) pytree on `mesh`."""
    return to_named(state_spec_tree(state_like, cfg, mesh), mesh)


def reshard_state(state: Pytree, cfg: ModelConfig, new_mesh) -> Pytree:
    """Re-place every leaf of `state` onto `new_mesh` under the arch rules."""
    shardings = state_shardings(jax.eval_shape(lambda: state), cfg, new_mesh)
    flat_s, treedef = jax.tree.flatten(state)
    flat_sh = jax.tree.leaves(shardings, is_leaf=lambda x: hasattr(x, "spec"))
    out = [jax.device_put(jax.device_get(x), sh)
           for x, sh in zip(flat_s, flat_sh)]
    return jax.tree.unflatten(treedef, out)
