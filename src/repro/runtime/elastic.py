"""Elastic scaling: move a training state between meshes of different size.

A checkpoint written on one mesh restores onto another because the manager
stores full (unsharded) host arrays; this module provides the in-memory
equivalent — `reshard_state(state, cfg, new_mesh)` re-places every leaf
against the sharding rules evaluated on the new mesh, as ONE batched
transfer: when every source device is addressable from this process (always
true in-process, and in particular whenever source and target meshes share
devices) the arrays move device-to-device with no host round-trip; only a
state whose buffers live on unaddressable devices pays a single batched
device_get. Combined with the fault-tolerant driver and the chaos harness
(`runtime.chaos`) this implements shrink/grow recovery: lose a pod -> reshard
(or restore) onto the surviving mesh and keep training; capacity arrives ->
grow back. The global batch is preserved either way; only the per-device
slice changes.

Bucket-resident state (`utils.buckets.BucketedState`) re-places onto an
*unsharded* target directly (the buffers move wholesale; the layout is
mesh-independent, so `buckets.rebucket` is an identity re-group); a sharded
target raises — flattening a model-sharded leaf into a global bucket would
silently all-gather, and per-shard bucketing is the ROADMAP follow-on.

tests/test_elastic.py pins the chaos-driven shrink/grow trajectories;
tests/test_runtime.py round-trips 8-device(4x2) -> 8-device(2x4) raw state.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.sharding import state_spec_tree, to_named
from repro.models.config import ModelConfig
from repro.utils import buckets

Pytree = Any


def make_sized_mesh(devices: int, model_axis: int = 1):
    """A (data, model) mesh over the first `devices` local devices.

    Unlike `launch.mesh.make_host_mesh` this does NOT claim every visible
    device — a shrink builds the survivor mesh over a prefix of the fleet,
    a grow takes the prefix back up. Deterministic device order keeps
    scripted chaos schedules reproducible.
    """
    devs = jax.devices()
    if devices > len(devs):
        raise ValueError(f"mesh of {devices} devices requested but only "
                         f"{len(devs)} are attached")
    if devices % model_axis:
        raise ValueError(f"{devices} devices do not divide model_axis="
                         f"{model_axis}")
    grid = np.array(devs[:devices]).reshape(devices // model_axis, model_axis)
    return Mesh(grid, ("data", "model"))


def state_shardings(state_like: Pytree, cfg: ModelConfig, mesh) -> Pytree:
    """NamedShardings for a TrainState(-like) pytree on `mesh`."""
    return to_named(state_spec_tree(state_like, cfg, mesh), mesh)


def _source_devices(flat: list) -> set:
    out: set = set()
    for x in flat:
        if isinstance(x, jax.Array):
            out |= set(x.devices())
    return out


def reshard_state(state: Pytree, cfg: ModelConfig, new_mesh) -> Pytree:
    """Re-place every leaf of `state` onto `new_mesh` under the arch rules."""
    if buckets.is_resident(state):
        if new_mesh is not None and new_mesh.size > 1:
            raise ValueError(
                "cannot reshard bucket-resident state onto a sharded mesh "
                f"(size {new_mesh.size}): flattened buckets would all-gather "
                "model-sharded leaves. View it out with buckets.to_portable "
                "first (and residentize after), or keep the target unsharded "
                "— per-shard bucketing is the ROADMAP follow-on.")
        if new_mesh is None:
            return state
        # unsharded target: buffers move wholesale (one transfer per bucket)
        return jax.device_put(state, NamedSharding(new_mesh, P()))
    shardings = state_shardings(jax.eval_shape(lambda: state), cfg, new_mesh)
    flat_s, treedef = jax.tree.flatten(state)
    flat_sh = jax.tree.leaves(shardings, is_leaf=lambda x: hasattr(x, "spec"))
    addressable = set(jax.devices())
    if _source_devices(flat_s) <= addressable:
        # shared/addressable devices: one batched device-to-device transfer
        out = jax.device_put(flat_s, flat_sh)
    else:
        # cross-process source: one batched D2H, then one batched placement
        out = jax.device_put(jax.device_get(flat_s), flat_sh)
    return jax.tree.unflatten(treedef, out)
