"""Deterministic chaos harness: scripted device-loss / capacity schedules.

`run_resilient`'s `failure_injector(step)` models one failure mode — a step
that dies. Preemptible fleets have two more: devices that *vanish* (the step
dies AND the survivors are a smaller mesh) and capacity that *arrives* (the
mesh can grow back). `ChaosSchedule` scripts all three as `MeshEvent`s keyed
on the training step, so a chaos run is exactly reproducible:

    schedule = ChaosSchedule([
        MeshEvent(step=40, devices=4),                  # graceful shrink
        MeshEvent(step=80, devices=8),                  # capacity returns
        MeshEvent(step=120, devices=2, kind="crash"),   # hard preemption
    ])
    with Engine(ElasticExecutor(inner, model_cfg=cfg), data, cbs) as eng:
        eng.fit(state, steps, events=schedule)

Two consumption surfaces:

  * `poll(step)` — the `MeshEvent` source the `ElasticExecutor` drains
    before each inner step: "resize" events reshard in-band (no rollback);
    "crash" events are recorded as pending and raised as `DeviceLoss`, so
    the resilient loop restores the last checkpoint and the executor's
    `on_restore` re-places it onto the survivor mesh.
  * `__call__(step)` — failure-injector compatibility: a schedule passed to
    a *non-elastic* run (`Engine.fit(failure_injector=schedule)`) raises its
    crash events as plain `InjectedFailure`s and ignores resizes, which
    generalizes today's hand-rolled injector closures.

Each event fires exactly once (wall-time semantics: a preemption happens
once, not once per replayed logical step after a rollback).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

from repro.runtime.fault_tolerance import InjectedFailure

KINDS = ("resize", "crash")


@dataclasses.dataclass(frozen=True)
class MeshEvent:
    """One scripted capacity change, firing when the fit reaches `step`.

    devices: target device count after the event (shrink when below the
        current mesh, grow when above — the schedule does not care which).
    kind: "resize" = graceful (reshard live state in-band, no rollback);
          "crash" = hard device loss (the step dies; recovery restores the
          last checkpoint onto the shrunken mesh).
    """
    step: int
    devices: int
    kind: str = "resize"

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"MeshEvent.kind must be one of {KINDS}, "
                             f"got {self.kind!r}")
        if self.devices < 1:
            raise ValueError(f"MeshEvent.devices must be >= 1, "
                             f"got {self.devices}")


class DeviceLoss(InjectedFailure):
    """A crash-kind MeshEvent fired: the step dies and the mesh shrinks."""

    def __init__(self, event: MeshEvent):
        super().__init__(f"device loss at step {event.step}: "
                         f"mesh shrinks to {event.devices} device(s)")
        self.event = event


class ChaosSchedule:
    """Scripted, fire-once MeshEvent source (see module doc)."""

    def __init__(self, events: Iterable[MeshEvent]):
        self._events = sorted(events, key=lambda e: e.step)
        self._cursor = 0

    @property
    def pending(self) -> tuple[MeshEvent, ...]:
        """Events not yet fired, in firing order."""
        return tuple(self._events[self._cursor:])

    def poll(self, step: int) -> Optional[MeshEvent]:
        """Next unfired event with `event.step <= step`, else None."""
        if self._cursor < len(self._events) \
                and self._events[self._cursor].step <= step:
            ev = self._events[self._cursor]
            self._cursor += 1
            return ev
        return None

    def __call__(self, step: int) -> None:
        """Failure-injector surface: crash events raise, resizes are skipped
        (a non-elastic loop has no way to act on them)."""
        while True:
            if self._cursor >= len(self._events) \
                    or self._events[self._cursor].step > step:
                return
            ev = self._events[self._cursor]
            self._cursor += 1
            if ev.kind == "crash":
                raise DeviceLoss(ev)


def parse_schedule(spec: str) -> ChaosSchedule:
    """Parse a launcher-friendly schedule string.

    Comma-separated events, each `STEP:DEVICES[:crash]`:

        "40:4,80:8,120:2:crash"

    shrinks to 4 devices at step 40, grows to 8 at step 80, and hard-kills
    down to 2 at step 120.
    """
    events = []
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        parts = item.split(":")
        if len(parts) not in (2, 3):
            raise ValueError(f"chaos event {item!r}: expected "
                             "STEP:DEVICES[:crash]")
        kind = "resize"
        if len(parts) == 3:
            kind = parts[2].strip()
        events.append(MeshEvent(step=int(parts[0]), devices=int(parts[1]),
                                kind=kind))
    if not events:
        raise ValueError(f"empty chaos schedule: {spec!r}")
    return ChaosSchedule(events)
