from repro.runtime.async_executor import AsyncSamExecutor, ExecutorConfig  # noqa: F401
from repro.runtime.elastic import reshard_state, state_shardings  # noqa: F401
from repro.runtime.fault_tolerance import (  # noqa: F401
    InjectedFailure,
    ResilienceConfig,
    RunReport,
    run_resilient,
)
