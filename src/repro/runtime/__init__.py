from repro.runtime.async_executor import AsyncSamExecutor, ExecutorConfig  # noqa: F401
from repro.runtime.chaos import (  # noqa: F401
    ChaosSchedule,
    DeviceLoss,
    MeshEvent,
    parse_schedule,
)
from repro.runtime.elastic import (  # noqa: F401
    make_sized_mesh,
    reshard_state,
    state_shardings,
)
from repro.runtime.fault_tolerance import (  # noqa: F401
    InjectedFailure,
    PoisonBatch,
    ResilienceConfig,
    RestartBudget,
    RunReport,
    run_resilient,
)
from repro.runtime.guard import (  # noqa: F401
    GuardConfig,
    GuardedExecutor,
    NumericChaos,
    NumericChaosPipeline,
    NumericRule,
    SpikeDetector,
    parse_numchaos,
)
from repro.runtime.health import (  # noqa: F401
    LADDER_LEVELS,
    LaneHealth,
    LaneLadder,
    ServerWatchdog,
)
