"""Lane health tracking + the ascent-lane degradation ladder + watchdog.

Three cooperating pieces that turn the executor's per-exchange outcomes into
an explicit, observable failure-response policy instead of an implicit one:

`LaneHealth`
    Rolling-window accounting over `ascent_exchange` outcomes: error rate,
    RTT, and silence. A lost exchange (the lane's grad=None sentinel, or a
    lockstep harvest timeout) records a failure; a harvested gradient records
    a success with its round-trip time. `stalled()` catches the failure mode
    error counting cannot: a blackholed connection that produces neither
    results nor errors while an exchange is outstanding.

`LaneLadder`
    The degradation policy itself, pure step-count logic with no I/O so it is
    exhaustively unit-testable: level 0 is the primary (remote) lane, each
    `demote()` moves one rung down (remote -> in-process thread lane ->
    ledger-only descent) and each `promote()` one rung back up. Hysteresis
    comes from two counters: a cooldown that must elapse before any
    promotion is attempted, and a probation window after every promotion —
    a demotion landing inside probation doubles the next cooldown, so a
    flapping upstream converges to the working rung instead of oscillating.

`ServerWatchdog`
    Scrapes the pool's revision-4 STATS frame through an observer HELLO
    (`service.client.fetch_pool_stats`) and classifies the server into
    ok / dead / wedged: dead means the scrape cannot reach it at all; wedged
    means it answers but its `exchanges` counter has stopped advancing for
    `wedge_scrapes` consecutive scrapes while work is queued — alive to TCP,
    useless to training. Both verdicts trigger the injected `restart_fn`
    under a shared `RestartBudget`, so a crash-looping server exhausts the
    budget instead of restarting forever.

All three are deterministic under injected clocks/scrape functions; the
chaos soak (`tests/test_netchaos.py`) exercises the wired-up whole through
`service.netchaos.ChaosProxy`.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Optional

from repro.obs import current_tracker
from repro.runtime.fault_tolerance import RestartBudget

#: ladder rung names, by level index (the executor maps these to lanes)
LADDER_LEVELS = ("remote", "local", "ledger")


class LaneHealth:
    """Rolling-window error-rate + RTT + silence tracking for one lane."""

    def __init__(self, *, window: int = 16, error_threshold: float = 0.5,
                 min_samples: int = 4, stall_timeout_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.window = window
        self.error_threshold = error_threshold
        self.min_samples = min_samples
        self.stall_timeout_s = stall_timeout_s
        self.clock = clock
        self._events: collections.deque = collections.deque(maxlen=window)
        #: submit timestamps of exchanges not yet answered (FIFO — the lanes
        #: are ordered depth-1 queues, so results come back in submit order)
        self._outstanding: collections.deque = collections.deque()
        self.successes = 0
        self.failures = 0

    def note_submit(self) -> None:
        self._outstanding.append(self.clock())

    def record(self, ok: bool, rtt_s: Optional[float] = None) -> None:
        """One exchange concluded: harvested gradient (ok) or lost (not ok)."""
        if self._outstanding:
            self._outstanding.popleft()
        self._events.append((bool(ok), rtt_s))
        if ok:
            self.successes += 1
        else:
            self.failures += 1

    def error_rate(self) -> float:
        if not self._events:
            return 0.0
        bad = sum(1 for ok, _ in self._events if not ok)
        return bad / len(self._events)

    def mean_rtt_s(self) -> float:
        rtts = [r for ok, r in self._events if ok and r is not None]
        return sum(rtts) / len(rtts) if rtts else 0.0

    def unhealthy(self) -> bool:
        """Enough recent samples and too many of them failures."""
        return (len(self._events) >= self.min_samples
                and self.error_rate() >= self.error_threshold)

    def stalled(self) -> bool:
        """An exchange is outstanding and the lane has been silent past the
        stall timeout — the blackhole signature (no errors, no results)."""
        if not self._outstanding:
            return False
        return self.clock() - self._outstanding[0] > self.stall_timeout_s

    def reset(self) -> None:
        """Fresh start (lane swap / reconnect): history from the previous
        lane must not condemn or absolve the new one."""
        self._events.clear()
        self._outstanding.clear()


class LaneLadder:
    """Degradation-ladder state machine: pure counters, no I/O.

    Levels run 0 (primary) .. n_levels-1 (deepest fallback). `tick()` once
    per executor step; `demote()` on an unhealthy/stalled verdict;
    `can_promote()` asks whether the cooldown has elapsed, and `promote()`
    moves one rung up and opens the probation window. A demotion inside
    probation doubles the next cooldown (capped), which is the hysteresis
    that prevents flapping against a half-dead upstream.
    """

    def __init__(self, n_levels: int = 3, *, probation_steps: int = 8,
                 cooldown_steps: int = 16, max_cooldown_steps: int = 256):
        assert n_levels >= 2
        self.n_levels = n_levels
        self.probation_steps = probation_steps
        self.base_cooldown = cooldown_steps
        self.max_cooldown = max_cooldown_steps
        self.level = 0
        self.failovers = 0       # cumulative demotions
        self.recoveries = 0      # cumulative promotions
        self._cooldown_cur = cooldown_steps   # next cooldown to impose
        self._cooldown_left = 0  # steps until promotion may be attempted
        self._probation_left = 0 # >0: recently promoted, demotion is costly

    def tick(self) -> None:
        if self._cooldown_left > 0:
            self._cooldown_left -= 1
        if self._probation_left > 0:
            self._probation_left -= 1
            if self._probation_left == 0:
                # survived probation: the rung above is trustworthy again
                self._cooldown_cur = self.base_cooldown

    @property
    def in_probation(self) -> bool:
        return self._probation_left > 0

    def demote(self) -> bool:
        """One rung down; returns False when already at the bottom."""
        if self.level >= self.n_levels - 1:
            return False
        if self.in_probation:
            # the rung we just returned to failed again: back off harder
            self._cooldown_cur = min(self.max_cooldown,
                                     self._cooldown_cur * 2)
            self._probation_left = 0
        self.level += 1
        self.failovers += 1
        self._cooldown_left = self._cooldown_cur
        return True

    def can_promote(self) -> bool:
        return self.level > 0 and self._cooldown_left == 0

    def promote(self) -> bool:
        """One rung up (callers gate on `can_promote()` plus lane readiness);
        opens the probation window."""
        if not self.can_promote():
            return False
        self.level -= 1
        self.recoveries += 1
        self._probation_left = self.probation_steps
        return True


class ServerWatchdog:
    """STATS-scraping watchdog: tells a wedged ascent pool from a dead one.

    `check()` performs one scrape + classification and acts on the verdict;
    `start()` runs it on a daemon thread every `interval_s`. Restarts go
    through `restart_fn()` under the shared `RestartBudget` — past the
    budget the watchdog stops restarting (and says so once) but keeps
    classifying, so telemetry still shows what the server is doing.
    """

    def __init__(self, addr_fn: Callable[[], str],
                 restart_fn: Callable[[str], None],
                 budget: RestartBudget, *,
                 interval_s: float = 5.0, wedge_scrapes: int = 3,
                 scrape_timeout_s: float = 10.0, auth_token: str = "",
                 stats_fn: Optional[Callable[[str], dict]] = None):
        self._addr_fn = addr_fn
        self._restart_fn = restart_fn
        self.budget = budget
        self.interval_s = interval_s
        self.wedge_scrapes = wedge_scrapes
        if stats_fn is None:
            from repro.service.client import fetch_pool_stats
            stats_fn = lambda addr: fetch_pool_stats(  # noqa: E731
                addr, auth_token=auth_token, timeout=scrape_timeout_s)
        self._stats_fn = stats_fn
        self._last_exchanges: Optional[int] = None
        self._frozen_scrapes = 0
        self._budget_spent_notice = False
        self.restarts = 0
        self.last_state = "ok"
        self.states: list = []      # classification history, for tests/ops
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # --- classification --------------------------------------------------------
    def classify(self) -> str:
        """One scrape -> "ok" | "dead" | "wedged" (no side effects beyond
        the freeze counter)."""
        try:
            snap = self._stats_fn(self._addr_fn())
        except Exception:  # noqa: BLE001 — unreachable/refusing/garbled alike
            self._last_exchanges = None
            self._frozen_scrapes = 0
            return "dead"
        exchanges = int(snap.get("exchanges", 0))
        depth = int(snap.get("queue_depth", 0))
        if (self._last_exchanges is not None
                and exchanges == self._last_exchanges and depth > 0):
            self._frozen_scrapes += 1
        else:
            self._frozen_scrapes = 0
        self._last_exchanges = exchanges
        if self._frozen_scrapes >= self.wedge_scrapes:
            return "wedged"
        return "ok"

    def check(self) -> str:
        """Classify and act: dead/wedged spend one restart and call
        `restart_fn(verdict)`."""
        verdict = self.classify()
        self.last_state = verdict
        self.states.append(verdict)
        if verdict == "ok":
            return verdict
        current_tracker().event("server_" + verdict, lane="watchdog",
                               restarts=self.restarts)
        try:
            self.budget.spend()
        except RuntimeError:
            if not self._budget_spent_notice:
                self._budget_spent_notice = True
                import sys
                print(f"[watchdog] server {verdict} but restart budget "
                      "exhausted; leaving it to the degradation ladder",
                      file=sys.stderr, flush=True)
            return verdict
        self.restarts += 1
        self._frozen_scrapes = 0
        self._last_exchanges = None
        self._restart_fn(verdict)
        return verdict

    # --- thread ----------------------------------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.check()
            except Exception:  # noqa: BLE001 — the watchdog must outlive a
                pass           # failed restart attempt; next tick re-checks

    def close(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
