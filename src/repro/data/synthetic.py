"""Deterministic synthetic datasets.

Two families:
* `TokenTask` — an LM stream with learnable structure (a random order-2 Markov
  chain over the vocabulary): losses actually go down, so integration tests
  and the paper-validation benchmarks measure real optimization, not noise.
* `ClassificationTask` — the paper's CIFAR-style benchmarks at CPU scale:
  Gaussian class clusters pushed through a fixed random MLP (nonlinear,
  controllable difficulty), with train/valid splits. Used by the Table 4.1 /
  Fig. 3/4/5 harnesses.

Everything is derived from an integer seed — no files, bit-reproducible,
shard-aware (rank r of R draws a disjoint sample stream).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenTask:
    vocab_size: int
    seed: int = 0
    order_states: int = 64     # latent states of the generating chain

    def _tables(self) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(self.seed)
        # latent-state transition and emission tables (peaked => learnable)
        trans = rng.dirichlet(np.full(self.order_states, 0.1),
                              size=self.order_states)
        emit = rng.dirichlet(np.full(self.vocab_size, 0.05),
                             size=self.order_states)
        return trans, emit

    def sample(self, n_seqs: int, seq_len: int, stream: int = 0) -> np.ndarray:
        """(n_seqs, seq_len) int32 tokens; `stream` selects a disjoint draw.

        Vectorized inverse-CDF sampling; vocabularies beyond 4096 fall back to
        uniform tokens (full-size configs are only exercised abstractly)."""
        rng = np.random.default_rng((self.seed, stream, 7))
        if self.vocab_size > 4096:
            return rng.integers(0, self.vocab_size,
                                size=(n_seqs, seq_len)).astype(np.int32)
        trans, emit = self._tables()
        trans_cdf = np.cumsum(trans, axis=-1)
        emit_cdf = np.cumsum(emit, axis=-1)
        state = rng.integers(0, self.order_states, size=n_seqs)
        out = np.empty((n_seqs, seq_len), np.int32)
        u_tok = rng.random((seq_len, n_seqs, 1))
        u_st = rng.random((seq_len, n_seqs, 1))
        for t in range(seq_len):
            out[:, t] = (emit_cdf[state] < u_tok[t]).sum(-1)
            state = (trans_cdf[state] < u_st[t]).sum(-1)
        return np.clip(out, 0, self.vocab_size - 1)

    def batch(self, n_seqs: int, seq_len: int, stream: int = 0) -> dict:
        tokens = self.sample(n_seqs, seq_len, stream)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = -1
        return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}


@dataclasses.dataclass(frozen=True)
class ClassificationTask:
    """Nonlinear Gaussian-cluster classification (CIFAR stand-in at CPU scale).

    Generalization-sensitive by construction: training draws from a FINITE
    pool (train_pool samples, cycled over epochs) with `label_noise` flipped
    labels, while validation is clean and unlimited — the regime where
    sharpness-aware methods earn their gap (cf. paper Table 4.1)."""
    n_classes: int = 10
    dim: int = 64
    depth: int = 2              # random-MLP warps applied to the clusters
    margin: float = 1.2         # cluster separation (lower = harder)
    noise: float = 1.0
    seed: int = 0
    train_pool: int = 1024      # finite training set size
    label_noise: float = 0.15   # fraction of flipped training labels

    def _make(self, n: int, stream: int) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng((self.seed, stream))
        labels = rng.integers(0, self.n_classes, size=n)
        centers_rng = np.random.default_rng(self.seed)  # shared across streams
        centers = centers_rng.normal(size=(self.n_classes, self.dim)) * self.margin
        x = centers[labels] + rng.normal(size=(n, self.dim)) * self.noise
        for i in range(self.depth):
            w = centers_rng.normal(size=(self.dim, self.dim)) / np.sqrt(self.dim)
            x = np.tanh(x @ w) + x * 0.5
        return x.astype(np.float32), labels.astype(np.int32)

    def _train_pool(self) -> tuple[np.ndarray, np.ndarray]:
        x, y = self._make(self.train_pool, stream=1)
        if self.label_noise > 0:
            rng = np.random.default_rng((self.seed, 2))
            flip = rng.random(self.train_pool) < self.label_noise
            y = np.where(flip, rng.integers(0, self.n_classes,
                                            size=self.train_pool), y)
        return x, y.astype(np.int32)

    def train_batches(self, batch_size: int, n_batches: int,
                      start: int = 0) -> Iterator[dict]:
        x, y = self._train_pool()
        rng = np.random.default_rng((self.seed, 3, start))
        for i in range(n_batches):
            idx = rng.integers(0, self.train_pool, size=batch_size)
            yield {"x": jnp.asarray(x[idx]), "y": jnp.asarray(y[idx])}

    VALID_STREAM = 10**9  # train streams must stay below this

    def valid_set(self, n: int = 2048) -> dict:
        x, y = self._make(n, stream=self.VALID_STREAM)
        return {"x": jnp.asarray(x), "y": jnp.asarray(y)}
