"""Memory-mapped token dataset (the production data path).

File format: a flat little-endian int32 token file (MaxText/llm.c style) plus
a small JSON sidecar ({"vocab_size": V}). Sequences are drawn by deterministic
strided addressing from (seed, stream) so the pipeline's restart/sharding
semantics match the synthetic source exactly.
"""
from __future__ import annotations

import json
import pathlib
from typing import Optional

import jax.numpy as jnp
import numpy as np


class MmapTokenDataset:
    def __init__(self, path: str | pathlib.Path, seed: int = 0):
        path = pathlib.Path(path)
        meta = json.loads(path.with_suffix(".json").read_text())
        self.vocab_size = int(meta["vocab_size"])
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.seed = seed

    def __len__(self) -> int:
        return len(self.tokens)

    def batch(self, n: int, seq_len: int, stream: int) -> dict:
        """Deterministic (seed, stream)-addressed batch of n sequences."""
        usable = len(self.tokens) - seq_len - 1
        assert usable > 0, "token file shorter than one sequence"
        rng = np.random.default_rng((self.seed, stream))
        starts = rng.integers(0, usable, size=n)
        idx = starts[:, None] + np.arange(seq_len + 1)[None, :]
        window = self.tokens[idx]
        return {"tokens": jnp.asarray(window[:, :-1]),
                "labels": jnp.asarray(window[:, 1:])}

    @staticmethod
    def write(path: str | pathlib.Path, tokens: np.ndarray,
              vocab_size: int) -> None:
        """Write a dataset file (used by tests and the data-prep example)."""
        path = pathlib.Path(path)
        tokens.astype(np.int32).tofile(path)
        path.with_suffix(".json").write_text(json.dumps(
            {"vocab_size": int(vocab_size), "n_tokens": int(tokens.size)}))
