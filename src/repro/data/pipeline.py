"""Shard-aware training data pipeline with checkpointable state.

The pipeline yields framework batches ({"tokens","labels"[,"ascent"]...}) and
owns three production concerns:

* sharding — each data-parallel rank draws a disjoint stream (rank folded
  into the sample-stream index), so the global batch is a partition, not a
  replica; under single-controller pjit (this repo's launchers) rank=0 and
  world=1 yields the full global batch which pjit shards;
* the AsyncSAM ascent sub-batch — b' fresh samples per step (paper §3.3),
  emitted under the "ascent" key so methods never slice the descent batch;
* restartability — `state()` / `restore()` capture the step cursor, so a
  restored run continues on the exact sample stream (bitwise-identical
  batches; tested in tests/test_checkpoint.py).

Host-side double-buffering (`prefetch=2`) overlaps synthesis/disk reads with
device steps.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np

from repro.data.synthetic import TokenTask
from repro.models.config import ModelConfig


@dataclasses.dataclass
class PipelineConfig:
    global_batch: int
    seq_len: int
    ascent_fraction: float = 0.0    # b'/b; 0 disables the ascent sub-batch
    seed: int = 0
    rank: int = 0                   # data-parallel rank (multi-host)
    world: int = 1
    prefetch: int = 2


class TokenPipeline:
    """Synthetic-LM pipeline (swap `source` for MmapTokenDataset in prod)."""

    def __init__(self, cfg: ModelConfig, pcfg: PipelineConfig,
                 source: Optional[object] = None):
        assert pcfg.global_batch % pcfg.world == 0
        self.cfg = cfg
        self.pcfg = pcfg
        self.source = source or TokenTask(vocab_size=cfg.vocab_size,
                                          seed=pcfg.seed)
        self._step = 0
        self._local_batch = pcfg.global_batch // pcfg.world
        b_asc = max(1, round(pcfg.global_batch * pcfg.ascent_fraction))
        self._local_ascent = max(1, b_asc // pcfg.world) if pcfg.ascent_fraction else 0

    # --- checkpointable cursor ------------------------------------------------
    def state(self) -> dict:
        # rank/world are identity, not cursor: restoring rank 0's checkpoint
        # into rank 1's pipeline would silently resume on the WRONG disjoint
        # stream shard — restore() refuses instead
        return {"step": self._step, "seed": self.pcfg.seed,
                "rank": self.pcfg.rank, "world": self.pcfg.world}

    def restore(self, state: dict) -> None:
        assert state["seed"] == self.pcfg.seed, "pipeline seed changed across restart"
        if "rank" in state:   # pre-identity-era states restore unchanged
            assert (state["rank"], state["world"]) == \
                (self.pcfg.rank, self.pcfg.world), \
                (f"pipeline identity changed across restart: checkpoint is "
                 f"rank {state['rank']}/{state['world']}, this pipeline is "
                 f"rank {self.pcfg.rank}/{self.pcfg.world}")
        self._step = int(state["step"])

    def peek(self) -> dict:
        """Synthesize the next batch WITHOUT advancing the cursor.

        Used by the Engine's pre-fit hooks (hetero calibration probes) so a
        timing probe never perturbs the restart-deterministic sample stream.
        """
        return self._make(self._step)

    # --- batch synthesis -------------------------------------------------------
    def _make(self, step: int) -> dict:
        # stream ids: (step, rank, lane) — descent lane 0, ascent lane 1
        stream = step * 2 * self.pcfg.world + 2 * self.pcfg.rank
        batch = self._one(self._local_batch, self.seq_len, stream)
        if self._local_ascent:
            batch["ascent"] = self._one(self._local_ascent, self.seq_len,
                                        stream + 1)
        return batch

    @property
    def seq_len(self) -> int:
        return self.pcfg.seq_len

    def _one(self, n: int, s: int, stream: int) -> dict:
        batch = self.source.batch(n, s, stream)
        extras = _family_extras(self.cfg, n, s, stream)
        batch.update(extras)
        return batch

    def __iter__(self) -> Iterator[dict]:
        if self.pcfg.prefetch <= 0:
            while True:
                batch = self._make(self._step)
                self._step += 1
                yield batch
        else:
            yield from self._prefetching()

    def _prefetching(self) -> Iterator[dict]:
        q: queue.Queue = queue.Queue(maxsize=self.pcfg.prefetch)
        stop = threading.Event()

        def worker(start_step: int):
            s = start_step
            while not stop.is_set():
                batch = self._make(s)        # synthesize once ...
                while not stop.is_set():
                    try:
                        q.put((s, batch), timeout=0.2)
                        s += 1
                        break                # ... retry only the hand-off
                    except queue.Full:
                        continue

        t = threading.Thread(target=worker, args=(self._step,), daemon=True)
        t.start()
        try:
            while True:
                s, batch = q.get()
                self._step = s + 1
                yield batch
        finally:
            stop.set()
            # wake a blocked put(), then wait the worker out: a daemon thread
            # left inside jnp.asarray at interpreter exit aborts the process
            # (std::terminate from native thread teardown)
            try:
                while True:
                    q.get_nowait()
            except queue.Empty:
                pass
            t.join(timeout=5.0)


def _family_extras(cfg: ModelConfig, n: int, s: int, stream: int) -> dict:
    """Modality-stub inputs (precomputed embeddings per the assignment)."""
    import jax.numpy as jnp

    rng = np.random.default_rng((stream, 99))
    extras = {}
    if cfg.vision is not None:
        extras["patch_embeds"] = jnp.asarray(rng.normal(size=(
            n, cfg.vision.n_image_tokens, cfg.vision.clip_dim)).astype(np.float32),
            dtype=jnp.dtype(cfg.compute_dtype))
    if cfg.family == "audio":
        from repro.models.registry import whisper_enc_len
        extras["enc_frames"] = jnp.asarray(rng.normal(size=(
            n, whisper_enc_len(cfg, s), cfg.d_model)).astype(np.float32),
            dtype=jnp.dtype(cfg.compute_dtype))
    return extras
