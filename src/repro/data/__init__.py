from repro.data.mmap_dataset import MmapTokenDataset  # noqa: F401
from repro.data.pipeline import PipelineConfig, TokenPipeline  # noqa: F401
from repro.data.synthetic import ClassificationTask, TokenTask  # noqa: F401
