"""End-to-end driver: train a ~100M-parameter qwen3-family model with AsyncSAM
for a few hundred steps, with checkpointing and restart (deliverable b) —
all through `Engine.fit` with a CheckpointCallback.

Defaults are sized for this CPU container (~100M params, 300 steps); on a pod
the same driver runs the full config via --full.

    PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse

import jax

from repro import optim
from repro.checkpoint import CheckpointManager
from repro.core import MethodConfig
from repro.data import PipelineConfig, TokenPipeline
from repro.engine import (CheckpointCallback, Engine, FusedExecutor,
                          LoggingCallback)
from repro.models import analytic_param_count, build_model
from repro.models.config import ModelConfig
from repro.runtime import ResilienceConfig

CFG_100M = ModelConfig(
    name="qwen3-100m", family="dense",
    n_layers=8, d_model=640, n_heads=10, n_kv_heads=2, d_ff=2048,
    vocab_size=32000, head_dim=64, act="silu", qk_norm=True,
    remat="none", compute_dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    ap.add_argument("--method", default="async_sam")
    args = ap.parse_args()

    cfg = CFG_100M
    bundle = build_model(cfg)
    print(f"params: {analytic_param_count(cfg) / 1e6:.1f}M")

    mcfg = MethodConfig(name=args.method, rho=0.05, ascent_fraction=0.25)
    opt = optim.adamw(optim.cosine_schedule(3e-4, args.steps,
                                            warmup_steps=20), clip_norm=1.0)
    executor = FusedExecutor(bundle.loss_fn, mcfg, opt)
    state = executor.init_state(bundle.init(jax.random.PRNGKey(0)),
                                jax.random.PRNGKey(1))

    pipe = TokenPipeline(cfg, PipelineConfig(global_batch=args.batch,
                                             seq_len=args.seq,
                                             ascent_fraction=0.25))
    callbacks = [
        LoggingCallback(every=20, total_steps=args.steps),
        CheckpointCallback(CheckpointManager(args.ckpt_dir, keep=2),
                           ResilienceConfig(save_every=100)),
    ]
    with Engine(executor, pipe, callbacks) as eng:
        report = eng.fit(state, args.steps)
    losses = [h["loss"] for h in report.metrics_history if "loss" in h]
    print(f"done: steps={report.steps_done} restarts={report.restarts} "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({report.wall_time_s:.0f}s)")


if __name__ == "__main__":
    main()
