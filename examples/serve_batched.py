"""Batched serving demo: prefill a request batch, decode continuations with
the same step functions the production dry-run lowers at 32k/500k shapes.

    PYTHONPATH=src python examples/serve_batched.py --arch zamba2-1.2b
"""
import argparse
import subprocess
import sys

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    args, rest = ap.parse_known_args()
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve", "--arch", args.arch,
         "--reduced", "--requests", "8", "--prompt-len", "32",
         "--max-new", "16", *rest]))
