"""Quickstart: train a small LM with AsyncSAM in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro import optim
from repro.configs import get_config
from repro.core import MethodConfig, init_train_state, make_method
from repro.data import PipelineConfig, TokenPipeline
from repro.models import build_model


def main():
    # 1. pick an architecture (any of the 10 assigned ids) at smoke scale
    cfg = get_config("olmo-1b", reduced=True)
    bundle = build_model(cfg)

    # 2. choose the training method — AsyncSAM is the paper's contribution:
    #    rho is the perturbation radius, ascent_fraction is b'/b (paper §3.3)
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.25)
    method = make_method(mcfg)
    optimizer = optim.adamw(optim.cosine_schedule(3e-3, 200))

    # 3. init state and jit the step
    params = bundle.init(jax.random.PRNGKey(0))
    state = init_train_state(params, optimizer, method, jax.random.PRNGKey(1))
    step = jax.jit(method.make_step(bundle.loss_fn, optimizer))

    # 4. stream data (the pipeline emits the b'-sized ascent sub-batch too)
    pipe = TokenPipeline(cfg, PipelineConfig(global_batch=8, seq_len=64,
                                             ascent_fraction=0.25))
    it = iter(pipe)
    for i in range(200):
        state, metrics = step(state, next(it))
        if i % 25 == 0:
            print(f"step {i:4d}  loss={float(metrics['loss']):.4f}  "
                  f"ascent_cos={float(metrics['ascent_cosine']):.3f}")
    print("final loss:", float(metrics["loss"]))


if __name__ == "__main__":
    main()
