"""Quickstart: train a small LM with AsyncSAM through the Engine in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro import optim
from repro.configs import get_config
from repro.core import MethodConfig
from repro.data import PipelineConfig, TokenPipeline
from repro.engine import Engine, FusedExecutor, LoggingCallback
from repro.models import build_model


def main():
    # 1. pick an architecture (any of the 10 assigned ids) at smoke scale
    cfg = get_config("olmo-1b", reduced=True)
    bundle = build_model(cfg)

    # 2. choose the training method — AsyncSAM is the paper's contribution:
    #    rho is the perturbation radius, ascent_fraction is b'/b (paper §3.3)
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.25)
    optimizer = optim.adamw(optim.cosine_schedule(3e-3, 200))

    # 3. an executor owns init/jit/step; the Engine owns the loop + callbacks.
    #    Swap FusedExecutor for HeteroExecutor to run the two-lane schedule —
    #    nothing else changes.
    executor = FusedExecutor(bundle.loss_fn, mcfg, optimizer)
    state = executor.init_state(bundle.init(jax.random.PRNGKey(0)),
                                jax.random.PRNGKey(1))

    # 4. stream data (the pipeline emits the b'-sized ascent sub-batch too)
    pipe = TokenPipeline(cfg, PipelineConfig(global_batch=8, seq_len=64,
                                             ascent_fraction=0.25))
    with Engine(executor, pipe, [LoggingCallback(every=25)]) as eng:
        report = eng.fit(state, steps=200)
    print("final loss:", report.metrics_history[-1]["loss"])


if __name__ == "__main__":
    main()
