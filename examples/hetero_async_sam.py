"""The paper's headline demo: hide SAM's perturbation cost on a heterogeneous
system (fast descent lane + slow ascent lane), reproducing Table 4.2's
mechanics on CPU.

    PYTHONPATH=src python examples/hetero_async_sam.py
"""
import time

import jax
import numpy as np

from repro import optim
from repro.core import MethodConfig, init_train_state, make_method
from repro.data.synthetic import ClassificationTask
from repro.runtime import AsyncSamExecutor, ExecutorConfig

import sys, pathlib
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.common import accuracy, mlp_init, mlp_loss  # noqa: E402

TASK = ClassificationTask(seed=7, margin=1.05)
STEPS, BATCH = 60, 1024
WIDTHS = (64, 1024, 1024, 1024, 10)   # big enough that compute >> queue overhead


def run_sync(method_name, frac=1.0):
    mcfg = MethodConfig(name=method_name, rho=0.05, ascent_fraction=frac,
                        same_batch_ascent=True)
    method = make_method(mcfg)
    opt = optim.sgd(0.05, momentum=0.9)
    state = init_train_state(mlp_init(jax.random.PRNGKey(0), WIDTHS), opt, method,
                             jax.random.PRNGKey(1))
    step = jax.jit(method.make_step(mlp_loss, opt))
    batches = list(TASK.train_batches(BATCH, STEPS))
    state, _ = step(state, batches[0])
    t0 = time.perf_counter()
    for b in batches[1:]:
        state, m = step(state, b)
    jax.block_until_ready(state.params)
    return time.perf_counter() - t0, accuracy(state.params, TASK.valid_set())


def run_hetero(delay_s, frac):
    """Slow ascent resource emulated with injected per-call delay."""
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=frac)
    method = make_method(mcfg)
    opt = optim.sgd(0.05, momentum=0.9)
    state = init_train_state(mlp_init(jax.random.PRNGKey(0), WIDTHS), opt, method,
                             jax.random.PRNGKey(1))
    batches = list(TASK.train_batches(BATCH, STEPS))
    bp = max(1, int(BATCH * frac))
    with AsyncSamExecutor(mlp_loss, mcfg, opt,
                          ExecutorConfig(ascent_delay_s=delay_s)) as ex:
        state, _ = ex.step(state, {**batches[0],
                                   "ascent": {k: v[:bp] for k, v in batches[0].items()}})
        t0 = time.perf_counter()
        for b in batches[1:]:
            state, m = ex.step(state, {**b, "ascent": {k: v[:bp] for k, v in b.items()}})
        dt = time.perf_counter() - t0
        ledger = ex.ledger.summary()
    return dt, accuracy(state.params, TASK.valid_set()), ledger


def main():
    t_sgd, acc_sgd = run_sync("sgd")
    t_sam, acc_sam = run_sync("sam")
    print(f"SGD  : {t_sgd:6.2f}s  acc={acc_sgd:.4f}")
    print(f"SAM  : {t_sam:6.2f}s  acc={acc_sam:.4f}   <- 2x gradient cost")
    for ratio in (2, 4):
        dt, acc, ledger = run_hetero(delay_s=0.0, frac=1.0 / ratio)
        print(f"AsyncSAM b/b'={ratio}x: {dt:6.2f}s  acc={acc:.4f}  "
              f"tau={ledger['tau']} refreshes={ledger['refreshes']}")
    print("-> AsyncSAM stays well under SAM's 2x cost at SAM-family accuracy.")
    print("   NOTE: in this container both lanes share the same CPU cores, so")
    print("   the ascent shows up as ~(1 + b'/b)x instead of being fully")
    print("   hidden; on a real CPU+GPU host the helper runs on otherwise-idle")
    print("   silicon and wall-clock matches SGD (paper Table 4.2 semantics,")
    print("   reproduced timing-faithfully in benchmarks/table_4_2_hetero.py).")


if __name__ == "__main__":
    main()
