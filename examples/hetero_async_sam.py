"""The paper's headline demo: hide SAM's perturbation cost on a heterogeneous
system (fast descent lane + slow ascent lane), reproducing Table 4.2's
mechanics on CPU. Both the synchronous baselines and the two-lane runs drive
the same `Engine.fit`; only the executor differs.

    PYTHONPATH=src python examples/hetero_async_sam.py
"""
import jax

from repro import optim
from repro.core import MethodConfig, slice_ascent_batch
from repro.data.synthetic import ClassificationTask
from repro.engine import Engine, FusedExecutor, HeteroExecutor, ThroughputMeter
from repro.runtime import ExecutorConfig

import sys, pathlib
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.common import accuracy, mlp_init, mlp_loss  # noqa: E402

TASK = ClassificationTask(seed=7, margin=1.05)
STEPS, BATCH = 60, 1024
WIDTHS = (64, 1024, 1024, 1024, 10)   # big enough that compute >> queue overhead


def _fit(executor, batches):
    state = executor.init_state(mlp_init(jax.random.PRNGKey(0), WIDTHS),
                                jax.random.PRNGKey(1))
    meter = ThroughputMeter()
    with Engine(executor, batches, [meter]) as eng:
        report = eng.fit(state, STEPS, warmup=1)   # compile outside the timer
    return sum(meter.step_times), accuracy(report.final_state.params,
                                           TASK.valid_set())


def run_sync(method_name, frac=1.0):
    mcfg = MethodConfig(name=method_name, rho=0.05, ascent_fraction=frac,
                        same_batch_ascent=True)
    opt = optim.sgd(0.05, momentum=0.9)
    batches = list(TASK.train_batches(BATCH, STEPS))
    ex = FusedExecutor(mlp_loss, mcfg, opt, donate=False)
    return _fit(ex, batches)


def run_hetero(delay_s, frac):
    """Slow ascent resource emulated with injected per-call delay."""
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=frac)
    opt = optim.sgd(0.05, momentum=0.9)
    batches = [{**b, "ascent": slice_ascent_batch(b, frac)}
               for b in TASK.train_batches(BATCH, STEPS)]
    ex = HeteroExecutor(mlp_loss, mcfg, opt,
                        exec_cfg=ExecutorConfig(ascent_delay_s=delay_s))
    dt, acc = _fit(ex, batches)
    return dt, acc, ex.ledger.summary()


def main():
    t_sgd, acc_sgd = run_sync("sgd")
    t_sam, acc_sam = run_sync("sam")
    print(f"SGD  : {t_sgd:6.2f}s  acc={acc_sgd:.4f}")
    print(f"SAM  : {t_sam:6.2f}s  acc={acc_sam:.4f}   <- 2x gradient cost")
    for ratio in (2, 4):
        dt, acc, ledger = run_hetero(delay_s=0.0, frac=1.0 / ratio)
        print(f"AsyncSAM b/b'={ratio}x: {dt:6.2f}s  acc={acc:.4f}  "
              f"tau={ledger['tau']} refreshes={ledger['refreshes']}")
    print("-> AsyncSAM stays well under SAM's 2x cost at SAM-family accuracy.")
    print("   NOTE: in this container both lanes share the same CPU cores, so")
    print("   the ascent shows up as ~(1 + b'/b)x instead of being fully")
    print("   hidden; on a real CPU+GPU host the helper runs on otherwise-idle")
    print("   silicon and wall-clock matches SGD (paper Table 4.2 semantics,")
    print("   reproduced timing-faithfully in benchmarks/table_4_2_hetero.py).")


if __name__ == "__main__":
    main()
