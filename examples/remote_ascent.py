"""Multi-host AsyncSAM on one machine: the loopback ascent service.

Spawns a real `repro.service.ascent_server` subprocess, then trains with
`--executor remote` semantics: the descent lane runs here, every ascent
gradient crosses a TCP socket as a compressed GRAD frame. Two demonstrations:

1. parity — under `ExecutorConfig(lockstep=True)` the remote run reproduces
   the in-process hetero run step for step (same tau schedule, same losses):
   moving the lane across the process boundary changes nothing about the
   math, only where it executes;
2. free-running — the async schedule with int8-compressed exchanges,
   reporting tau histogram, measured wire bytes and round-trip time.

The same two commands split across two hosts give the paper's CPU-helper +
accelerator deployment (see README "Multi-host ascent service").

    PYTHONPATH=src python examples/remote_ascent.py
"""
import jax
import numpy as np

from repro import optim
from repro.core import MethodConfig, slice_ascent_batch
from repro.data.synthetic import ClassificationTask
from repro.engine import Engine, HeteroExecutor, RemoteExecutor, StalenessTelemetry
from repro.runtime import ExecutorConfig
from repro.service.testing import MLP_LOSS_SPEC, mlp_init, mlp_loss

TASK = ClassificationTask(seed=7, margin=1.05, dim=64)
STEPS, BATCH, FRAC = 40, 512, 0.5
WIDTHS = (64, 256, 256, 10)


def accuracy(params, batch):
    logits = mlp_loss(params, batch, None)[1]["logits"]
    return float(np.mean(np.argmax(logits, -1) == batch["y"]))


def fit(executor, steps=STEPS):
    telemetry = StalenessTelemetry(print_summary=False)
    with executor as ex:
        state = ex.init_state(mlp_init(jax.random.PRNGKey(0), WIDTHS),
                              jax.random.PRNGKey(1))
        batches = [{**b, "ascent": slice_ascent_batch(b, FRAC)}
                   for b in TASK.train_batches(BATCH, steps)]
        report = Engine(ex, batches, [telemetry]).fit(state, steps)
    return report, telemetry.summary()


def main():
    opt = lambda: optim.sgd(0.05, momentum=0.9)  # noqa: E731

    # --- 1. parity: lockstep hetero vs lockstep remote --------------------------
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=FRAC)
    rep_h, _ = fit(HeteroExecutor(
        mlp_loss, mcfg, opt(), exec_cfg=ExecutorConfig(lockstep=True)))
    rep_r, _ = fit(RemoteExecutor(
        mlp_loss, mcfg, opt(),
        exec_cfg=ExecutorConfig(lockstep=True, serve_ascent=True,
                                loss_spec=MLP_LOSS_SPEC)))
    lh = np.array([h["loss"] for h in rep_h.metrics_history])
    lr = np.array([h["loss"] for h in rep_r.metrics_history])
    print(f"parity : hetero acc="
          f"{accuracy(rep_h.final_state.params, TASK.valid_set()):.4f}  "
          f"remote acc="
          f"{accuracy(rep_r.final_state.params, TASK.valid_set()):.4f}  "
          f"max|loss diff|={float(np.max(np.abs(lh - lr))):.2e}")

    # --- 2. free-running async schedule with a compressed wire ------------------
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=FRAC,
                        compressor="int8")
    ex = RemoteExecutor(mlp_loss, mcfg, opt(), calibrate=True,
                        calibration_probes=1,   # warms spawn/connect/compile
                        exec_cfg=ExecutorConfig(
                            serve_ascent=True, loss_spec=MLP_LOSS_SPEC))
    rep, tel = fit(ex, steps=120)
    wire = [h["wire_bytes"] for h in rep.metrics_history if h.get("wire_bytes")]
    rtt = [h["rtt_s"] for h in rep.metrics_history if h.get("rtt_s")]
    print(f"async  : acc={accuracy(rep.final_state.params, TASK.valid_set()):.4f}"
          f"  tau_hist={tel['tau_hist']}  exchanges={ex.client.exchanges}")
    print(f"         wire/exchange={int(np.mean(wire)) if wire else 0}B (int8)"
          f"  rtt={np.mean(rtt) * 1e3 if rtt else 0:.1f}ms"
          f"  calibrated b'/b={rep.pre_fit['calibrated_ascent_fraction']:.2f}")
    print("-> same Engine.fit, same step math; only the lane moved across")
    print("   the process boundary. Point --ascent-addr at another host to")
    print("   split it across machines.")


if __name__ == "__main__":
    main()
