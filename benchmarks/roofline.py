"""Roofline analysis (deliverable g).

Terms per (arch x shape x mesh), TPU v5e-class constants:

    compute    = FLOPs / (chips * 197e12)
    memory     = bytes_accessed / (chips * 819e9)
    collective = wire_bytes / (chips-local links * 50e9)

Sources and the scan caveat: XLA's cost_analysis counts a lax.scan body ONCE
(observed 16x undercount on olmo). The production dry-run therefore keeps the
scan program for memory_analysis (what fits on a chip) while this harness
re-lowers each cell with layers UNROLLED (cfg.scan_layers=False,
n_microbatches=1) to obtain exact per-step FLOPs / bytes / collective bytes.
`analytic` columns (MODEL_FLOPS = 6*N*D, 6*N_active*D for MoE) cross-check the
exact numbers and feed the "useful compute" ratio.

Outputs: artifacts/analysis/<cell>.json + artifacts/roofline.csv +
a markdown table for EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import sys

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

REPO = pathlib.Path(__file__).resolve().parents[1]
DRYRUN_DIR = REPO / "artifacts" / "dryrun"
ANALYSIS_DIR = REPO / "artifacts" / "analysis"


# ---------------------------------------------------------------------------
# Analytic FLOPs model (cross-check + MODEL_FLOPS)
# ---------------------------------------------------------------------------

def model_flops(cfg, shape, mcfg=None, remat_extra: float = 1.0) -> dict:
    """Analytic per-step GLOBAL flops for a cell. Returns components."""
    from repro.models import analytic_param_count

    S, B = shape.seq_len, shape.global_batch
    n_total = analytic_param_count(cfg)
    n_active = analytic_param_count(cfg, active_only=True)
    # matmul params: exclude the embed gather; tied embeds still pay the
    # unembed matmul, so the net adjustment is -V*d only when untied
    embed_adj = 0 if cfg.tie_embeddings else cfg.vocab_size * cfg.d_model
    p_mat = n_active - embed_adj

    attn = _attention_flops(cfg, S) * B
    if shape.kind == "train":
        f = (mcfg.ascent_fraction if mcfg else 0.25)
        f = f / max(1, getattr(mcfg, "ascent_interval", 1) if mcfg else 1)
        tokens = B * S
        total = (2 * p_mat * tokens + attn) * (3.0 + remat_extra) * (1.0 + f)
        reference = 6 * n_active * tokens          # MODEL_FLOPS = 6*N*D
    elif shape.kind == "prefill":
        tokens = B * S
        total = 2 * p_mat * tokens + attn
        reference = 2 * n_active * tokens          # inference: 2*N*D
    else:  # decode: one token, attention over the full cache
        tokens = B
        total = 2 * p_mat * B + _decode_attn_flops(cfg, S) * B
        reference = 2 * n_active * tokens
    return {"total": total, "model_flops_6nd": reference,
            "n_params": n_total, "n_active": n_active}


def _attention_flops(cfg, S: int) -> float:
    """Score+context flops per sequence (full blocks, as the jnp path runs)."""
    hd = cfg.resolved_head_dim
    if cfg.family == "ssm":       # rwkv: K*V state update+readout per token
        r = cfg.rwkv
        heads = cfg.d_model // r.head_dim
        return 4.0 * S * heads * r.head_dim * r.head_dim * cfg.n_layers
    total = 0.0
    if cfg.family == "hybrid":
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        heads = d_inner // s.head_dim
        T = s.chunk_size
        per_tok = 2 * T * (s.d_state + s.head_dim) + 4 * s.head_dim * s.d_state
        total += S * per_tok * heads * cfg.n_layers
        n_attn = (cfg.n_layers + cfg.hybrid.period - 1) // cfg.hybrid.period
        total += 4.0 * S * S * cfg.n_heads * hd * n_attn
        return total
    ctx = min(S, cfg.sliding_window) if cfg.sliding_window else S
    qk_dim = hd
    v_dim = hd
    if cfg.mla:
        qk_dim = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
        v_dim = cfg.mla.v_head_dim
    n_attn_layers = cfg.n_layers
    total += 2.0 * S * ctx * cfg.n_heads * (qk_dim + v_dim) * n_attn_layers
    if cfg.family == "audio":
        e = cfg.encdec
        total += 2.0 * S * S * cfg.n_heads * 2 * hd * e.n_encoder_layers  # enc
        total += 2.0 * S * S * cfg.n_heads * 2 * hd * cfg.n_layers        # cross
    return total


def _decode_attn_flops(cfg, S: int) -> float:
    hd = cfg.resolved_head_dim
    if cfg.family == "ssm":
        r = cfg.rwkv
        heads = cfg.d_model // r.head_dim
        return 4.0 * heads * r.head_dim * r.head_dim * cfg.n_layers
    if cfg.family == "hybrid":
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        heads = d_inner // s.head_dim
        n_attn = (cfg.n_layers + cfg.hybrid.period - 1) // cfg.hybrid.period
        return (4.0 * heads * s.head_dim * s.d_state * cfg.n_layers
                + 4.0 * S * cfg.n_heads * hd * n_attn)
    ctx = min(S, cfg.sliding_window) if cfg.sliding_window else S
    qk_dim = hd
    v_dim = hd
    if cfg.mla:
        qk_dim = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim  # absorbed
        v_dim = cfg.mla.kv_lora_rank
    return 2.0 * ctx * cfg.n_heads * (qk_dim + v_dim) * cfg.n_layers


def analytic_decode_bytes(cfg, shape) -> float:
    """HBM traffic model for one decode step (params + cache read)."""
    from repro.models import analytic_param_count, build_model
    import jax

    from repro.utils.trees import tree_bytes

    n = analytic_param_count(cfg)
    bundle = build_model(cfg)
    cache = jax.eval_shape(lambda: bundle.init_cache(
        shape.global_batch, shape.seq_len, pos=shape.seq_len - 1))
    return 4.0 * n + 2.0 * tree_bytes(cache)  # fp32 params + cache r/w


# ---------------------------------------------------------------------------
# Exact per-step analysis via unrolled lowering
# ---------------------------------------------------------------------------

def analyze_cell(arch: str, shape_name: str, multi_pod: bool = False,
                 save: bool = True, cfg_kw: dict | None = None,
                 tag: str = "") -> dict:
    """Unrolled lowering of one cell -> exact flops/bytes/collectives."""
    import dataclasses as dc

    from repro.configs import get_config
    from repro.core import MethodConfig
    from repro.launch import dryrun as D

    cfg = dc.replace(get_config(arch), scan_layers=False, **(cfg_kw or {}))
    mcfg = MethodConfig(name="async_sam", n_microbatches=1)
    r = D.run_cell(arch, shape_name, multi_pod=multi_pod, method_cfg=mcfg,
                   cfg_override=cfg, save=False, verbose=False,
                   tag="unrolled")
    out = dataclasses.asdict(r) if dataclasses.is_dataclass(r) else r
    if save and r.status == "ok":
        ANALYSIS_DIR.mkdir(parents=True, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        (ANALYSIS_DIR / f"{arch}_{shape_name}_{r.mesh}{suffix}.json").write_text(
            json.dumps(out, indent=1))
    return out


# ---------------------------------------------------------------------------
# Table builder
# ---------------------------------------------------------------------------

def build_table(chips: int = 256, verbose: bool = True) -> list[dict]:
    from repro.configs import get_config
    from repro.core import MethodConfig
    from repro.models.config import SHAPES

    rows = []
    for prod_file in sorted(DRYRUN_DIR.glob("*_16x16.json")):
        prod = json.loads(prod_file.read_text())
        if prod["status"] != "ok":
            if prod["status"] == "skipped":
                rows.append({"arch": prod["arch"], "shape": prod["shape"],
                             "status": "skipped", "note": prod["note"]})
            continue
        arch, shape_name = prod["arch"], prod["shape"]
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        ana_file = ANALYSIS_DIR / f"{arch}_{shape_name}_16x16.json"
        ana = json.loads(ana_file.read_text()) if ana_file.exists() else None

        mcfg = MethodConfig()
        analytic = model_flops(cfg, shape, mcfg)
        # unrolled-HLO flops are exact ONLY for train cells (prefill/decode
        # paths still scan over layers; their unrolled artifacts undercount).
        # Collectives always come from the production artifact so every row
        # shares one methodology (in-scan collectives counted once — a known
        # floor documented in §Dry-run).
        if (ana and ana.get("status") == "ok" and ana.get("flops", 0) > 0
                and shape.kind == "train"):
            flops_chip = ana["flops"]
            src = "unrolled-hlo"
        else:
            flops_chip = analytic["total"] / chips
            src = "analytic"
        coll_chip = prod["collective_bytes"]
        # HBM traffic model: HLO "bytes accessed" counts every operand pre-
        # fusion (observed 20x+ over-estimate), so the memory term uses a
        # working-set model instead: state r/w (params+opt, grads) plus the
        # live activation footprint streamed a small constant number of times.
        if shape.kind == "decode":
            bytes_chip = analytic_decode_bytes(cfg, shape) / chips
        else:
            bytes_chip = (2.0 * prod["argument_bytes"]
                          + 3.0 * prod["peak_memory_per_device"])

        t_compute = flops_chip / PEAK_FLOPS
        t_memory = bytes_chip / HBM_BW
        t_coll = coll_chip / ICI_BW
        dominant = max((t_compute, "compute"), (t_memory, "memory"),
                       (t_coll, "collective"))[1]
        bound = max(t_compute, t_memory, t_coll)
        useful = analytic["model_flops_6nd"] / max(1.0, flops_chip * chips)
        # achievable fraction-of-peak when running at the roofline bound
        mfu_bound = (analytic["model_flops_6nd"]
                     / (chips * PEAK_FLOPS * bound)) if bound else 0.0
        rows.append({
            "arch": arch, "shape": shape_name, "status": "ok", "src": src,
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
            "model_flops_6nd": analytic["model_flops_6nd"],
            "hlo_flops_global": flops_chip * chips,
            "useful_ratio": useful,
            "peak_mem_gb": prod["peak_memory_per_device"] / 1e9,
            "roofline_fraction": mfu_bound,
            "lever": _lever(cfg, shape, dominant, useful),
        })
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    (REPO / "artifacts").mkdir(exist_ok=True)
    _write_csv(rows, REPO / "artifacts" / "roofline.csv")
    if verbose:
        for r in rows:
            if r["status"] == "ok":
                print(f"roofline,{r['arch']},{r['shape']},{r['src']},"
                      f"{r['t_compute_s']:.4f},{r['t_memory_s']:.4f},"
                      f"{r['t_collective_s']:.4f},{r['dominant']},"
                      f"useful={r['useful_ratio']:.3f},"
                      f"mfu_bound={r['roofline_fraction']:.3f}")
            else:
                print(f"roofline,{r['arch']},{r['shape']},skipped")
    return rows


def _lever(cfg, shape, dominant: str, useful: float) -> str:
    """One sentence: what moves this cell's dominant roofline term down."""
    if dominant == "compute":
        if cfg.moe is not None and useful < 0.4:
            return ("dense-dispatch einsums dominate: lower capacity_factor "
                    "(-19% measured on mixtral) or ragged-dispatch kernel")
        if shape.kind == "train":
            return ("remat=dots removes the re-forward (-25%, needs ~2x act "
                    "memory) and ascent_interval=k amortizes the ascent to f/k")
        return "TPU flash kernel skips masked kv blocks (~2x attention flops)"
    if dominant == "collective":
        if cfg.family in ("hybrid", "ssm"):
            return ("fsdp_sp profile (seq-sharded activations) replaces "
                    "per-block all-reduces with per-layer weight gathers "
                    "(2.2x measured on zamba2)")
        return ("overlap grad reduce-scatter with the collective-free ascent "
                "pass; bf16 weight streaming halves gather bytes")
    if shape.kind == "decode":
        return ("bandwidth-bound by design: quantized (int8) KV cache and "
                "wider decode batches raise arithmetic intensity")
    return "stream fewer activation passes (fuse CE; larger microbatches)"


def _write_csv(rows, path):
    import csv

    keys = ["arch", "shape", "status", "src", "t_compute_s", "t_memory_s",
            "t_collective_s", "dominant", "model_flops_6nd",
            "hlo_flops_global", "useful_ratio", "peak_mem_gb",
            "roofline_fraction", "lever", "note"]
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys, extrasaction="ignore")
        w.writeheader()
        for r in rows:
            w.writerow(r)


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "useful | MFU-bound | peak GB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped ({r.get('note','')[:40]}) | — | — | — |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | {r['peak_mem_gb']:.1f} |\n")
    return "".join(out)


if __name__ == "__main__":
    if len(sys.argv) > 2 and sys.argv[1] == "analyze":
        print(json.dumps(analyze_cell(sys.argv[2], sys.argv[3]), indent=1)[:500])
    else:
        build_table()
