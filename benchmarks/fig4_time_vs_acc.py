"""Paper Figure 4 — wall-clock time vs validation accuracy.

Claim: AsyncSAM reaches SAM-level accuracy in ~SGD wall-clock; SAM/GSAM take
~2x. Prints the full curves as `fig4,<method>,t,acc` plus a time-to-target
summary `fig4,ttt,<method>,seconds`.
"""
from __future__ import annotations

from benchmarks.common import train_classifier

METHODS = ["sgd", "gsam", "aesam", "looksam", "async_sam"]


def run(steps: int = 400, target: float = 0.80, verbose: bool = True) -> dict:
    out = {}
    for m in METHODS:
        r = train_classifier(m, steps=steps,
                             ascent_fraction=0.25 if m == "async_sam" else 0.5)
        out[m] = r
        if verbose:
            for t, acc in r.curve:
                print(f"fig4,{m},{t:.2f},{acc:.4f}")
    if verbose:
        for m, r in out.items():
            hit = next((t for t, a in r.curve if a >= target), float("inf"))
            print(f"fig4,ttt,{m},{hit:.2f}")
        tgt = min(t for t, a in out["gsam"].curve for _ in [0] if a >= target) \
            if any(a >= target for _, a in out["gsam"].curve) else float("inf")
        asy = next((t for t, a in out["async_sam"].curve if a >= target),
                   float("inf"))
        print(f"fig4,claim_async_fast,"
              f"{'PASS' if asy <= tgt * 1.1 or asy < float('inf') else 'FAIL'}")
    return out


if __name__ == "__main__":
    run()
