"""Shared harness for the paper-validation benchmarks (CPU scale).

The paper's CIFAR/ResNet workloads are replaced by a matched-structure
stand-in (ClassificationTask: Gaussian clusters through a random nonlinear
warp, MLP classifier) so every optimizer comparison runs in seconds on CPU
while preserving the phenomena under test: SAM-family generalization gains,
gradient stability, and the throughput cost of the extra ascent pass.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import MethodConfig
from repro.data.synthetic import ClassificationTask
from repro.engine import (Engine, EvalCallback, FusedExecutor,
                          StalenessTelemetry, ThroughputMeter)

TASK = ClassificationTask(n_classes=10, dim=64, margin=1.05, noise=1.0, seed=7)

# single source of truth in repro.service.testing: the remote benchmark lane
# resolves the SAME function by import path on the server side, so the ascent
# gradient can never come from a drifted copy of the descent loss
from repro.service.testing import mlp_loss  # noqa: E402,F401
from repro.service.testing import mlp_init as _mlp_init  # noqa: E402


def mlp_init(key, widths=(64, 128, 128, 10)) -> dict:
    return _mlp_init(key, widths)


def accuracy(params, batch) -> float:
    logits = mlp_loss(params, batch, None)[1]["logits"]
    return float(jnp.mean(jnp.argmax(logits, -1) == batch["y"]))


@dataclasses.dataclass
class TrainResult:
    method: str
    val_acc: float
    train_loss: float
    wall_time_s: float
    step_times: list
    curve: list              # [(time_s, val_acc), ...]


def train_classifier(method_name: str, *, steps: int = 400, batch: int = 128,
                     rho: float = 0.05, lr: float = 0.05,
                     ascent_fraction: float = 0.5, seed: int = 0,
                     eval_every: int = 50, task: Optional[ClassificationTask] = None,
                     mcfg_extra: Optional[dict] = None,
                     telemetry_jsonl: Optional[str] = None) -> TrainResult:
    task = task or TASK
    mcfg = MethodConfig(name=method_name, rho=rho,
                        ascent_fraction=ascent_fraction,
                        same_batch_ascent=True, mesa_start_step=steps // 4,
                        **(mcfg_extra or {}))
    opt = optim.sgd(optim.cosine_schedule(lr, steps), momentum=0.9)
    val = task.valid_set()
    batches = list(task.train_batches(batch, steps, start=seed * steps))

    meter = ThroughputMeter()
    evals = EvalCallback(lambda st: accuracy(st.params, val),
                         every=eval_every, total_steps=steps)
    callbacks = [meter, evals]
    if telemetry_jsonl:
        callbacks.append(StalenessTelemetry(print_summary=False,
                                            jsonl_path=telemetry_jsonl))
    with FusedExecutor(mlp_loss, mcfg, opt, donate=False) as ex:
        state = ex.init_state(mlp_init(jax.random.PRNGKey(seed)),
                              jax.random.PRNGKey(seed + 1))
        # warmup=1: compile outside the timed region (as all benches did)
        report = Engine(ex, batches, callbacks).fit(state, steps, warmup=1)

    final = report.final_state
    losses = [h["loss"] for h in report.metrics_history if "loss" in h]
    return TrainResult(method=method_name,
                       val_acc=accuracy(final.params, val),
                       train_loss=losses[-1],
                       wall_time_s=report.wall_time_s,
                       step_times=meter.step_times, curve=evals.curve)


def mean_std(xs) -> tuple[float, float]:
    xs = np.asarray(xs, np.float64)
    return float(xs.mean()), float(xs.std())
