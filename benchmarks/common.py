"""Shared harness for the paper-validation benchmarks (CPU scale).

The paper's CIFAR/ResNet workloads are replaced by a matched-structure
stand-in (ClassificationTask: Gaussian clusters through a random nonlinear
warp, MLP classifier) so every optimizer comparison runs in seconds on CPU
while preserving the phenomena under test: SAM-family generalization gains,
gradient stability, and the throughput cost of the extra ascent pass.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import MethodConfig, init_train_state, make_method
from repro.data.synthetic import ClassificationTask

TASK = ClassificationTask(n_classes=10, dim=64, margin=1.05, noise=1.0, seed=7)


def mlp_init(key, widths=(64, 128, 128, 10)) -> dict:
    params = {}
    for i, (a, b) in enumerate(zip(widths[:-1], widths[1:])):
        k = jax.random.fold_in(key, i)
        params[f"w{i}"] = jax.random.normal(k, (a, b)) / jnp.sqrt(a)
        params[f"b{i}"] = jnp.zeros(b)
    return params


def mlp_loss(params, batch, rng):
    h = batch["x"]
    n = len([k for k in params if k.startswith("w")])
    for i in range(n):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            h = jax.nn.gelu(h)
    onehot = jax.nn.one_hot(batch["y"], h.shape[-1])
    loss = -jnp.mean(jnp.sum(jax.nn.log_softmax(h) * onehot, axis=-1))
    return loss, {"logits": h}


def accuracy(params, batch) -> float:
    logits = mlp_loss(params, batch, None)[1]["logits"]
    return float(jnp.mean(jnp.argmax(logits, -1) == batch["y"]))


@dataclasses.dataclass
class TrainResult:
    method: str
    val_acc: float
    train_loss: float
    wall_time_s: float
    step_times: list
    curve: list              # [(time_s, val_acc), ...]


def train_classifier(method_name: str, *, steps: int = 400, batch: int = 128,
                     rho: float = 0.05, lr: float = 0.05,
                     ascent_fraction: float = 0.5, seed: int = 0,
                     eval_every: int = 50, task: Optional[ClassificationTask] = None,
                     mcfg_extra: Optional[dict] = None) -> TrainResult:
    task = task or TASK
    mcfg = MethodConfig(name=method_name, rho=rho,
                        ascent_fraction=ascent_fraction,
                        same_batch_ascent=True, mesa_start_step=steps // 4,
                        **(mcfg_extra or {}))
    method = make_method(mcfg)
    opt = optim.sgd(optim.cosine_schedule(lr, steps), momentum=0.9)
    params = mlp_init(jax.random.PRNGKey(seed))
    state = init_train_state(params, opt, method, jax.random.PRNGKey(seed + 1))
    step = jax.jit(method.make_step(mlp_loss, opt))
    val = task.valid_set()

    batches = list(task.train_batches(batch, steps, start=seed * steps))
    # warmup compile outside the timed region
    state, m = step(state, batches[0])
    jax.block_until_ready(state.params)

    t0 = time.perf_counter()
    curve, times = [], []
    for i, b in enumerate(batches[1:], start=1):
        t1 = time.perf_counter()
        state, m = step(state, b)
        jax.block_until_ready(state.params)
        times.append(time.perf_counter() - t1)
        if i % eval_every == 0 or i == steps - 1:
            curve.append((time.perf_counter() - t0, accuracy(state.params, val)))
    return TrainResult(method=method_name,
                       val_acc=accuracy(state.params, val),
                       train_loss=float(m["loss"]),
                       wall_time_s=time.perf_counter() - t0,
                       step_times=times, curve=curve)


def mean_std(xs) -> tuple[float, float]:
    xs = np.asarray(xs, np.float64)
    return float(xs.mean()), float(xs.std())
