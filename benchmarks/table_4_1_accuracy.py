"""Paper Table 4.1 — classification accuracy of the 8-method family.

CPU-scale stand-in benchmark (see benchmarks/common.py). Claims validated:
SAM-family methods beat SGD; AsyncSAM is comparable to SAM/GSAM.
Prints `method,acc_mean,acc_std,claim` CSV.
"""
from __future__ import annotations

from benchmarks.common import mean_std, train_classifier

METHODS = ["sgd", "sam", "gsam", "esam", "looksam", "aesam", "mesa", "async_sam"]
# beyond-paper variant: amortized ascent refresh (EXPERIMENTS §Perf)
VARIANTS = [("async_sam_k4", "async_sam", {"ascent_interval": 4})]


def run(steps: int = 400, seeds=(0, 1, 2), verbose: bool = True) -> dict:
    results = {}
    for m in METHODS:
        accs = [train_classifier(m, steps=steps, seed=s).val_acc for s in seeds]
        results[m] = mean_std(accs)
        if verbose:
            print(f"table_4_1,{m},{results[m][0]:.4f},{results[m][1]:.4f}")
    for tag, m, extra in VARIANTS:
        accs = [train_classifier(m, steps=steps, seed=s,
                                 mcfg_extra=extra).val_acc for s in seeds]
        results[tag] = mean_std(accs)
        if verbose:
            print(f"table_4_1,{tag},{results[tag][0]:.4f},{results[tag][1]:.4f}")
    if verbose:
        sam_like = results["async_sam"][0]
        print(f"table_4_1,claim_async_vs_sgd,{sam_like - results['sgd'][0]:.4f},"
              f"{'PASS' if sam_like >= results['sgd'][0] - 0.002 else 'FAIL'}")
        print(f"table_4_1,claim_async_vs_sam,{sam_like - results['sam'][0]:.4f},"
              f"{'PASS' if abs(sam_like - results['sam'][0]) < 0.03 else 'FAIL'}")
    return results


if __name__ == "__main__":
    run()
