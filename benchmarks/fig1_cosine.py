"""Paper Figure 1 — cosine similarity of consecutive gradients (same data).

Claim: similarity stays high (paper: mostly > 0.8 on CIFAR-scale nets; the
threshold scales with model/task noise) => one-step-stale ascent directions
remain informative. Prints `fig1,<probe>,mean_cos,min_cos,frac_above_0.8`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import TASK, mlp_init, mlp_loss
from repro import optim
from repro.core import MethodConfig, init_train_state, make_method
from repro.utils import trees


def run(steps: int = 300, verbose: bool = True) -> dict:
    method = make_method(MethodConfig(name="sgd"))
    opt = optim.sgd(0.05, momentum=0.9)
    params = mlp_init(jax.random.PRNGKey(0))
    state = init_train_state(params, opt, method, jax.random.PRNGKey(1))
    step = jax.jit(method.make_step(mlp_loss, opt))
    grad_fn = jax.jit(jax.grad(lambda p, b: mlp_loss(p, b, None)[0]))

    probe = next(iter(TASK.train_batches(256, 1, start=9999)))  # fixed samples
    batches = list(TASK.train_batches(128, steps))
    prev_g, sims = None, []
    for b in batches:
        g = grad_fn(state.params, probe)
        if prev_g is not None:
            sims.append(float(trees.tree_cosine_similarity(g, prev_g)))
        prev_g = g
        state, _ = step(state, b)
    sims = jnp.asarray(sims[5:])  # skip the initial transient
    out = {"mean": float(jnp.mean(sims)), "min": float(jnp.min(sims)),
           "frac_above_0.8": float(jnp.mean(sims > 0.8))}
    if verbose:
        print(f"fig1,mlp,{out['mean']:.4f},{out['min']:.4f},{out['frac_above_0.8']:.3f}")
        print(f"fig1,claim_high_similarity,"
              f"{'PASS' if out['mean'] > 0.8 else 'FAIL'},mean={out['mean']:.3f}")
    return out


if __name__ == "__main__":
    run()
