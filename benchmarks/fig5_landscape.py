"""Paper Figure 5 — loss-landscape flatness at the converged solution.

Quantitative proxies instead of a 30x30 surface plot:
  * random-direction sharpness: E[L(w + r*u) - L(w)] over unit Gaussians u;
  * adversarial sharpness: L(w + r*g/||g||) - L(w) (the SAM inner max).
Claim: SAM and AsyncSAM both land in flatter regions than SGD.
Prints `fig5,<method>,rand_sharpness,adv_sharpness,val_acc`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import TASK, mlp_loss, train_classifier
from repro.core import perturb
from repro.utils import trees

METHODS = ["sgd", "sam", "async_sam"]
RHO = 0.5


def sharpness(params, batch, n_dirs: int = 12, rho: float = RHO):
    base = float(mlp_loss(params, batch, None)[0])
    key = jax.random.PRNGKey(42)
    rand = []
    for i in range(n_dirs):
        u = trees.tree_random_like(jax.random.fold_in(key, i), params)
        w = perturb(params, u, rho)
        rand.append(float(mlp_loss(w, batch, None)[0]) - base)
    g = jax.grad(lambda p: mlp_loss(p, batch, None)[0])(params)
    adv = float(mlp_loss(perturb(params, g, rho), batch, None)[0]) - base
    return sum(rand) / len(rand), adv


def run(steps: int = 400, verbose: bool = True) -> dict:
    batch = TASK.valid_set(1024)
    out = {}
    for m in METHODS:
        r = train_classifier(m, steps=steps, rho=0.1)
        rs, advs = sharpness(_params_of(m, steps), batch)
        out[m] = (rs, advs, r.val_acc)
        if verbose:
            print(f"fig5,{m},{rs:.4f},{advs:.4f},{r.val_acc:.4f}")
    if verbose:
        print(f"fig5,claim_sam_flatter,"
              f"{'PASS' if out['sam'][1] < out['sgd'][1] else 'FAIL'}")
        print(f"fig5,claim_async_flatter,"
              f"{'PASS' if out['async_sam'][1] < out['sgd'][1] else 'FAIL'}")
    return out


def _params_of(method: str, steps: int):
    """Re-train and return final parameters (kept simple; seconds on CPU)."""
    from repro import optim
    from repro.core import MethodConfig, init_train_state, make_method
    from benchmarks.common import mlp_init

    mcfg = MethodConfig(name=method, rho=0.1, ascent_fraction=0.5,
                        same_batch_ascent=True)
    mth = make_method(mcfg)
    opt = optim.sgd(optim.cosine_schedule(0.05, steps), momentum=0.9)
    state = init_train_state(mlp_init(jax.random.PRNGKey(0)), opt, mth,
                             jax.random.PRNGKey(1))
    step = jax.jit(mth.make_step(mlp_loss, opt))
    for b in TASK.train_batches(128, steps):
        state, _ = step(state, b)
    return state.params


if __name__ == "__main__":
    run()
