"""Overlap report — the paper's Fig-1 claim as a measured artifact.

AsyncSAM's core timing claim is that the ascent (perturbation) computation
runs on a slow lane *while* the descent lane keeps stepping — at best the
perturbation time is entirely hidden. This report makes that measurable:
feed it a Chrome/Perfetto trace produced by `repro.obs.TraceEventSink`
(e.g. `python -m repro.launch.train --trace trace.json ...`, or the built-in
`--run` mode below) and it computes the **hidden-perturbation fraction**:
the share of ascent-lane busy time (ascent_compute / ascent_rpc /
pool_exchange spans) that overlaps descent-lane compute spans.

    python benchmarks/overlap_report.py --run hetero          # trace + report
    python benchmarks/overlap_report.py --run remote          # via the pool
    python benchmarks/overlap_report.py --trace trace.json    # existing trace

Writes `artifacts/perf/BENCH_overlap.json` (hidden fraction, step-time
p50/p95, total wire bytes) so the bench trajectory tracks overlap across
commits; the trace itself lands in `artifacts/traces/` (gitignored — load it
at ui.perfetto.dev to *see* the overlap as stacked tracks).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

#: span names that are real perturbation work on a slow lane
ASCENT_BUSY = ("ascent_compute", "ascent_rpc", "pool_exchange")
#: descent-lane spans the perturbation can hide under
DESCENT_BUSY = ("descent_compute",)


def load_trace(path) -> dict:
    return json.loads(pathlib.Path(path).read_text())


def _merge(intervals: list) -> list:
    """Sorted union of (t0, t1) intervals."""
    out: list = []
    for t0, t1 in sorted(intervals):
        if out and t0 <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], t1))
        else:
            out.append((t0, t1))
    return out


def _overlap(t0: float, t1: float, merged: list) -> float:
    return sum(max(0.0, min(t1, b) - max(t0, a)) for a, b in merged)


def _percentile(sorted_vals: list, q: float) -> float:
    if not sorted_vals:
        return 0.0
    return sorted_vals[int(q * (len(sorted_vals) - 1))]


def compute_overlap(trace: dict) -> dict:
    """-> the overlap report for one trace (times in seconds)."""
    spans = [e for e in trace.get("traceEvents", []) if e.get("ph") == "X"]
    descent = _merge([(e["ts"], e["ts"] + e["dur"]) for e in spans
                      if e["name"] in DESCENT_BUSY])
    ascent = [(e["ts"], e["ts"] + e["dur"]) for e in spans
              if e["name"] in ASCENT_BUSY]
    busy_us = sum(t1 - t0 for t0, t1 in ascent)
    hidden_us = sum(_overlap(t0, t1, descent) for t0, t1 in ascent)
    steps = sorted(e["dur"] * 1e-6 for e in spans
                   if e["name"] == "train_step")
    wire = sum(e.get("args", {}).get("wire_bytes", 0) for e in spans
               if e["name"] == "ascent_rpc")
    return {
        "hidden_fraction": (hidden_us / busy_us) if busy_us else 0.0,
        "ascent_busy_s": busy_us * 1e-6,
        "hidden_s": hidden_us * 1e-6,
        "ascent_spans": len(ascent),
        "steps": len(steps),
        "step_time_p50_s": _percentile(steps, 0.50),
        "step_time_p95_s": _percentile(steps, 0.95),
        "wire_bytes_total": int(wire),
    }


def run_traced(executor: str, steps: int, trace_path: pathlib.Path) -> None:
    """Small lockstep MLP fit with a TraceEventSink attached."""
    import jax

    from repro import optim
    from repro.core import MethodConfig, slice_ascent_batch
    from repro.data.synthetic import ClassificationTask
    from repro.engine import Engine, HeteroExecutor, RemoteExecutor
    from repro.obs import TraceEventSink, Tracker
    from repro.runtime import ExecutorConfig
    from repro.service.ascent_server import AscentServer
    from repro.service.testing import mlp_init, mlp_loss

    task = ClassificationTask(n_classes=4, dim=8, seed=3)
    batches = [{**b, "ascent": slice_ascent_batch(b, 0.5)}
               for b in task.train_batches(128, steps)]
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5)
    opt = optim.sgd(0.1, momentum=0.9)
    # lockstep: every step harvests the previous step's exchange, so the
    # overlap in the trace is the paper's steady-state tau=1 schedule
    server = None
    if executor == "remote":
        server = AscentServer(mlp_loss)
        server.serve_in_thread()
        xcfg = ExecutorConfig(lockstep=True, ascent_addr=server.address)
        ex = RemoteExecutor(mlp_loss, mcfg, opt, exec_cfg=xcfg)
    else:
        xcfg = ExecutorConfig(lockstep=True)
        ex = HeteroExecutor(mlp_loss, mcfg, opt, exec_cfg=xcfg)
    tracker = Tracker([TraceEventSink(trace_path)])
    try:
        with ex:
            state = ex.init_state(mlp_init(jax.random.PRNGKey(0)),
                                  jax.random.PRNGKey(1))
            Engine(ex, batches).fit(state, steps, tracker=tracker)
    finally:
        tracker.close()
        if server is not None:
            server.close()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--trace", default="",
                    help="existing trace-event JSON to analyze")
    ap.add_argument("--run", choices=("hetero", "remote"), default="",
                    help="produce the trace first: small lockstep MLP fit "
                         "on this executor")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--out", default=str(ROOT / "artifacts" / "perf"
                                         / "BENCH_overlap.json"))
    args = ap.parse_args(argv)
    if not args.trace and not args.run:
        ap.error("pass --trace <file> or --run {hetero,remote}")
    trace_path = pathlib.Path(
        args.trace or ROOT / "artifacts" / "traces"
        / f"overlap_{args.run}.json")
    if args.run:
        trace_path.parent.mkdir(parents=True, exist_ok=True)
        run_traced(args.run, args.steps, trace_path)
        print(f"trace written to {trace_path} (load at ui.perfetto.dev)")
    report = compute_overlap(load_trace(trace_path))
    report["executor"] = args.run or "trace"
    print(json.dumps(report, indent=2))
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"report written to {out}")


if __name__ == "__main__":
    main()
