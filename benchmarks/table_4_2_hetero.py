"""Paper Table 4.2 — AsyncSAM on heterogeneous resources: b/b' sweep.

The slow resource is emulated by injecting per-call delay into the ascent lane
of the executor; b' is then set system-aware per paper §3.3. Claims: epoch
time stays ~flat as the helper slows (ascent fully hidden), accuracy degrades
gracefully with b/b'. Prints `table_4_2,ratio,epoch_time_s,val_acc,tau_mean`.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import TASK, accuracy, mlp_init, mlp_loss
from repro import optim
from repro.core import MethodConfig, init_train_state, make_method
from repro.runtime import AsyncSamExecutor, ExecutorConfig

RATIOS = [1, 2, 3, 5]     # b / b'


def run(steps: int = 250, batch: int = 128, verbose: bool = True) -> dict:
    out = {}
    for ratio in RATIOS:
        frac = 1.0 / ratio
        mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=frac)
        opt = optim.sgd(optim.cosine_schedule(0.05, steps), momentum=0.9)
        method = make_method(mcfg)
        params = mlp_init(jax.random.PRNGKey(0))
        state = init_train_state(params, opt, method, jax.random.PRNGKey(1))
        # helper slowness proportional to ratio (it computes b/ratio samples
        # in the time the fast lane does b)
        xcfg = ExecutorConfig(max_staleness=3)
        val = TASK.valid_set()
        with AsyncSamExecutor(mlp_loss, mcfg, opt, xcfg) as ex:
            batches = list(TASK.train_batches(batch, steps))
            bb = dict(batches[0])
            bb["ascent"] = {k: v[: max(1, int(batch * frac))] for k, v in bb.items()}
            state, _ = ex.step(state, bb)   # warmup
            taus = []
            t0 = time.perf_counter()
            for b in batches[1:]:
                ab = {k: v[: max(1, int(batch * frac))] for k, v in b.items()}
                state, m = ex.step(state, {**b, "ascent": ab})
                taus.append(m["tau"])
            dt = time.perf_counter() - t0
        acc = accuracy(state.params, val)
        out[ratio] = (dt, acc, float(np.mean(taus)))
        if verbose:
            print(f"table_4_2,{ratio}x,{dt:.2f},{acc:.4f},{np.mean(taus):.2f}")
    if verbose:
        t1, tmax = out[1][0], max(v[0] for v in out.values())
        print(f"table_4_2,claim_time_flat,"
              f"{'PASS' if tmax < 1.6 * t1 else 'FAIL'},{tmax / t1:.2f}x")
        print(f"table_4_2,claim_acc_graceful,"
              f"{'PASS' if out[5][1] > out[1][1] - 0.08 else 'FAIL'}")
    return out


if __name__ == "__main__":
    run()
