"""Paper Table 4.2 — AsyncSAM on heterogeneous resources: b/b' sweep.

The slow resource is emulated by injecting per-call delay into the ascent lane
of the executor; b' is then set system-aware per paper §3.3. Claims: epoch
time stays ~flat as the helper slows (ascent fully hidden), accuracy degrades
gracefully with b/b'. Prints `table_4_2,ratio,epoch_time_s,val_acc,tau_mean`.

Runs through `Engine.fit` with the HeteroExecutor (the same path as
`--executor hetero` in the launcher).
"""
from __future__ import annotations

import pathlib

import jax
import numpy as np

from benchmarks.common import TASK, accuracy, mlp_init, mlp_loss
from repro import optim
from repro.core import MethodConfig, slice_ascent_batch
from repro.engine import Engine, HeteroExecutor, StalenessTelemetry, ThroughputMeter
from repro.runtime import ExecutorConfig

RATIOS = [1, 2, 3, 5]     # b / b'
TELEMETRY_DIR = (pathlib.Path(__file__).resolve().parents[1]
                 / "artifacts" / "telemetry")


def run(steps: int = 250, batch: int = 128, verbose: bool = True) -> dict:
    out = {}
    for ratio in RATIOS:
        frac = 1.0 / ratio
        mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=frac)
        opt = optim.sgd(optim.cosine_schedule(0.05, steps), momentum=0.9)
        val = TASK.valid_set()
        batches = [{**b, "ascent": slice_ascent_batch(b, frac)}
                   for b in TASK.train_batches(batch, steps)]
        meter = ThroughputMeter()
        telemetry = StalenessTelemetry(
            print_summary=False,
            jsonl_path=TELEMETRY_DIR / f"table_4_2_ratio{ratio}.jsonl")
        with HeteroExecutor(mlp_loss, mcfg, opt,
                            exec_cfg=ExecutorConfig(max_staleness=3)) as ex:
            state = ex.init_state(mlp_init(jax.random.PRNGKey(0)),
                                  jax.random.PRNGKey(1))
            report = Engine(ex, batches, [meter, telemetry]).fit(
                state, steps, warmup=1)
        taus = [h["tau"] for h in report.metrics_history]
        dt = sum(meter.step_times)
        acc = accuracy(report.final_state.params, val)
        out[ratio] = (dt, acc, float(np.mean(taus)))
        if verbose:
            print(f"table_4_2,{ratio}x,{dt:.2f},{acc:.4f},{np.mean(taus):.2f}")
    if verbose:
        t1, tmax = out[1][0], max(v[0] for v in out.values())
        print(f"table_4_2,claim_time_flat,"
              f"{'PASS' if tmax < 1.6 * t1 else 'FAIL'},{tmax / t1:.2f}x")
        print(f"table_4_2,claim_acc_graceful,"
              f"{'PASS' if out[5][1] > out[1][1] - 0.08 else 'FAIL'}")
    return out


if __name__ == "__main__":
    run()
