"""Paper Table 4.2 — AsyncSAM on heterogeneous resources: b/b' sweep.

The slow resource is emulated by injecting per-call delay into the ascent lane
of the executor; b' is then set system-aware per paper §3.3. Claims: epoch
time stays ~flat as the helper slows (ascent fully hidden), accuracy degrades
gracefully with b/b'. Prints `table_4_2,ratio,epoch_time_s,val_acc,tau_mean`.

Runs through `Engine.fit` with the HeteroExecutor (the same path as
`--executor hetero` in the launcher).

`run_remote()` adds the multi-host lane: the same schedule with the ascent
gradient crossing a real socket to a spawned `repro.service.ascent_server`
subprocess (the `--executor remote --serve-ascent` path), reporting the
*measured* wire bytes per exchange against the `Compressor.wire_bytes` +
`protocol.grad_frame_bytes` model — the two must agree exactly for the
gradient-return frame.
"""
from __future__ import annotations

import pathlib

import jax
import numpy as np

from benchmarks.common import TASK, accuracy, mlp_init, mlp_loss
from repro import optim
from repro.core import MethodConfig, slice_ascent_batch
from repro.core.ascent import Compressor
from repro.engine import (Engine, HeteroExecutor, RemoteExecutor,
                          StalenessTelemetry, ThroughputMeter)
from repro.runtime import ExecutorConfig
from repro.service import protocol

RATIOS = [1, 2, 3, 5]     # b / b'
TELEMETRY_DIR = (pathlib.Path(__file__).resolve().parents[1]
                 / "artifacts" / "telemetry")


def run(steps: int = 250, batch: int = 128, verbose: bool = True) -> dict:
    out = {}
    for ratio in RATIOS:
        frac = 1.0 / ratio
        mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=frac)
        opt = optim.sgd(optim.cosine_schedule(0.05, steps), momentum=0.9)
        val = TASK.valid_set()
        batches = [{**b, "ascent": slice_ascent_batch(b, frac)}
                   for b in TASK.train_batches(batch, steps)]
        meter = ThroughputMeter()
        telemetry = StalenessTelemetry(
            print_summary=False,
            jsonl_path=TELEMETRY_DIR / f"table_4_2_ratio{ratio}.jsonl")
        with HeteroExecutor(mlp_loss, mcfg, opt,
                            exec_cfg=ExecutorConfig(max_staleness=3)) as ex:
            state = ex.init_state(mlp_init(jax.random.PRNGKey(0)),
                                  jax.random.PRNGKey(1))
            report = Engine(ex, batches, [meter, telemetry]).fit(
                state, steps, warmup=1)
        taus = [h["tau"] for h in report.metrics_history]
        dt = sum(meter.step_times)
        acc = accuracy(report.final_state.params, val)
        out[ratio] = (dt, acc, float(np.mean(taus)))
        if verbose:
            print(f"table_4_2,{ratio}x,{dt:.2f},{acc:.4f},{np.mean(taus):.2f}")
    if verbose:
        t1, tmax = out[1][0], max(v[0] for v in out.values())
        print(f"table_4_2,claim_time_flat,"
              f"{'PASS' if tmax < 1.6 * t1 else 'FAIL'},{tmax / t1:.2f}x")
        print(f"table_4_2,claim_acc_graceful,"
              f"{'PASS' if out[5][1] > out[1][1] - 0.08 else 'FAIL'}")
    return out


def run_remote(steps: int = 120, batch: int = 128, compressor: str = "int8",
               verbose: bool = True) -> dict:
    """Multi-host lane: ascent over a real socket (loopback subprocess).

    Reports measured wire traffic per exchange vs the modeled GRAD frame
    length (`protocol.grad_frame_bytes` on top of `Compressor.wire_bytes`).
    The server holds `repro.service.testing:mlp_loss` — the same generic
    w{i}/b{i} MLP math as `benchmarks.common.mlp_loss`, importable from the
    subprocess regardless of cwd.
    """
    frac = 0.5
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=frac,
                        compressor=compressor)
    opt = optim.sgd(optim.cosine_schedule(0.05, steps), momentum=0.9)
    val = TASK.valid_set()
    batches = [{**b, "ascent": slice_ascent_batch(b, frac)}
               for b in TASK.train_batches(batch, steps)]
    meter = ThroughputMeter()
    telemetry = StalenessTelemetry(
        print_summary=False,
        jsonl_path=TELEMETRY_DIR / f"table_4_2_remote_{compressor}.jsonl")
    # calibrate=True doubles as the lane warmup: the pre-fit probe pays the
    # server spawn + connect + jit compile in blocking round trips, so the
    # timed loop below measures the steady-state exchange, not startup
    with RemoteExecutor(mlp_loss, mcfg, opt, calibrate=True,
                        calibration_probes=1,
                        exec_cfg=ExecutorConfig(
                            max_staleness=3, serve_ascent=True,
                            loss_spec="repro.service.testing:mlp_loss")) as ex:
        state = ex.init_state(mlp_init(jax.random.PRNGKey(0)),
                              jax.random.PRNGKey(1))
        report = Engine(ex, batches, [meter, telemetry]).fit(
            state, steps, warmup=1)
        client = ex.client
        grad_template = jax.device_get(mlp_init(jax.random.PRNGKey(0)))
        comp = Compressor(kind=compressor, topk_fraction=mcfg.topk_fraction)
        modeled = protocol.grad_frame_bytes(comp, grad_template)
        measured = client.wire_bytes_per_exchange
        out = {
            "val_acc": accuracy(report.final_state.params, val),
            "epoch_time_s": sum(meter.step_times),
            "exchanges": client.exchanges,
            "grad_frame_measured": measured,
            "grad_frame_modeled": modeled,
            "payload_modeled": comp.wire_bytes(grad_template),
            "job_frame_bytes": client.last_wire_out_bytes,
        }
        # steady-state RTT from the per-step records: client.timings also
        # holds the calibration warmup (connect + server jit, ~30x larger)
        rtts = [h["rtt_s"] for h in report.metrics_history if h.get("rtt_s")]
        out["rtt_mean_s"] = float(np.mean(rtts)) if rtts else 0.0
    taus = [h["tau"] for h in report.metrics_history]
    out["tau_mean"] = float(np.mean(taus))
    if verbose:
        print(f"table_4_2_remote,{compressor},"
              f"{out['epoch_time_s']:.2f},{out['val_acc']:.4f},"
              f"{out['tau_mean']:.2f},exchanges={out['exchanges']}")
        print(f"table_4_2_remote,wire,grad_frame_measured="
              f"{out['grad_frame_measured']},grad_frame_modeled="
              f"{out['grad_frame_modeled']},payload_modeled="
              f"{out['payload_modeled']},job_frame={out['job_frame_bytes']},"
              f"rtt_mean_s={out['rtt_mean_s']:.4f}")
        print(f"table_4_2_remote,claim_wire_model_exact,"
              f"{'PASS' if out['grad_frame_measured'] == out['grad_frame_modeled'] else 'FAIL'}")
    return out


if __name__ == "__main__":
    run()
    run_remote()
