"""Paper Table 4.2 — AsyncSAM on heterogeneous resources: b/b' sweep.

The slow resource is emulated by injecting per-call delay into the ascent lane
of the executor; b' is then set system-aware per paper §3.3. Claims: epoch
time stays ~flat as the helper slows (ascent fully hidden), accuracy degrades
gracefully with b/b'. Prints `table_4_2,ratio,epoch_time_s,val_acc,tau_mean`.

Runs through `Engine.fit` with the HeteroExecutor (the same path as
`--executor hetero` in the launcher).

`run_remote()` adds the multi-host lane: the same schedule with the ascent
gradient crossing a real socket to a spawned `repro.service.ascent_server`
subprocess (the `--executor remote --serve-ascent` path), reporting the
*measured* wire bytes per exchange against the byte models — exact-match
asserted in BOTH directions: the gradient-return frame against
`Compressor.wire_bytes` + `protocol.grad_frame_bytes`, and the JOB frame
(full snapshot or delta-encoded, per `--job-compress`) against
`protocol.job_frame_bytes`.

`run_wire_budget()` sweeps the three JOB encodings through short measured
loopback runs, then models the olmo-1b wire budget per exchange
(fp32 snapshot vs int8 delta vs topk delta) from abstract params — the
artifact behind the README wire-budget table and the >=4x JOB-direction
acceptance claim.

Both entry points take `clients=N` (CLI `--clients N`): N descent clients
attach to ONE spawned pool server (`--pool-workers 2`) in the same
ascent-sync group, each fit on its own thread. The wire models are then
asserted measured == modeled per client, and the fleet aggregate
(sum of per-client JOB/GRAD bytes) plus the pool's shutdown stats line are
reported — the multi-client half of the Table 4.2 wire story.
"""
from __future__ import annotations

import json
import pathlib
import threading

import jax
import numpy as np

from benchmarks.common import TASK, accuracy, mlp_init, mlp_loss
from repro import optim
from repro.core import MethodConfig, slice_ascent_batch
from repro.core.ascent import Compressor
from repro.engine import (Engine, HeteroExecutor, RemoteExecutor,
                          StalenessTelemetry, ThroughputMeter)
from repro.runtime import ExecutorConfig
from repro.service import protocol

RATIOS = [1, 2, 3, 5]     # b / b'
TELEMETRY_DIR = (pathlib.Path(__file__).resolve().parents[1]
                 / "artifacts" / "telemetry")
PERF_DIR = (pathlib.Path(__file__).resolve().parents[1]
            / "artifacts" / "perf")


def run(steps: int = 250, batch: int = 128, verbose: bool = True) -> dict:
    out = {}
    for ratio in RATIOS:
        frac = 1.0 / ratio
        mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=frac)
        opt = optim.sgd(optim.cosine_schedule(0.05, steps), momentum=0.9)
        val = TASK.valid_set()
        batches = [{**b, "ascent": slice_ascent_batch(b, frac)}
                   for b in TASK.train_batches(batch, steps)]
        meter = ThroughputMeter()
        telemetry = StalenessTelemetry(
            print_summary=False,
            jsonl_path=TELEMETRY_DIR / f"table_4_2_ratio{ratio}.jsonl")
        with HeteroExecutor(mlp_loss, mcfg, opt,
                            exec_cfg=ExecutorConfig(max_staleness=3)) as ex:
            state = ex.init_state(mlp_init(jax.random.PRNGKey(0)),
                                  jax.random.PRNGKey(1))
            report = Engine(ex, batches, [meter, telemetry]).fit(
                state, steps, warmup=1)
        taus = [h["tau"] for h in report.metrics_history]
        dt = sum(meter.step_times)
        acc = accuracy(report.final_state.params, val)
        out[ratio] = (dt, acc, float(np.mean(taus)))
        if verbose:
            print(f"table_4_2,{ratio}x,{dt:.2f},{acc:.4f},{np.mean(taus):.2f}")
    if verbose:
        t1, tmax = out[1][0], max(v[0] for v in out.values())
        print(f"table_4_2,claim_time_flat,"
              f"{'PASS' if tmax < 1.6 * t1 else 'FAIL'},{tmax / t1:.2f}x")
        print(f"table_4_2,claim_acc_graceful,"
              f"{'PASS' if out[5][1] > out[1][1] - 0.08 else 'FAIL'}")
    return out


def run_remote(steps: int = 120, batch: int = 128, compressor: str = "int8",
               job_compress: str = "int8", job_delta: bool = True,
               clients: int = 1, verbose: bool = True) -> dict:
    """Multi-host lane: ascent over a real socket (loopback subprocess).

    Reports measured wire traffic per exchange vs the byte models, exact in
    both directions: GRAD (`protocol.grad_frame_bytes` on top of
    `Compressor.wire_bytes` — including the revision-3 pool-telemetry
    prelude the pooled server now sends) and JOB (`protocol.job_frame_bytes`
    — full snapshot and, when `job_compress`/`job_delta` enable it, the
    delta-encoded form). The server holds `repro.service.testing:mlp_loss`
    — the same generic w{i}/b{i} MLP math as `benchmarks.common.mlp_loss`,
    importable from the subprocess regardless of cwd.

    `clients > 1` switches to the pool topology: one spawned server with two
    ascent workers, N concurrent client fits (see `_run_remote_pool`).
    """
    if clients > 1:
        return _run_remote_pool(steps, batch, compressor, job_compress,
                                job_delta, clients, verbose)
    frac = 0.5
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=frac,
                        compressor=compressor)
    opt = optim.sgd(optim.cosine_schedule(0.05, steps), momentum=0.9)
    val = TASK.valid_set()
    batches = [{**b, "ascent": slice_ascent_batch(b, frac)}
               for b in TASK.train_batches(batch, steps)]
    meter = ThroughputMeter()
    telemetry = StalenessTelemetry(
        print_summary=False,
        jsonl_path=TELEMETRY_DIR
        / f"table_4_2_remote_{compressor}_job_{job_compress}.jsonl")
    # calibrate=True doubles as the lane warmup: the pre-fit probe pays the
    # server spawn + connect + jit compile in blocking round trips, so the
    # timed loop below measures the steady-state exchange, not startup
    with RemoteExecutor(mlp_loss, mcfg, opt, calibrate=True,
                        calibration_probes=1,
                        exec_cfg=ExecutorConfig(
                            max_staleness=3, serve_ascent=True,
                            job_compress=job_compress, job_delta=job_delta,
                            loss_spec="repro.service.testing:mlp_loss")) as ex:
        state = ex.init_state(mlp_init(jax.random.PRNGKey(0)),
                              jax.random.PRNGKey(1))
        report = Engine(ex, batches, [meter, telemetry]).fit(
            state, steps, warmup=1)
        client = ex.client
        params_t = jax.device_get(mlp_init(jax.random.PRNGKey(0)))
        ascent_t = jax.device_get(batches[0]["ascent"])
        # steady-state jobs carry the ascent batch trimmed to the CALIBRATED
        # b' (HeteroExecutor._cap_ascent); the one snapshot JOB was the
        # uncapped calibration probe — model each with its actual shapes
        target = max(1, int(round(batch * min(frac, ex.calibrated_fraction
                                              or frac))))
        ascent_capped = jax.tree.map(lambda x: x[:target], ascent_t)
        rng_t = np.asarray(jax.device_get(jax.random.PRNGKey(1)))
        comp = Compressor(kind=compressor, topk_fraction=mcfg.topk_fraction)
        # the pooled server negotiates protocol revision 3, so every GRAD
        # frame carries the pool-telemetry prelude — model it
        modeled = protocol.grad_frame_bytes(comp, params_t, pool=True)
        measured = client.wire_bytes_per_exchange
        delta_active = job_delta and job_compress != "none"
        # a snapshot is either the uncapped calibration probe or a capped
        # fit-loop job (job_compress none every step; delta runs only on a
        # resync fallback) — both shapes are legal on the wire
        snap_modeled = {protocol.job_frame_bytes(
            job_compress, params_t, a, rng_t, delta=False)
            for a in (ascent_t, ascent_capped)}
        job_modeled = {"snapshot": max(snap_modeled)}
        if delta_active:
            job_modeled[job_compress] = protocol.job_frame_bytes(
                job_compress, params_t, ascent_capped, rng_t, delta=True,
                topk_fraction=mcfg.topk_fraction)
        # measured == modeled, asserted per job kind seen on the wire
        for kind, measured_job in client.job_frame_measured.items():
            if kind == "snapshot":
                assert measured_job in snap_modeled, \
                    (measured_job, snap_modeled)
            else:
                assert measured_job == job_modeled[kind], \
                    (kind, measured_job, job_modeled)
        out = {
            "val_acc": accuracy(report.final_state.params, val),
            "epoch_time_s": sum(meter.step_times),
            "exchanges": client.exchanges,
            "grad_frame_measured": measured,
            "grad_frame_modeled": modeled,
            "payload_modeled": comp.wire_bytes(params_t),
            "job_compress": job_compress,
            "job_delta": delta_active,
            "job_frame_measured": dict(client.job_frame_measured),
            "job_frame_modeled": job_modeled,
            "job_snapshot_jobs": client.job_encoder.snapshot_jobs,
            "job_delta_jobs": client.job_encoder.delta_jobs,
            # steady-state per-exchange split (the delta form once synced,
            # else the snapshot): the JOB/GRAD byte report
            "job_bytes_per_exchange": client.last_wire_out_bytes,
            "grad_bytes_per_exchange": client.last_wire_in_bytes,
        }
        # steady-state RTT from the per-step records: client.timings also
        # holds the calibration warmup (connect + server jit, ~30x larger)
        rtts = [h["rtt_s"] for h in report.metrics_history if h.get("rtt_s")]
        out["rtt_mean_s"] = float(np.mean(rtts)) if rtts else 0.0
    taus = [h["tau"] for h in report.metrics_history]
    out["tau_mean"] = float(np.mean(taus))
    if verbose:
        print(f"table_4_2_remote,{compressor},"
              f"{out['epoch_time_s']:.2f},{out['val_acc']:.4f},"
              f"{out['tau_mean']:.2f},exchanges={out['exchanges']}")
        print(f"table_4_2_remote,wire,grad_frame_measured="
              f"{out['grad_frame_measured']},grad_frame_modeled="
              f"{out['grad_frame_modeled']},payload_modeled="
              f"{out['payload_modeled']},"
              f"job={out['job_bytes_per_exchange']},"
              f"grad={out['grad_bytes_per_exchange']},"
              f"rtt_mean_s={out['rtt_mean_s']:.4f}")
        print(f"table_4_2_remote,job_wire,compress={job_compress},"
              f"measured={out['job_frame_measured']},"
              f"modeled={out['job_frame_modeled']}")
        print(f"table_4_2_remote,claim_wire_model_exact,"
              f"{'PASS' if out['grad_frame_measured'] == out['grad_frame_modeled'] else 'FAIL'}")
    return out


def _run_remote_pool(steps: int, batch: int, compressor: str,
                     job_compress: str, job_delta: bool, clients: int,
                     verbose: bool) -> dict:
    """N descent clients against ONE spawned pool server (2 ascent workers).

    Each client runs the same fit on its own thread, attached to the shared
    `fleet` ascent-sync group with a stable numeric `client_id`, and the
    wire models are asserted measured == modeled for every client's own
    stream. The aggregate (summed per-client JOB/GRAD bytes) is the fleet
    wire budget; the pool's shutdown stats line (parsed from the subprocess
    tail after kill) is the scheduler-side evidence — connections, served
    exchanges, shared-shadow install/replay counters.

    The fits run lockstep with a per-step barrier across the replicas (a DP
    launcher's collective would impose the same cadence): every step is a
    real exchange — no warmup race against the subprocess jit — and the
    replica skew stays within the canonical shadow's replay ring.
    """
    from repro.engine.callbacks import Callback
    from repro.service import spawn_server

    class _StepBarrier(Callback):
        def __init__(self, barrier):
            self.barrier = barrier

        def on_step(self, engine, state, metrics, step_time_s):
            self.barrier.wait(timeout=600)
    frac = 0.5
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=frac,
                        compressor=compressor)
    val = TASK.valid_set()
    batches = [{**b, "ascent": slice_ascent_batch(b, frac)}
               for b in TASK.train_batches(batch, steps)]
    handle = spawn_server("repro.service.testing:mlp_loss",
                          pool_workers=2,
                          queue_depth=max(4, 2 * clients))
    barrier = threading.Barrier(clients)
    results: list = [None] * clients
    errors: list = []

    def _one(idx: int) -> None:
        opt = optim.sgd(optim.cosine_schedule(0.05, steps), momentum=0.9)
        meter = ThroughputMeter()
        telemetry = StalenessTelemetry(
            print_summary=False,
            jsonl_path=TELEMETRY_DIR /
            f"table_4_2_pool_{compressor}_job_{job_compress}_c{idx}.jsonl")
        with RemoteExecutor(mlp_loss, mcfg, opt, exec_cfg=ExecutorConfig(
                max_staleness=3, lockstep=True, ascent_addr=handle.addr,
                job_compress=job_compress, job_delta=job_delta,
                client_id=str(idx), sync_group="fleet")) as ex:
            state = ex.init_state(mlp_init(jax.random.PRNGKey(0)),
                                  jax.random.PRNGKey(1))
            report = Engine(ex, batches,
                            [meter, telemetry, _StepBarrier(barrier)]).fit(
                state, steps, warmup=1)
            c = ex.client
            results[idx] = {
                "client_id": idx,
                "val_acc": accuracy(report.final_state.params, val),
                "exchanges": c.exchanges,
                "grad_frame_measured": c.wire_bytes_per_exchange,
                "job_frame_measured": dict(c.job_frame_measured),
                "wire_in_bytes": c.wire_in_bytes,
                "wire_out_bytes": c.wire_out_bytes,
                "busy_rejections": c.busy_rejections,
                "detaches": c.detaches,
            }

    def _guard(idx: int) -> None:
        try:
            _one(idx)
        except BaseException as e:  # noqa: BLE001 — re-raised on the caller
            errors.append((idx, e))
            barrier.abort()          # release any replica waiting on us

    try:
        threads = [threading.Thread(target=_guard, args=(i,), daemon=True)
                   for i in range(clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        handle.kill()
    if errors:
        raise RuntimeError(f"pool client {errors[0][0]} failed") \
            from errors[0][1]
    stats = handle.stats()

    # measured == modeled, per client, both wire directions
    params_t = jax.device_get(mlp_init(jax.random.PRNGKey(0)))
    ascent_t = jax.device_get(batches[0]["ascent"])
    rng_t = np.asarray(jax.device_get(jax.random.PRNGKey(1)))
    comp = Compressor(kind=compressor, topk_fraction=mcfg.topk_fraction)
    modeled = protocol.grad_frame_bytes(comp, params_t, pool=True)
    delta_active = job_delta and job_compress != "none"
    job_modeled = {"snapshot": protocol.job_frame_bytes(
        job_compress, params_t, ascent_t, rng_t, delta=False)}
    if delta_active:
        job_modeled[job_compress] = protocol.job_frame_bytes(
            job_compress, params_t, ascent_t, rng_t, delta=True,
            topk_fraction=mcfg.topk_fraction)
    for r in results:
        assert r["exchanges"] > 0, r
        assert r["grad_frame_measured"] == modeled, (r, modeled)
        for kind, measured_job in r["job_frame_measured"].items():
            assert measured_job == job_modeled[kind], \
                (r["client_id"], kind, measured_job, job_modeled)
    out = {
        "clients": clients,
        "per_client": results,
        "val_acc": float(np.mean([r["val_acc"] for r in results])),
        "exchanges": sum(r["exchanges"] for r in results),
        "grad_frame_measured": results[0]["grad_frame_measured"],
        "grad_frame_modeled": modeled,
        "job_frame_measured": dict(results[0]["job_frame_measured"]),
        "job_frame_modeled": job_modeled,
        "fleet_wire_out_bytes": sum(r["wire_out_bytes"] for r in results),
        "fleet_wire_in_bytes": sum(r["wire_in_bytes"] for r in results),
        "pool_stats": stats,
    }
    if stats:
        # scheduler-side cross-check: every client attached, and the pool
        # served at least as many exchanges as any single client saw
        assert stats["connections"] >= clients, (stats, clients)
        assert stats["exchanges"] >= max(r["exchanges"] for r in results), \
            stats
    if verbose:
        for r in results:
            print(f"table_4_2_pool,client={r['client_id']},"
                  f"exchanges={r['exchanges']},acc={r['val_acc']:.4f},"
                  f"job_bytes={r['wire_out_bytes']},"
                  f"grad_bytes={r['wire_in_bytes']},"
                  f"busy={r['busy_rejections']},detaches={r['detaches']}")
        print(f"table_4_2_pool,fleet,clients={clients},"
              f"exchanges={out['exchanges']},"
              f"job_bytes={out['fleet_wire_out_bytes']},"
              f"grad_bytes={out['fleet_wire_in_bytes']}")
        print("table_4_2_pool,claim_wire_model_exact_per_client,PASS")
        if stats:
            print(f"table_4_2_pool,server_stats,{json.dumps(stats)}")
    return out


def run_wire_budget(steps: int = 40, batch: int = 128, clients: int = 1,
                    verbose: bool = True) -> dict:
    """JOB-direction wire budget: measured sweep + modeled olmo-1b table.

    Three short loopback runs (one per JOB encoding) assert measured ==
    modeled `job_frame_bytes` on the live wire; the olmo-1b budget is then
    modeled from abstract params (`jax.eval_shape`) at full scale — the
    numbers in the README wire-budget table and
    `artifacts/perf/olmo-1b_remote_wire.json`. Asserts the acceptance
    claim: int8 delta cuts the JOB-direction (params) bytes >= 4x vs the
    fp32 snapshot, both measured (MLP loopback) and modeled (olmo-1b).
    """
    measured = {}
    for enc in ("none", "int8", "topk"):
        r = run_remote(steps=steps, batch=batch, compressor="int8",
                       job_compress=enc, job_delta=(enc != "none"),
                       clients=clients, verbose=False)
        measured[enc] = {
            "job_frame_measured": r["job_frame_measured"],
            "job_frame_modeled": r["job_frame_modeled"],
            "grad_frame_measured": r["grad_frame_measured"],
        }
        if verbose:
            print(f"table_4_2_wire,{enc},measured={r['job_frame_measured']},"
                  f"modeled={r['job_frame_modeled']}")

    # params-direction ratio on the loopback MLP: the breakdown's params
    # term is batch-independent, and run_remote already asserted measured ==
    # modeled frame-for-frame, so this ratio is pinned to the live wire
    params_t = jax.device_get(mlp_init(jax.random.PRNGKey(0)))
    ascent_t = jax.device_get(slice_ascent_batch(
        next(iter(TASK.train_batches(batch, 1))), 0.5))
    rng_t = np.asarray(jax.device_get(jax.random.PRNGKey(1)))
    m_snap = protocol.job_frame_breakdown(
        "none", params_t, ascent_t, rng_t, delta=False)["params"]
    m_i8 = protocol.job_frame_breakdown(
        "int8", params_t, ascent_t, rng_t, delta=True)["params"]
    measured_ratio = m_snap / m_i8
    assert measured_ratio >= 4.0, (m_snap, m_i8)

    # modeled olmo-1b budget from abstract shapes (no weights materialized)
    from repro.configs import get_config
    from repro.models import batch_spec, build_model
    from repro.models.config import SHAPES
    cfg = get_config("olmo-1b")
    bundle = build_model(cfg)
    params_sds = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    ascent_sds = batch_spec(cfg, SHAPES["train_4k"],
                            ascent_fraction=0.25)["ascent"]
    rng_sds = jax.ShapeDtypeStruct((2,), np.uint32)
    olmo = {}
    for enc, delta in (("none", False), ("int8", True), ("topk", True)):
        olmo[enc] = protocol.job_frame_breakdown(
            enc, params_sds, ascent_sds, rng_sds, delta=delta,
            topk_fraction=0.01)
    modeled_ratio = olmo["none"]["params"] / olmo["int8"]["params"]
    assert modeled_ratio >= 4.0, olmo
    out = {
        "measured_mlp": measured,
        "measured_job_params_ratio_int8": measured_ratio,
        "olmo_1b_modeled": olmo,
        "olmo_1b_job_params_ratio_int8": modeled_ratio,
        "olmo_1b_job_frame_ratio_int8":
            olmo["none"]["frame"] / olmo["int8"]["frame"],
    }
    PERF_DIR.mkdir(parents=True, exist_ok=True)
    with open(PERF_DIR / "olmo-1b_remote_wire.json", "w") as f:
        json.dump(out, f, indent=1)
    if verbose:
        gb = 1 << 30
        print(f"table_4_2_wire,olmo-1b,snapshot="
              f"{olmo['none']['frame'] / gb:.3f}GiB,int8_delta="
              f"{olmo['int8']['frame'] / gb:.3f}GiB,topk_delta="
              f"{olmo['topk']['frame'] / gb:.4f}GiB")
        print(f"table_4_2_wire,claim_job_4x,"
              f"{'PASS' if min(measured_ratio, modeled_ratio) >= 4.0 else 'FAIL'},"
              f"measured={measured_ratio:.2f}x,modeled={modeled_ratio:.2f}x")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(
        description="Table 4.2: AsyncSAM hetero + remote/pool wire budget")
    ap.add_argument("--clients", type=int, default=1,
                    help="descent clients attached to one pool server "
                         "(>1 switches the remote runs to pool topology)")
    args = ap.parse_args()
    run()
    run_remote(clients=args.clients)
    run_wire_budget(clients=args.clients)
