"""Benchmark entrypoint: one harness per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV per the repo convention, where
us_per_call is the wall time of the harness and `derived` carries its
headline metric/claim verdict. Full detail rows (each harness's own CSV)
stream above the summary.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer steps/seeds (CI mode)")
    args = ap.parse_args()

    steps = 150 if args.quick else 400
    seeds = (0,) if args.quick else (0, 1, 2)

    from benchmarks import (fig1_cosine, fig3_throughput, fig4_time_vs_acc,
                            fig5_landscape, roofline, table_4_1_accuracy,
                            table_4_2_hetero)

    summary: list[str] = []

    def timed(name, fn, derived_fn):
        t0 = time.perf_counter()
        out = fn()
        us = (time.perf_counter() - t0) * 1e6
        summary.append(f"{name},{us:.0f},{derived_fn(out)}")
        return out

    timed("table_4_1_accuracy",
          lambda: table_4_1_accuracy.run(steps=steps, seeds=seeds),
          lambda r: f"async_sam_acc={r['async_sam'][0]:.4f};"
                    f"sgd_acc={r['sgd'][0]:.4f}")
    timed("table_4_2_hetero",
          lambda: table_4_2_hetero.run(steps=max(100, steps // 2)),
          lambda r: f"acc@5x={r[5][1]:.4f}")
    timed("fig1_cosine",
          lambda: fig1_cosine.run(steps=max(100, steps // 2)),
          lambda r: f"mean_cos={r['mean']:.3f}")
    timed("fig3_throughput",
          lambda: fig3_throughput.run(steps=max(100, steps // 2)),
          lambda r: f"async/sgd={r['async_sam'] / r['sgd']:.3f};"
                    f"sam/sgd={r['sam'] / r['sgd']:.3f}")
    timed("fig4_time_vs_acc",
          lambda: fig4_time_vs_acc.run(steps=steps),
          lambda r: f"async_final={r['async_sam'].val_acc:.4f}")
    timed("fig5_landscape",
          lambda: fig5_landscape.run(steps=steps),
          lambda r: f"adv_sharp_sgd={r['sgd'][1]:.3f};"
                    f"async={r['async_sam'][1]:.3f}")
    timed("roofline_table",
          lambda: roofline.build_table(),
          lambda rows: f"cells={sum(1 for r in rows if r['status'] == 'ok')}")

    print("\nname,us_per_call,derived")
    for line in summary:
        print(line)


if __name__ == "__main__":
    main()
