"""Measure one §Perf hillclimb variant: cell + overrides -> roofline terms.

Usage:
  python benchmarks/perf_cell.py '{"arch":"olmo-1b","shape_name":"train_4k",
      "variant":"dots","cfg_kw":{"remat":"dots"},"mcfg_kw":{"ascent_interval":4}}'

Writes artifacts/perf/<arch>_<shape>_<variant>.json and prints the three
roofline terms + MFU-bound (see EXPERIMENTS.md §Perf).
"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=512'
import dataclasses, json, sys
import pathlib
REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO)); sys.path.insert(0, str(REPO / "src"))
import pathlib

from benchmarks.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, model_flops)
from repro.configs import get_config
from repro.core import MethodConfig
from repro.launch import dryrun as D
from repro.models.config import SHAPES
from repro.optim.fused import epilogue_hbm_bytes

def measure(arch, shape_name, variant, cfg_kw=None, mcfg_kw=None):
    cfg = get_config(arch)
    if cfg_kw:
        cfg = dataclasses.replace(cfg, **cfg_kw)
    mkw = {"name": "async_sam", "n_microbatches": 4}
    mkw.update(mcfg_kw or {})
    mcfg = MethodConfig(**mkw)
    r = D.run_cell(arch, shape_name, method_cfg=mcfg, cfg_override=cfg,
                   save=False, verbose=False)
    shape = SHAPES[shape_name]
    ana = model_flops(cfg, shape, mcfg,
                      remat_extra=1.0 if cfg.remat == "full" else 0.0)
    chips = 256
    t_comp = ana["total"] / chips / PEAK_FLOPS
    mem_bytes = 2 * r.argument_bytes + 3 * r.peak_memory_per_device
    t_mem = mem_bytes / HBM_BW
    t_coll = r.collective_bytes / ICI_BW
    # modeled HBM traffic of the weight-space epilogue (perturb + adamw tail,
    # matching the dry-run's optimizer: adamw + clip, async carried norm),
    # per-leaf passes vs the fused flat-buffer path
    ep_kw = dict(family="adamw", clip=True, weight_decay=True,
                 carried_norm=(mcfg.name == "async_sam"))
    ep_unfused = epilogue_hbm_bytes(r.param_count, r.param_bytes,
                                    fused=False, **ep_kw)
    ep_fused = epilogue_hbm_bytes(r.param_count, r.param_bytes,
                                  fused=True, **ep_kw)
    out = {"arch": arch, "shape": shape_name, "variant": variant,
           "status": r.status, "note": r.note[:200],
           "t_compute_s": t_comp, "t_memory_s": t_mem, "t_coll_s": t_coll,
           "bound_s": max(t_comp, t_mem, t_coll),
           "mfu_bound": ana["model_flops_6nd"] / (chips * PEAK_FLOPS *
                                                  max(t_comp, t_mem, t_coll)),
           "collective_gb": r.collective_bytes / 1e9,
           "temp_gb": r.peak_memory_per_device / 1e9,
           "epilogue_hbm_bytes": {
               "unfused": ep_unfused, "fused": ep_fused,
               "reduction": ep_unfused / ep_fused if ep_fused else 0.0,
               "t_epilogue_unfused_s": ep_unfused / chips / HBM_BW,
               "t_epilogue_fused_s": ep_fused / chips / HBM_BW},
           "inventory": r.inventory}
    d = REPO / "artifacts" / "perf"; d.mkdir(parents=True, exist_ok=True)
    (d / f"{arch}_{shape_name}_{variant}.json").write_text(json.dumps(out, indent=1))
    ep = out["epilogue_hbm_bytes"]
    print(f"{variant:28s} {r.status:4s} comp={t_comp:.3f}s mem={t_mem:.3f}s "
          f"coll={t_coll:.3f}s bound={out['bound_s']:.3f}s "
          f"mfu={out['mfu_bound']:.3f} tempGB={out['temp_gb']:.1f} "
          f"collGB={out['collective_gb']:.1f} "
          f"epilogue={ep['unfused'] / 1e9:.1f}GB->{ep['fused'] / 1e9:.1f}GB "
          f"({ep['reduction']:.2f}x)", flush=True)
    return out

if __name__ == "__main__":
    import importlib
    spec = json.loads(sys.argv[1])
    measure(**spec)
