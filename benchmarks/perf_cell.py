"""Measure one §Perf hillclimb variant: cell + overrides -> roofline terms.

Usage:
  python benchmarks/perf_cell.py '{"arch":"olmo-1b","shape_name":"train_4k",
      "variant":"dots","cfg_kw":{"remat":"dots"},"mcfg_kw":{"ascent_interval":4}}'

Writes artifacts/perf/<arch>_<shape>_<variant>.json and prints the three
roofline terms + MFU-bound (see EXPERIMENTS.md §Perf).

Besides the MODELED epilogue HBM bytes (optim.fused.epilogue_hbm_bytes, both
residency regimes), the artifact now carries REALIZED per-step epilogue
traffic: the fused train step is traced twice — once over plain pytree state,
once over bucket-resident state — under `buckets.track_copies()`, which
counts every tree->bucket gather and bucket->tree scatter that the trace
bakes into the program. realized = kernel-streamed bytes + counted conversion
bytes; with resident buckets the count must be zero, i.e. realized within
10% of the modeled fused number (asserted), where the per-call regime sits
at ~1x of the per-leaf path.
"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=512'
import dataclasses, json, sys
import pathlib
REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO)); sys.path.insert(0, str(REPO / "src"))
import pathlib

import jax

from benchmarks.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, model_flops)
from repro.configs import get_config
from repro.core import MethodConfig
from repro.launch import dryrun as D
from repro.models import batch_spec, build_model
from repro.models.config import SHAPES
from repro.optim import make_optimizer
from repro.optim.fused import epilogue_hbm_bytes
from repro.utils import buckets


def realized_epilogue_bytes(cfg, shape, mcfg, modeled_kernel_bytes):
    """Trace-count the fused step's gather/scatter copies, both regimes.

    The unsharded fused step (the regime the fused path targets) is traced
    abstractly — `jax.eval_shape` executes the bucket conversions at trace
    time, so `buckets.track_copies` tallies exactly the copies the compiled
    program would perform, without touching a device.

    Residency follows the executor's own eligibility gating (resident=None):
    a variant whose MethodConfig is not resident-safe (compressed exchange,
    a non-weight-space method) only gets the per-call regime, and the
    resident-realized assert is skipped for it — perf_cell measures what the
    production executor would actually run.
    """
    from repro.engine import FusedExecutor
    bundle = build_model(cfg)
    batch_sds = batch_spec(cfg, shape, ascent_fraction=mcfg.ascent_fraction)
    out = {}
    for resident in (False, None):
        ex = FusedExecutor(bundle.loss_fn, mcfg,
                           make_optimizer("adamw", 1e-3, clip_norm=1.0),
                           fused_update=True, resident=resident)
        if resident is None and not ex.resident:
            ex.close()
            out["resident"] = None      # cell not resident-eligible
            continue
        state_sds = ex.abstract_state(
            lambda: bundle.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))
        with buckets.track_copies() as stats:
            jax.eval_shape(ex._step_raw, state_sds, batch_sds)
        ex.close()
        out["resident" if resident is None else "per_call"] = {
            "gathers": stats.gathers, "scatters": stats.scatters,
            "conversion_bytes": stats.total_bytes,
            "realized_bytes": modeled_kernel_bytes + stats.total_bytes,
        }
    return out


def measure(arch, shape_name, variant, cfg_kw=None, mcfg_kw=None):
    cfg = get_config(arch)
    if cfg_kw:
        cfg = dataclasses.replace(cfg, **cfg_kw)
    mkw = {"name": "async_sam", "n_microbatches": 4}
    mkw.update(mcfg_kw or {})
    mcfg = MethodConfig(**mkw)
    r = D.run_cell(arch, shape_name, method_cfg=mcfg, cfg_override=cfg,
                   save=False, verbose=False)
    shape = SHAPES[shape_name]
    ana = model_flops(cfg, shape, mcfg,
                      remat_extra=1.0 if cfg.remat == "full" else 0.0)
    chips = 256
    t_comp = ana["total"] / chips / PEAK_FLOPS
    mem_bytes = 2 * r.argument_bytes + 3 * r.peak_memory_per_device
    t_mem = mem_bytes / HBM_BW
    t_coll = r.collective_bytes / ICI_BW
    # modeled HBM traffic of the weight-space epilogue (perturb + adamw tail,
    # matching the dry-run's optimizer: adamw + clip, async carried norm),
    # per-leaf passes vs the fused flat-buffer path
    ep_kw = dict(family="adamw", clip=True, weight_decay=True,
                 carried_norm=(mcfg.name == "async_sam"))
    ep_unfused = epilogue_hbm_bytes(r.param_count, r.param_bytes,
                                    fused=False, **ep_kw)
    ep_fused = epilogue_hbm_bytes(r.param_count, r.param_bytes,
                                  fused=True, resident=True, **ep_kw)
    ep_fused_per_call = epilogue_hbm_bytes(r.param_count, r.param_bytes,
                                           fused=True, resident=False, **ep_kw)
    realized = realized_epilogue_bytes(cfg, shape, mcfg, ep_fused)
    res, per_call = realized["resident"], realized["per_call"]
    # the whole point of bucket residency: realized == modeled, not a ceiling
    if res is not None:
        assert res["realized_bytes"] <= 1.1 * ep_fused, \
            (res, ep_fused, "resident realized traffic exceeds modeled +10%")
    out = {"arch": arch, "shape": shape_name, "variant": variant,
           "status": r.status, "note": r.note[:200],
           "t_compute_s": t_comp, "t_memory_s": t_mem, "t_coll_s": t_coll,
           "bound_s": max(t_comp, t_mem, t_coll),
           "mfu_bound": ana["model_flops_6nd"] / (chips * PEAK_FLOPS *
                                                  max(t_comp, t_mem, t_coll)),
           "collective_gb": r.collective_bytes / 1e9,
           "temp_gb": r.peak_memory_per_device / 1e9,
           "epilogue_hbm_bytes": {
               "unfused": ep_unfused, "fused": ep_fused,
               "fused_per_call_modeled": ep_fused_per_call,
               "reduction": ep_unfused / ep_fused if ep_fused else 0.0,
               "reduction_per_call_modeled": (ep_unfused / ep_fused_per_call
                                              if ep_fused_per_call else 0.0),
               "t_epilogue_unfused_s": ep_unfused / chips / HBM_BW,
               "t_epilogue_fused_s": ep_fused / chips / HBM_BW},
           "epilogue_realized_bytes": {
               **realized,
               "reduction_realized_resident": (
                   ep_unfused / res["realized_bytes"]
                   if res is not None else None),
               "reduction_realized_per_call": (
                   ep_unfused / per_call["realized_bytes"]),
           },
           "inventory": r.inventory}
    d = REPO / "artifacts" / "perf"; d.mkdir(parents=True, exist_ok=True)
    (d / f"{arch}_{shape_name}_{variant}.json").write_text(json.dumps(out, indent=1))
    ep = out["epilogue_hbm_bytes"]
    er = out["epilogue_realized_bytes"]
    print(f"{variant:28s} {r.status:4s} comp={t_comp:.3f}s mem={t_mem:.3f}s "
          f"coll={t_coll:.3f}s bound={out['bound_s']:.3f}s "
          f"mfu={out['mfu_bound']:.3f} tempGB={out['temp_gb']:.1f} "
          f"collGB={out['collective_gb']:.1f} "
          f"epilogue={ep['unfused'] / 1e9:.1f}GB->{ep['fused'] / 1e9:.1f}GB "
          f"({ep['reduction']:.2f}x)", flush=True)
    res_txt = ("not resident-eligible" if res is None else
               f"{res['realized_bytes'] / 1e9:.1f}GB "
               f"({er['reduction_realized_resident']:.2f}x, "
               f"{res['gathers']}g/{res['scatters']}s)")
    print(f"{'':28s} realized: per-call "
          f"{per_call['realized_bytes'] / 1e9:.1f}GB "
          f"({er['reduction_realized_per_call']:.2f}x of per-leaf, "
          f"{per_call['gathers']}g/{per_call['scatters']}s) -> resident "
          f"{res_txt}", flush=True)
    return out

if __name__ == "__main__":
    import importlib
    spec = json.loads(sys.argv[1])
    measure(**spec)
