"""Paper Figure 3 — training throughput (samples/sec) per optimizer.

Claims: SAM ~0.5x SGD; AsyncSAM(fused, b'=b/4) well above SAM; the
heterogeneous executor hides the ascent entirely (~SGD throughput) when the
helper keeps up. Prints `fig3,<method>,samples_per_s,relative_to_sgd`.

Each run also streams its per-step tau/step-time records to
artifacts/telemetry/fig3_<method>.jsonl (StalenessTelemetry), so the
degradation curves can be plotted against the throughput numbers.
"""
from __future__ import annotations

import pathlib

import numpy as np

from benchmarks.common import train_classifier

TELEMETRY_DIR = (pathlib.Path(__file__).resolve().parents[1]
                 / "artifacts" / "telemetry")

CASES = [("sgd", {}), ("sam", {}), ("gsam", {}), ("looksam", {}),
         ("esam", {}), ("aesam", {}), ("mesa", {}),
         ("async_sam", {"ascent_fraction": 0.25})]


def run(steps: int = 200, batch: int = 256, verbose: bool = True) -> dict:
    out = {}
    for name, extra in CASES:
        r = train_classifier(name, steps=steps, batch=batch,
                             ascent_fraction=extra.get("ascent_fraction", 0.5),
                             telemetry_jsonl=str(TELEMETRY_DIR
                                                 / f"fig3_{name}.jsonl"))
        med = float(np.median(r.step_times))
        out[name] = batch / med
    if verbose:
        base = out["sgd"]
        for name, v in out.items():
            print(f"fig3,{name},{v:.0f},{v / base:.3f}")
        print(f"fig3,claim_async_faster_than_sam,"
              f"{'PASS' if out['async_sam'] > out['sam'] * 1.15 else 'FAIL'}")
    return out


if __name__ == "__main__":
    run()
