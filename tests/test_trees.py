"""Property-based tests for the pytree substrate (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402
from hypothesis.extra import numpy as hnp  # noqa: E402

from repro.utils import trees

SHAPES = st.sampled_from([(3,), (2, 4), (5, 1, 2), ()])


def _tree(draw, shape):
    a = draw(hnp.arrays(np.float32, shape,
                        elements=st.floats(-100, 100, width=32)))
    b = draw(hnp.arrays(np.float32, shape,
                        elements=st.floats(-100, 100, width=32)))
    return {"x": jnp.asarray(a), "nested": {"y": jnp.asarray(b)}}


@st.composite
def tree_pairs(draw):
    shape = draw(SHAPES)
    return _tree(draw, shape), _tree(draw, shape)


@settings(max_examples=50, deadline=None)
@given(tree_pairs())
def test_axpy_matches_manual(pair):
    t1, t2 = pair
    out = trees.tree_axpy(2.5, t1, t2)
    np.testing.assert_allclose(out["x"], 2.5 * t1["x"] + t2["x"], rtol=1e-6)
    np.testing.assert_allclose(out["nested"]["y"],
                               2.5 * t1["nested"]["y"] + t2["nested"]["y"],
                               rtol=1e-6)


@settings(max_examples=50, deadline=None)
@given(tree_pairs())
def test_dot_is_bilinear_and_symmetric(pair):
    t1, t2 = pair
    d12 = float(trees.tree_dot(t1, t2))
    d21 = float(trees.tree_dot(t2, t1))
    assert d12 == pytest.approx(d21, rel=1e-5, abs=1e-4)
    d_scaled = float(trees.tree_dot(trees.tree_scale(t1, 3.0), t2))
    assert d_scaled == pytest.approx(3.0 * d12, rel=1e-4, abs=1e-3)


@settings(max_examples=50, deadline=None)
@given(tree_pairs())
def test_norm_sq_consistency(pair):
    t1, _ = pair
    assert float(trees.tree_sq_norm(t1)) == pytest.approx(
        float(trees.tree_dot(t1, t1)), rel=1e-5, abs=1e-4)
    assert float(trees.global_norm(t1)) == pytest.approx(
        float(np.sqrt(trees.tree_sq_norm(t1))), rel=1e-6, abs=1e-6)


@settings(max_examples=30, deadline=None)
@given(tree_pairs())
def test_flatten_roundtrip(pair):
    t1, _ = pair
    vec = trees.tree_flatten_to_vector(t1)
    back = trees.tree_unflatten_from_vector(vec, t1)
    assert jax.tree.all(jax.tree.map(
        lambda a, b: jnp.array_equal(a, b), t1, back))


def test_cosine_similarity_bounds_and_identity():
    key = jax.random.PRNGKey(0)
    t = {"a": jax.random.normal(key, (32,)), "b": jax.random.normal(key, (4, 4))}
    assert float(trees.tree_cosine_similarity(t, t)) == pytest.approx(1.0, abs=1e-5)
    neg = trees.tree_scale(t, -1.0)
    assert float(trees.tree_cosine_similarity(t, neg)) == pytest.approx(-1.0, abs=1e-5)


def test_paths_align_with_leaves():
    t = {"w": jnp.zeros(2), "blocks": {"attn": {"wq": jnp.zeros((2, 2))}}}
    paths = trees.tree_paths(t)
    assert "blocks/attn/wq" in paths and "w" in paths
    assert len(paths) == len(jax.tree.leaves(t))
