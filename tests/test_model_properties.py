"""Deeper model-layer properties: rope, MLA absorption, MoE dispatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.configs import get_config
from repro.models import build_model, synth_batch
from repro.models.layers import apply_rope
from repro.models.moe import _capacity, moe_apply, moe_init

KEY = jax.random.PRNGKey(0)


# --- rotary embeddings ------------------------------------------------------

def test_rope_preserves_norm():
    x = jax.random.normal(KEY, (2, 8, 4, 32))
    pos = jnp.arange(8)[None, :]
    y = apply_rope(x, pos, theta=10000.0)
    np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1),
                               jnp.linalg.norm(y, axis=-1), rtol=1e-5)


def test_rope_relative_position_property():
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    q = jax.random.normal(KEY, (1, 1, 1, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, 32))

    def dot_at(i, j):
        qi = apply_rope(q, jnp.asarray([[i]]), 10000.0)
        kj = apply_rope(k, jnp.asarray([[j]]), 10000.0)
        return float(jnp.sum(qi * kj))

    assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), rel=1e-4)
    assert dot_at(5, 5) == pytest.approx(dot_at(0, 0), rel=1e-4)


# --- MLA: absorbed decode == decompressed attention --------------------------

def test_mla_absorbed_decode_matches_decompressed():
    """The latent-space decode scores must equal decompress-then-attend."""
    cfg = get_config("deepseek-v2-lite-16b", reduced=True)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    bundle = build_model(cfg)
    params = bundle.init(KEY)
    S = 10
    batch = synth_batch(cfg, 2, S, jax.random.fold_in(KEY, 2))
    full, _ = bundle.forward(params, batch)
    pre = {k: (v[:, :S - 1] if v.ndim >= 2 and v.shape[1] == S else v)
           for k, v in batch.items()}
    _, cache = bundle.prefill(params, pre, pad_to=S)
    logits, _ = bundle.decode(params, cache,
                              {"tokens": batch["tokens"][:, S - 1:S]})
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    assert float(jnp.max(jnp.abs(logits[:, 0] - full[:, S - 1]))) / scale < 3e-3


# --- MoE dispatch properties --------------------------------------------------

def _moe_cfg(capacity_factor=8.0):
    cfg = get_config("mixtral-8x7b", reduced=True)
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=capacity_factor))


def test_moe_capacity_helper_bounds():
    cfg = _moe_cfg()
    c = _capacity(cfg.moe, group_size=64)
    assert cfg.moe.top_k <= c <= 64


def test_moe_outputs_are_convex_combinations_when_no_drops():
    """With ample capacity every token is routed: output magnitude bounded by
    the max expert response (no token silently zeroed)."""
    cfg = _moe_cfg(capacity_factor=8.0)
    params = moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (2, 16, cfg.d_model),
                          jnp.float32)
    y, aux = moe_apply(params, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 0.0
    # with capacity slack, no token may map to exactly zero (dropped)
    norms = jnp.linalg.norm(y.reshape(-1, cfg.d_model), axis=-1)
    assert float(jnp.min(norms)) > 0.0


def test_moe_dropping_reduces_output_energy():
    """Tiny capacity drops tokens -> strictly less routed mass."""
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (2, 32, 64), jnp.float32)
    big = _moe_cfg(8.0)
    small = dataclasses.replace(
        big, moe=dataclasses.replace(big.moe, capacity_factor=0.25))
    params = moe_init(KEY, big)
    y_big, _ = moe_apply(params, x, big)
    y_small, _ = moe_apply(params, x, small)
    assert float(jnp.linalg.norm(y_small)) < float(jnp.linalg.norm(y_big))


def test_moe_aux_loss_balanced_router_is_minimal():
    """A uniform router gives aux ~ weight (the analytic minimum of E*f.p)."""
    cfg = _moe_cfg()
    params = moe_init(KEY, cfg)
    params = dict(params)
    params["router"] = jnp.zeros_like(params["router"])  # uniform probs
    x = jax.random.normal(KEY, (4, 32, cfg.d_model), jnp.float32)
    _, aux = moe_apply(params, x, cfg)
    assert float(aux) == pytest.approx(cfg.moe.router_aux_weight, rel=0.1)


# --- sliding-window + qk-norm interactions ------------------------------------

def test_qk_norm_bounds_attention_logits():
    cfg = get_config("qwen3-8b", reduced=True)
    bundle = build_model(cfg)
    params = bundle.init(KEY)
    batch = synth_batch(cfg, 2, 16, jax.random.fold_in(KEY, 5))
    # scale up embeddings 100x: qk-norm must keep logits finite and moderate
    params = jax.tree.map(lambda x: x * 100.0, params)
    logits, _ = bundle.forward(params, batch)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
