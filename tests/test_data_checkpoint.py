"""Data pipeline determinism/sharding + checkpoint manager behavior."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import MmapTokenDataset, PipelineConfig, TokenPipeline
from repro.data.synthetic import ClassificationTask, TokenTask


def _pipe(**kw):
    cfg = get_config("olmo-1b", reduced=True)
    defaults = dict(global_batch=4, seq_len=16, ascent_fraction=0.5, prefetch=0)
    defaults.update(kw)
    return TokenPipeline(cfg, PipelineConfig(**defaults))


def test_pipeline_deterministic_across_instances():
    a = [next(iter(_pipe())) for _ in range(1)][0]
    b = [next(iter(_pipe())) for _ in range(1)][0]
    assert jnp.array_equal(a["tokens"], b["tokens"])
    assert jnp.array_equal(a["ascent"]["tokens"], b["ascent"]["tokens"])


def test_pipeline_restart_resumes_same_stream():
    p1 = _pipe()
    it = iter(p1)
    batches = [next(it) for _ in range(5)]
    cursor = p1.state()

    p2 = _pipe()
    p2.restore(cursor)
    nxt = next(iter(p2))
    ref = _collect_step(_pipe(), 5)
    assert jnp.array_equal(nxt["tokens"], ref["tokens"])


def _collect_step(pipe, n):
    it = iter(pipe)
    for _ in range(n):
        b = next(it)
    return next(it)


def test_pipeline_ranks_draw_disjoint_streams():
    b0 = next(iter(_pipe(rank=0, world=2)))
    b1 = next(iter(_pipe(rank=1, world=2)))
    assert not jnp.array_equal(b0["tokens"], b1["tokens"])


def test_ascent_subbatch_differs_from_descent():
    b = next(iter(_pipe()))
    assert b["ascent"]["tokens"].shape[0] == 2    # 50% of 4
    assert not jnp.array_equal(b["ascent"]["tokens"], b["tokens"][:2])


def test_markov_stream_is_learnable_structure():
    """Token bigram distribution must be far from uniform (learnable)."""
    task = TokenTask(vocab_size=64, seed=0)
    toks = task.sample(8, 256, stream=0)
    counts = np.bincount(toks.reshape(-1), minlength=64)
    freq = counts / counts.sum()
    assert freq.max() > 2.5 / 64  # clearly peaked vs uniform


def test_mmap_dataset_roundtrip(tmp_path):
    tokens = np.arange(10_000, dtype=np.int32) % 97
    path = tmp_path / "toks.bin"
    MmapTokenDataset.write(path, tokens, vocab_size=97)
    ds = MmapTokenDataset(path, seed=3)
    b = ds.batch(4, 32, stream=5)
    assert b["tokens"].shape == (4, 32)
    # labels are next-token shifted views of the same buffer
    assert jnp.array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    b2 = MmapTokenDataset(path, seed=3).batch(4, 32, stream=5)
    assert jnp.array_equal(b["tokens"], b2["tokens"])


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------

def _state(seed=0):
    key = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(key, (8, 4)),
                       "b": jnp.zeros(4)},
            "opt": {"mu": jnp.ones((8, 4))},
            "step": jnp.asarray(7)}


def test_checkpoint_roundtrip_exact(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    st = _state()
    mgr.save(7, st, extras={"pipeline": {"step": 7, "seed": 0}})
    restored, extras = mgr.restore(jax.eval_shape(lambda: st))
    assert jax.tree.all(jax.tree.map(jnp.array_equal, st, restored))
    assert extras["pipeline"]["step"] == 7


def test_checkpoint_keep_k_garbage_collects(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(s))
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    st = _state()
    mgr.save(1, st, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1
    restored, _ = mgr.restore(jax.eval_shape(lambda: st))
    assert jax.tree.all(jax.tree.map(jnp.array_equal, st, restored))


def test_checkpoint_restores_latest_of_many(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5)
    for s in (10, 20):
        mgr.save(s, _state(s))
    restored, _ = mgr.restore(jax.eval_shape(_state))
    assert jax.tree.all(jax.tree.map(jnp.array_equal, _state(20), restored))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _state())
    bad = jax.eval_shape(lambda: {"params": {"w": jnp.zeros((9, 4)),
                                             "b": jnp.zeros(4)},
                                  "opt": {"mu": jnp.zeros((8, 4))},
                                  "step": jnp.asarray(0)})
    with pytest.raises(AssertionError):
        mgr.restore(bad)
