"""Optimizer substrate + end-to-end behaviours of the public API."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import MethodConfig, available_methods, make_method


def test_registry_covers_paper_methods():
    assert set(available_methods()) == {
        "sgd", "sam", "gsam", "async_sam", "looksam", "esam", "aesam", "mesa"}


def test_unknown_method_raises():
    with pytest.raises(ValueError, match="unknown method"):
        make_method(MethodConfig(name="zen-sam"))


def test_sgd_momentum_matches_manual():
    opt = optim.sgd(0.1, momentum=0.9)
    params = {"w": jnp.asarray([1.0, 2.0])}
    state = opt.init(params)
    g = {"w": jnp.asarray([0.5, -1.0])}
    u1, state = opt.update(g, state, params)
    np.testing.assert_allclose(u1["w"], -0.1 * jnp.asarray([0.5, -1.0]))
    u2, state = opt.update(g, state, params)
    # momentum: m2 = 0.9*g + g = 1.9g
    np.testing.assert_allclose(u2["w"], -0.1 * 1.9 * jnp.asarray([0.5, -1.0]),
                               rtol=1e-6)


def test_adamw_first_step_is_lr_signed():
    opt = optim.adamw(1e-2, weight_decay=0.0)
    params = {"w": jnp.asarray([1.0, -1.0])}
    state = opt.init(params)
    g = {"w": jnp.asarray([3.0, -2.0])}
    u, _ = opt.update(g, state, params)
    # bias-corrected first Adam step is -lr * sign(g) (up to eps)
    np.testing.assert_allclose(u["w"], [-1e-2, 1e-2], rtol=1e-4)


def test_clip_by_global_norm():
    opt = optim.chain(optim.clip_by_global_norm(1.0),
                      optim.scale_by_learning_rate(1.0))
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    g = {"w": jnp.full(4, 10.0)}
    u, _ = opt.update(g, state, params)
    assert float(jnp.linalg.norm(u["w"])) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    sched = optim.cosine_schedule(1.0, total_steps=100, warmup_steps=10)
    assert float(sched(0)) == 0.0
    assert float(sched(10)) == pytest.approx(1.0, rel=1e-5)
    assert float(sched(100)) == pytest.approx(0.0, abs=1e-6)
    assert float(sched(55)) < float(sched(20))


def test_step_decay_schedule():
    sched = optim.step_decay_schedule(0.1, [50, 80], factor=0.1)
    assert float(sched(10)) == pytest.approx(0.1)
    assert float(sched(60)) == pytest.approx(0.01)
    assert float(sched(90)) == pytest.approx(0.001, rel=1e-5)


def test_weight_decay_mask():
    opt = optim.chain(
        optim.add_decayed_weights(0.1, mask_fn=lambda p: "scale" not in p),
        optim.scale_by_learning_rate(1.0))
    params = {"w": jnp.ones(2), "ln": {"scale": jnp.ones(2)}}
    state = opt.init(params)
    g = {"w": jnp.zeros(2), "ln": {"scale": jnp.zeros(2)}}
    u, _ = opt.update(g, state, params)
    np.testing.assert_allclose(u["w"], -0.1 * jnp.ones(2))
    np.testing.assert_allclose(u["ln"]["scale"], jnp.zeros(2))


def test_train_launcher_end_to_end(tmp_path):
    """The CLI launcher trains a reduced arch and checkpoints (deliverable b)."""
    import subprocess, sys, os, pathlib
    repo = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ, PYTHONPATH=str(repo / "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "olmo-1b",
         "--reduced", "--method", "async_sam", "--steps", "12", "--batch", "4",
         "--seq", "32", "--save-every", "6",
         "--ckpt-dir", str(tmp_path / "run")],
        capture_output=True, text=True, timeout=560, env=env)
    assert proc.returncode == 0, proc.stderr
    assert "done: 12 steps" in proc.stdout
    assert (tmp_path / "run").exists()


def test_serve_launcher_end_to_end():
    import subprocess, sys, os, pathlib
    repo = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ, PYTHONPATH=str(repo / "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "olmo-1b",
         "--reduced", "--requests", "4", "--prompt-len", "16", "--max-new", "8"],
        capture_output=True, text=True, timeout=560, env=env)
    assert proc.returncode == 0, proc.stderr
    assert "decode" in proc.stdout
