"""Wire-level chaos harness + the health-driven degradation ladder.

`service.netchaos.ChaosProxy` sits between `RemoteAscentClient` and a real
ascent server and attacks the connection frame by frame (corrupt, truncate,
drop, delay, stall, blackhole, duplicate) under a deterministic
`FaultSchedule`; `runtime.health` turns the resulting exchange outcomes
into an explicit failover ladder (remote -> in-process thread -> ledger) and
a STATS-scraping server watchdog. This file pins:

* the schedule/proxy mechanics themselves (deterministic firing, grammar),
* LaneHealth / LaneLadder / ServerWatchdog in isolation (fake clocks/scrapes),
* the acceptance soak: a remote fit through a hostile schedule covering
  every fault kind completes with finite losses and >=1 ladder downgrade
  plus >=1 recovery in the obs registry keys,
* transient-only faults under lockstep being bitwise invisible
  (the `retry_inflight` path),
* reconnect-storm bounds (jittered backoff, fatal auth errors don't retry),
* checkpoint integrity: corrupt-checkpoint fallback to a verified older
  step, and async-save errors surfacing instead of vanishing.

Every blocking wait has an explicit deadline; `scripts/tier1.sh --netchaos`
adds a process-level timeout on top.
"""
import socket
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import CheckpointManager
from repro.checkpoint.manager import CheckpointIntegrityError
from repro.core import MethodConfig, slice_ascent_batch
from repro.core.ascent import Compressor
from repro.data.synthetic import ClassificationTask
from repro.engine import Engine, RemoteExecutor
from repro.runtime import (ExecutorConfig, LaneHealth, LaneLadder,
                           ResilienceConfig, RestartBudget, ServerWatchdog,
                           run_resilient)
from repro.service import protocol
from repro.service.ascent_server import AscentServer
from repro.service.client import RemoteAscentClient
from repro.service.netchaos import (ChaosProxy, FaultRule, FaultSchedule,
                                    parse_faults)
from repro.service.protocol import FrameType
from repro.service.testing import mlp_init, mlp_loss

TASK = ClassificationTask(n_classes=4, dim=8, seed=3)
BATCH = 64
WIDTHS = (8, 32, 4)


def _params(seed=0):
    return mlp_init(jax.random.PRNGKey(seed), WIDTHS)


def _batches(n, frac=0.5):
    return [{**b, "ascent": slice_ascent_batch(b, frac)}
            for b in TASK.train_batches(BATCH, n)]


def _mcfg():
    return MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5)


# ---------------------------------------------------------------------------
# FaultSchedule / spec grammar
# ---------------------------------------------------------------------------

def test_fault_rules_fire_deterministically():
    sched = FaultSchedule([FaultRule("corrupt", frame="GRAD", nth=2),
                           FaultRule("drop", frame="GRAD", every=3, count=1),
                           FaultRule("delay", frame="JOB_DELTA")])
    fired = [sched.fire("s2c", "GRAD") for _ in range(7)]
    # first firing rule wins AND consumes the frame: nth=2 takes frame 2,
    # so the every=3 rule only counts frames 1,3,4,... and fires on frame 4;
    # count=1 then caps it (frame 7 would otherwise be its 6th match)
    assert [f.action if f else None for f in fired] == \
        [None, "corrupt", None, "drop", None, None, None]
    # unrestricted rule fires on every matching frame, wrong frames never
    assert sched.fire("c2s", "JOB_DELTA").action == "delay"
    assert sched.fire("c2s", "HELLO") is None


def test_fault_schedule_prob_is_seeded_deterministic():
    def run(seed):
        sched = FaultSchedule([FaultRule("delay", prob=0.5)], seed=seed)
        return [sched.fire("s2c", "GRAD") is not None for _ in range(32)]
    assert run(7) == run(7)
    assert run(7) != run(8)


def test_parse_faults_grammar():
    sched = parse_faults(
        "corrupt:GRAD:nth=2,delay:*:prob=0.25:delay_s=0.1,"
        "blackhole:GRAD:nth=4:duration_s=0.5,drop:HELLO:direction=c2s")
    actions = [(r.action, r.frame) for r in sched.rules]
    assert actions == [("corrupt", "GRAD"), ("delay", "*"),
                       ("blackhole", "GRAD"), ("drop", "HELLO")]
    assert sched.rules[0].nth == 2
    assert sched.rules[1].prob == 0.25 and sched.rules[1].delay_s == 0.1
    assert sched.rules[2].duration_s == 0.5
    assert sched.rules[3].direction == "c2s"
    with pytest.raises(ValueError, match="unknown fault action"):
        parse_faults("explode:GRAD")
    with pytest.raises(ValueError, match="unknown fault option"):
        parse_faults("drop:GRAD:when=later")


# ---------------------------------------------------------------------------
# LaneHealth / LaneLadder (pure logic, fake clocks)
# ---------------------------------------------------------------------------

def test_lane_health_error_rate_window_and_reset():
    h = LaneHealth(window=4, error_threshold=0.5, min_samples=3)
    h.record(False)
    h.record(False)
    assert not h.unhealthy()            # below min_samples
    h.record(False)
    assert h.unhealthy()
    # the window forgets: three successes push the failures out
    for _ in range(4):
        h.record(True, rtt_s=0.01)
    assert not h.unhealthy() and h.error_rate() == 0.0
    assert h.mean_rtt_s() == pytest.approx(0.01)
    h.record(False)
    h.reset()
    assert h.error_rate() == 0.0 and not h.stalled()


def test_lane_health_stall_detection():
    now = [0.0]
    h = LaneHealth(stall_timeout_s=5.0, clock=lambda: now[0])
    assert not h.stalled()              # nothing outstanding
    h.note_submit()
    now[0] = 4.0
    assert not h.stalled()
    now[0] = 5.5
    assert h.stalled()                  # silence past the timeout
    h.record(True)                      # the answer arrived after all
    assert not h.stalled()


def test_ladder_demotes_promotes_with_cooldown():
    lad = LaneLadder(probation_steps=2, cooldown_steps=3)
    assert lad.level == 0 and not lad.can_promote()
    assert lad.demote()
    assert (lad.level, lad.failovers) == (1, 1)
    for _ in range(2):
        lad.tick()
        assert not lad.can_promote()    # cooldown still running
    lad.tick()
    assert lad.can_promote()
    assert lad.promote()
    assert (lad.level, lad.recoveries) == (0, 1)
    assert not lad.promote()            # already at the top


def test_ladder_probation_doubles_cooldown_no_flapping():
    lad = LaneLadder(probation_steps=4, cooldown_steps=2)
    lad.demote()
    for _ in range(2):
        lad.tick()
    lad.promote()
    assert lad.in_probation
    lad.demote()                        # failed during probation
    # hysteresis: the next cooldown is doubled (2 -> 4)
    for _ in range(3):
        lad.tick()
        assert not lad.can_promote()
    lad.tick()
    assert lad.can_promote()
    # surviving a full probation restores the base cooldown
    lad.promote()
    for _ in range(4):
        lad.tick()
    assert not lad.in_probation
    lad.demote()
    lad.tick()
    lad.tick()
    assert lad.can_promote()


def test_ladder_bottoms_out_at_last_level():
    lad = LaneLadder(n_levels=3, cooldown_steps=1)
    assert lad.demote() and lad.demote()
    assert lad.level == 2
    assert not lad.demote()             # nowhere further down
    assert lad.failovers == 2


# ---------------------------------------------------------------------------
# ServerWatchdog (fake scrapes; `check()` driven directly)
# ---------------------------------------------------------------------------

def test_watchdog_dead_server_restarts_under_budget():
    verdicts = []
    wd = ServerWatchdog(lambda: "nowhere:1", verdicts.append,
                        RestartBudget(2, what="server restart"),
                        stats_fn=lambda addr: (_ for _ in ()).throw(
                            ConnectionError("refused")))
    assert wd.check() == "dead"
    assert verdicts == ["dead"] and wd.restarts == 1


def test_watchdog_tells_wedged_from_merely_busy():
    feed = iter([
        {"exchanges": 5, "queue_depth": 2},   # baseline
        {"exchanges": 9, "queue_depth": 3},   # advancing: busy, healthy
        {"exchanges": 9, "queue_depth": 3},   # frozen 1
        {"exchanges": 9, "queue_depth": 3},   # frozen 2
        {"exchanges": 9, "queue_depth": 3},   # frozen 3 -> wedged
    ])
    verdicts = []
    wd = ServerWatchdog(lambda: "x", verdicts.append,
                        RestartBudget(4, what="server restart"),
                        wedge_scrapes=3, stats_fn=lambda addr: next(feed))
    assert [wd.check() for _ in range(4)] == ["ok", "ok", "ok", "ok"]
    assert wd.check() == "wedged"
    assert verdicts == ["wedged"] and wd.restarts == 1


def test_watchdog_idle_server_is_not_wedged():
    # frozen counters with an EMPTY queue = idle, never a wedge verdict
    wd = ServerWatchdog(lambda: "x", lambda v: None,
                        RestartBudget(4, what="server restart"),
                        wedge_scrapes=2,
                        stats_fn=lambda addr: {"exchanges": 7,
                                               "queue_depth": 0})
    assert [wd.check() for _ in range(6)] == ["ok"] * 6


def test_watchdog_budget_bounds_restarts():
    restarts = []
    wd = ServerWatchdog(lambda: "x", restarts.append,
                        RestartBudget(1, what="server restart"),
                        stats_fn=lambda addr: (_ for _ in ()).throw(
                            OSError("unreachable")))
    for _ in range(4):
        assert wd.check() == "dead"
    assert len(restarts) == 1           # past the budget: classified only


def test_watchdog_live_scrape_against_real_server():
    server = AscentServer(mlp_loss)
    server.serve_in_thread()
    try:
        wd = ServerWatchdog(lambda: server.address, lambda v: None,
                            RestartBudget(1, what="server restart"))
        assert wd.check() == "ok"
    finally:
        server.close()


# ---------------------------------------------------------------------------
# ChaosProxy mechanics
# ---------------------------------------------------------------------------

def test_proxy_passthrough_preserves_the_exchange():
    server = AscentServer(mlp_loss)
    server.serve_in_thread()
    proxy = ChaosProxy(server.address, FaultSchedule([]))
    client = RemoteAscentClient(proxy.addr, Compressor("none"))
    try:
        params = jax.device_get(_params())
        batch = jax.device_get(_batches(1)[0]["ascent"])
        assert client.submit(0, params, batch, jax.random.PRNGKey(5), 0)
        got = client.poll(block=True, timeout=120.0)
        assert got is not None and got[1] is not None
        assert proxy.connections == 1
        # both directions were pumped frame-aware (HELLO out, GRAD back)
        assert proxy.frames.get(("c2s", "HELLO")) == 1
        assert proxy.frames.get(("s2c", "GRAD")) == 1
    finally:
        client.close()
        proxy.close()
        server.close()


def test_proxy_corrupt_frame_is_lost_exchange_not_poison():
    """A corrupted GRAD fails the client's crc check: that one exchange is
    reported lost (grad=None sentinel), the client reconnects, and the next
    exchange succeeds."""
    server = AscentServer(mlp_loss)
    server.serve_in_thread()
    sched = FaultSchedule([FaultRule("corrupt", frame="GRAD", nth=1)])
    proxy = ChaosProxy(server.address, sched)
    client = RemoteAscentClient(proxy.addr, Compressor("none"),
                                reconnect_backoff_s=0.05)
    try:
        params = jax.device_get(_params())
        batch = jax.device_get(_batches(1)[0]["ascent"])
        assert client.submit(0, params, batch, jax.random.PRNGKey(5), 0)
        got = client.poll(block=True, timeout=120.0)
        assert got is not None and got[1] is None       # lost, not hung
        deadline = time.monotonic() + 60.0
        while not client.submit(0, params, batch, jax.random.PRNGKey(6), 1):
            assert time.monotonic() < deadline
            time.sleep(0.02)
        got = client.poll(block=True, timeout=120.0)
        assert got is not None and got[1] is not None   # recovered
        assert client.drops >= 1
        assert ("s2c", "GRAD", "corrupt") in proxy.faults
    finally:
        client.close()
        proxy.close()
        server.close()


# ---------------------------------------------------------------------------
# reconnect-storm bounds + fatal auth (satellite)
# ---------------------------------------------------------------------------

def test_reconnect_storm_is_bounded_by_jittered_backoff():
    """Every connection is dropped at HELLO: the client must retry on the
    jittered exponential backoff schedule, not busy-loop. The proxy's accept
    counter IS the attempt rate."""
    server = AscentServer(mlp_loss)
    server.serve_in_thread()
    sched = FaultSchedule([FaultRule("drop", frame="HELLO")])
    proxy = ChaosProxy(server.address, sched)
    client = RemoteAscentClient(proxy.addr, Compressor("none"),
                                reconnect_backoff_s=0.05,
                                reconnect_backoff_max_s=0.2)
    try:
        time.sleep(1.2)
        attempts = proxy.connections
    finally:
        client.close()
        proxy.close()
        server.close()
    # minimum jittered delays sum to ~1.1s over ~13 attempts at (0.05, 0.2);
    # a busy-loop would land hundreds of connections in the same window
    assert 2 <= attempts <= 20, attempts
    assert not client.connected.is_set()


def _auth_rejecting_server():
    """Minimal protocol speaker that refuses every HELLO as auth-rejected."""
    listener, addr = protocol.bind_listener("127.0.0.1:0", backlog=4)
    accepts = [0]
    stop = threading.Event()

    def loop():
        listener.settimeout(0.2)
        while not stop.is_set():
            try:
                sock, _ = listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            accepts[0] += 1
            try:
                protocol.recv_frame(sock, timeout=10.0)
                protocol.send_frame(sock, FrameType.ERROR,
                                    b"auth-rejected: bad token")
            except Exception:  # noqa: BLE001 — test double
                pass
            finally:
                try:
                    sock.close()
                except OSError:
                    pass

    thread = threading.Thread(target=loop, daemon=True)
    thread.start()

    def close():
        stop.set()
        listener.close()
        thread.join(timeout=10.0)

    return addr, accepts, close


def test_fatal_auth_rejection_never_reenters_backoff_loop():
    addr, accepts, close_server = _auth_rejecting_server()
    client = RemoteAscentClient(addr, Compressor("none"),
                                reconnect_backoff_s=0.02,
                                reconnect_backoff_max_s=0.05,
                                auth_token="wrong")
    try:
        deadline = time.monotonic() + 30.0
        while not client.fatal_error:
            assert time.monotonic() < deadline, "auth rejection not surfaced"
            time.sleep(0.01)
        # give a buggy retry loop many backoff periods to re-connect
        time.sleep(0.5)
        assert accepts[0] == 1, "fatal error re-entered the reconnect loop"
        client._thread.join(timeout=10.0)
        assert not client._thread.is_alive()
        with pytest.raises(RuntimeError, match="rejected"):
            client.submit(0, {}, {}, None, 0)
        with pytest.raises(RuntimeError, match="rejected"):
            client.poll()
    finally:
        client.close()
        close_server()


# ---------------------------------------------------------------------------
# degradation ladder through the executor
# ---------------------------------------------------------------------------

def test_ladder_fails_over_to_local_lane_when_remote_is_dead():
    """A remote lane that never answers (dead address) trips the stall
    detector; the executor fails over to the in-process thread lane and
    perturbed steps resume — no recovery, since the remote never comes up."""
    # a port that refuses connections: bind, then close
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    dead_addr = "127.0.0.1:%d" % probe.getsockname()[1]
    probe.close()
    # stall timeout must exceed the local lane's first-exchange jit compile,
    # or the ladder (correctly) demotes straight through to the ledger
    xcfg = ExecutorConfig(
        ascent_addr=dead_addr, connect_timeout_s=1.0,
        reconnect_backoff_s=0.1, max_staleness=3,
        lane_ladder=True, health_window=4, health_min_samples=2,
        health_stall_timeout_s=3.0, ladder_cooldown_steps=10_000)
    hist = []
    with RemoteExecutor(mlp_loss, _mcfg(), optim.sgd(0.1, momentum=0.9),
                        exec_cfg=xcfg) as ex:
        state = ex.init_state(_params(), jax.random.PRNGKey(1))
        # run until the ladder has demoted AND the local lane delivered a
        # perturbed step (first local exchange pays a jit compile, so a
        # fixed step count is a flake under load) — deadline-bounded
        deadline = time.monotonic() + 120.0
        for b in _batches(2000):
            state, m = ex.step(state, b)
            hist.append(m)
            if m["lane_state"] == 1.0 and m["perturbed"] == 1.0:
                break
            assert time.monotonic() < deadline, \
                "no failover + local perturbed step within deadline"
            time.sleep(0.02)
    assert ex._inner._ladder.failovers >= 1
    assert hist[0]["lane_state"] == 0.0
    assert hist[-1]["lane_state"] == 1.0 and hist[-1]["perturbed"] == 1.0
    assert any(m.get("lane_failovers", 0) >= 1 for m in hist)
    assert all(np.isfinite(float(m["loss"])) for m in hist)


def _paced(batches, pace_s):
    for b in batches:
        time.sleep(pace_s)
        yield b


def test_soak_hostile_schedule_completes_with_failover_and_recovery():
    """Acceptance soak: a remote fit through a schedule covering every fault
    kind completes with finite losses, >=1 ladder downgrade and >=1 recovery
    recorded in the registry keys, and shuts down cleanly."""
    server = AscentServer(mlp_loss)
    server.serve_in_thread()
    # hostile opening (first four GRADs all die), then sporadic transient
    # faults the recovered lane rides out
    sched = parse_faults(
        "corrupt:GRAD:nth=1,corrupt:GRAD:nth=2,truncate:GRAD:nth=3,"
        "blackhole:GRAD:nth=4:duration_s=0.2,duplicate:GRAD:nth=6,"
        "delay:GRAD:nth=7:delay_s=0.03,drop:JOB_DELTA:nth=9,"
        "stall:JOB_DELTA:nth=11:delay_s=0.03", seed=5)
    proxy = ChaosProxy(server.address, sched)
    xcfg = ExecutorConfig(
        ascent_addr=proxy.addr, reconnect_backoff_s=0.05,
        max_staleness=3, lane_ladder=True,
        health_window=4, health_error_threshold=0.5, health_min_samples=2,
        health_stall_timeout_s=5.0,
        ladder_cooldown_steps=5, ladder_probation_steps=3)
    try:
        with RemoteExecutor(mlp_loss, _mcfg(), optim.sgd(0.1, momentum=0.9),
                            exec_cfg=xcfg) as ex:
            state = ex.init_state(_params(), jax.random.PRNGKey(1))
            report = Engine(ex, _paced(_batches(90), 0.015)).fit(state, 90)
            ladder = ex._inner._ladder
        hist = report.metrics_history
        assert len(hist) == 90
        assert all(np.isfinite(m["loss"]) for m in hist)
        # the ladder went down AND came back up, and said so in the
        # registry keys (cumulative counters on the transition steps)
        assert ladder.failovers >= 1 and ladder.recoveries >= 1, \
            (ladder.failovers, ladder.recoveries, sched.fired_actions())
        assert max(m.get("lane_failovers", 0) for m in hist) >= 1
        assert max(m.get("lane_recoveries", 0) for m in hist) >= 1
        assert any(m["lane_state"] > 0 for m in hist)
        assert hist[-1]["lane_state"] == 0.0     # finished back on remote
        # the schedule actually attacked the wire, more ways than one
        assert proxy.fault_count() >= 4
        assert len(set(a for _, _, a in proxy.faults)) >= 3
    finally:
        proxy.close()
        server.close()
    # clean thread shutdown: nothing left alive from the executor
    leftovers = [t.name for t in threading.enumerate()
                 if not t.daemon and t is not threading.main_thread()]
    assert leftovers == [], leftovers


def test_transient_faults_are_bitwise_invisible_under_lockstep():
    """delay / stall / dropped-connection / corrupt faults are all transient
    when `retry_inflight` is on (lockstep): the interrupted exchange is
    resent as a snapshot of the encoder's shadow and recomputed on identical
    params, so the fit matches the undisturbed run bit for bit."""
    def run(spec):
        server = AscentServer(mlp_loss)
        server.serve_in_thread()
        proxy = ChaosProxy(server.address, parse_faults(spec))
        xcfg = ExecutorConfig(lockstep=True, ascent_addr=proxy.addr,
                              reconnect_backoff_s=0.05)
        losses = []
        try:
            with RemoteExecutor(mlp_loss, _mcfg(),
                                optim.sgd(0.1, momentum=0.9),
                                exec_cfg=xcfg) as ex:
                state = ex.init_state(_params(), jax.random.PRNGKey(1))
                for b in _batches(12):
                    state, m = ex.step(state, b)
                    losses.append(float(m["loss"]))
                retried = ex.client.retried_exchanges
            faults = proxy.fault_count()
        finally:
            proxy.close()
            server.close()
        return losses, retried, faults

    base, base_retried, base_faults = run("")
    spec = ("delay:GRAD:nth=2:delay_s=0.05,stall:GRAD:nth=4:delay_s=0.05,"
            "drop:GRAD:nth=6,corrupt:GRAD:nth=7,"
            "stall:JOB_DELTA:nth=3:delay_s=0.05,drop:JOB_DELTA:nth=9")
    hit, hit_retried, hit_faults = run(spec)
    assert base_faults == 0 and hit_faults >= 4
    assert base_retried == 0
    assert hit_retried >= 2        # the destructive faults went through retry
    assert np.array_equal(np.asarray(base), np.asarray(hit)), (base, hit)


# ---------------------------------------------------------------------------
# checkpoint integrity + async-save error surfacing (satellites)
# ---------------------------------------------------------------------------

def _ck_state(seed=0):
    key = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(key, (8, 4)),
                       "b": jnp.full((4,), float(seed))},
            "step": jnp.asarray(seed)}


def test_corrupt_checkpoint_restore_falls_back_to_verified_step(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5)
    for s in (1, 2, 3):
        mgr.save(s, _ck_state(s))
    # flip bytes inside the newest step's array data: same size, wrong bits
    victim = next((tmp_path / "step_00000003" / "arrays").glob("*w.npy"))
    raw = bytearray(victim.read_bytes())
    raw[-4] ^= 0xFF
    victim.write_bytes(bytes(raw))
    assert not mgr.verify_step(3)
    assert mgr.verify_step(2)
    restored, _ = mgr.restore(jax.eval_shape(_ck_state))
    assert jax.tree.all(jax.tree.map(jnp.array_equal, _ck_state(2), restored))


def test_truncated_checkpoint_is_skipped_and_uncounted(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5)
    for s in (1, 2):
        mgr.save(s, _ck_state(s))
    assert mgr.all_steps() == [1, 2]
    # truncate a leaf file (partial write / torn disk)
    victim = next((tmp_path / "step_00000002" / "arrays").glob("*.npy"))
    victim.write_bytes(victim.read_bytes()[:10])
    restored, _ = mgr.restore(jax.eval_shape(_ck_state))
    assert jax.tree.all(jax.tree.map(jnp.array_equal, _ck_state(1), restored))
    # a deleted leaf fails even the cheap manifest-level verification
    victim.unlink()
    assert mgr.all_steps() == [1]
    assert mgr.latest_step() == 1


def test_tampered_manifest_fails_verification(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5)
    mgr.save(1, _ck_state(1))
    mgr.save(2, _ck_state(2))
    mani = tmp_path / "step_00000002" / "manifest.json"
    mani.write_text(mani.read_text().replace('"step": 2', '"step": 20'))
    assert mgr.all_steps() == [1]       # checksum sibling catches the edit
    restored, _ = mgr.restore(jax.eval_shape(_ck_state))
    assert jax.tree.all(jax.tree.map(jnp.array_equal, _ck_state(1), restored))


def test_all_checkpoints_corrupt_raises_integrity_error(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5)
    mgr.save(1, _ck_state(1))
    for f in (tmp_path / "step_00000001" / "arrays").glob("*.npy"):
        raw = bytearray(f.read_bytes())
        raw[-1] ^= 0xFF
        f.write_bytes(bytes(raw))
    with pytest.raises(CheckpointIntegrityError):
        mgr.restore(jax.eval_shape(_ck_state))


def test_legacy_checkpoint_without_checksums_still_restores(tmp_path):
    """Pre-integrity-era checkpoints (no crc fields, no manifest sibling)
    must keep restoring: absent checksums verify vacuously."""
    import json
    mgr = CheckpointManager(tmp_path, keep=5)
    mgr.save(1, _ck_state(1))
    d = tmp_path / "step_00000001"
    manifest = json.loads((d / "manifest.json").read_text())
    for rec in manifest["leaves"]:
        rec.pop("crc32", None)
    (d / "manifest.json").write_text(json.dumps(manifest))
    (d / "manifest.crc32").unlink()
    assert mgr.all_steps() == [1]
    restored, _ = mgr.restore(jax.eval_shape(_ck_state))
    assert jax.tree.all(jax.tree.map(jnp.array_equal, _ck_state(1), restored))


def test_async_save_error_surfaces_from_wait_and_next_save(tmp_path,
                                                           monkeypatch):
    import repro.checkpoint.manager as manager_mod
    mgr = CheckpointManager(tmp_path, keep=5)
    mgr.save(1, _ck_state(1))

    real_save = manager_mod.np.save
    mode = ["boom"]

    def maybe_boom(path, arr):
        if mode[0] == "boom":
            raise OSError("disk full")
        return real_save(path, arr)

    monkeypatch.setattr(manager_mod.np, "save", maybe_boom)
    mgr.save(2, _ck_state(2), blocking=False)
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        mgr.wait()
    mgr.wait()                          # raised once, then cleared
    # the re-raise also fires from the NEXT save (the loop's common path)
    mgr.save(3, _ck_state(3), blocking=False)
    mgr._worker.join()                  # failure captured before the heal
    mode[0] = "ok"
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        mgr.save(4, _ck_state(4), blocking=False)
    # the failed steps never became visible checkpoints
    assert mgr.all_steps() == [1]


class _ListPipeline:
    def __init__(self, batches):
        self._batches = batches

    def __iter__(self):
        return iter(self._batches)

    def state(self):
        return {"cursor": 0}

    def restore(self, cursor):
        pass


def test_run_resilient_spends_a_restart_on_async_save_error(tmp_path,
                                                            monkeypatch):
    """An async checkpoint-save failure is a real failure: one spent restart
    and a rollback, never a silent gap in the checkpoint history."""
    from repro.core import TrainState
    import repro.checkpoint.manager as manager_mod
    real_save = manager_mod.np.save
    fails = [0]
    armed = [True]

    def flaky_save(path, arr):
        if fails[0]:
            fails[0] -= 1
            raise OSError("disk full")
        return real_save(path, arr)

    monkeypatch.setattr(manager_mod.np, "save", flaky_save)

    def step_fn(state, batch):
        if int(state.step) == 4 and armed[0]:
            armed[0] = False
            fails[0] = 1        # poison the NEXT async save (at step 5)
        state = state._replace(step=state.step + 1)
        return state, {"loss": jnp.asarray(0.5)}

    state = TrainState(step=jnp.asarray(0, jnp.int32),
                       rng=jax.random.PRNGKey(0),
                       params={"w": jnp.zeros(3)},
                       opt_state={"m": jnp.zeros(3)},
                       method_state={"a": jnp.zeros(3)})
    report = run_resilient(
        step_fn, state, _ListPipeline([{}] * 40),
        CheckpointManager(tmp_path, keep=5), n_steps=12,
        rcfg=ResilienceConfig(save_every=5, max_restarts=3, async_save=True))
    assert report.steps_done == 12
    assert report.restarts == 1, report.restarts
