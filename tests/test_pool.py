"""Multi-client ascent pool: scheduler, shared shadow, groups, hardening.

What PR 6 adds on top of the single-connection service tests
(`test_service.py`): N concurrent clients against one `AscentPool` —
the canonical generation-stamped `SharedShadow` that lockstep DP replicas'
delta streams land on exactly once (bitwise-pinned), `global` ascent-sync
groups handing every member the same smoothed gradient per (generation,
step), BUSY backpressure degrading to the staleness ledger, shared-token
auth fast-failing bad clients, and per-client error isolation (one dead
client never stalls its peers). The subprocess test at the bottom is the
acceptance criterion: two concurrent `RemoteExecutor` fits, one spawned
pool server, identical losses, one shadow install on the server's exit
stats line.
"""
import json
import threading
import time
import zlib

import jax
import numpy as np
import pytest

from repro import optim
from repro.core import MethodConfig, slice_ascent_batch
from repro.core.ascent import Compressor
from repro.data.synthetic import ClassificationTask
from repro.engine import Engine, RemoteExecutor, StalenessTelemetry
from repro.runtime import ExecutorConfig
from repro.service.ascent_server import AscentServer, spawn_server
from repro.service.client import RemoteAscentClient, reconnect_delay
from repro.service.pool import client_uid
from repro.service.testing import MLP_LOSS_SPEC, mlp_init, mlp_loss

TASK = ClassificationTask(n_classes=4, dim=8, seed=3)
BATCH = 64
WIDTHS = (8, 32, 4)


def _params(seed=0):
    return mlp_init(jax.random.PRNGKey(seed), WIDTHS)


def _batches(n, frac=0.5):
    return [{**b, "ascent": slice_ascent_batch(b, frac)}
            for b in TASK.train_batches(BATCH, n)]


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# satellite: jittered exponential reconnect backoff (pure math)
# ---------------------------------------------------------------------------

def test_reconnect_delay_jittered_exponential():
    hi = [reconnect_delay(a, 0.1, 8.0, rand=lambda: 1.0) for a in range(1, 12)]
    lo = [reconnect_delay(a, 0.1, 8.0, rand=lambda: 0.0) for a in range(1, 12)]
    # doubling span, capped
    assert hi[:4] == pytest.approx([0.1, 0.2, 0.4, 0.8])
    assert max(hi) == 8.0 and hi[-1] == 8.0
    # jitter floor is half the span: two clients kicked off the same server
    # never thunder back in phase, but neither waits pathologically long
    for l, h in zip(lo, hi):
        assert l == pytest.approx(h / 2)
    mid = [reconnect_delay(a, 0.1, 8.0) for a in range(1, 12)]
    for m, l, h in zip(mid, lo, hi):
        assert l <= m <= h


# ---------------------------------------------------------------------------
# auth: wrong token draws a fast fatal rejection, right token trains
# ---------------------------------------------------------------------------

def test_auth_rejection_fast_failure_and_accepted_token():
    server = AscentServer(mlp_loss, auth_token="sesame")
    server.serve_in_thread()
    params = jax.device_get(_params())
    batch = jax.device_get(_batches(1)[0]["ascent"])
    bad = RemoteAscentClient(server.address, Compressor("none"),
                             auth_token="wrong", reconnect_backoff_s=0.05)
    try:
        deadline = time.monotonic() + 60
        while not bad.fatal_error and time.monotonic() < deadline:
            time.sleep(0.02)
        # the rejection is terminal: no reconnect storm, submit raises
        assert "auth-rejected" in bad.fatal_error
        with pytest.raises(RuntimeError, match="rejected"):
            bad.submit(0, params, batch, jax.random.PRNGKey(0), 0)
        assert not bad._thread.is_alive()
        assert bad.reconnects == 0
    finally:
        bad.close()
    good = RemoteAscentClient(server.address, Compressor("none"),
                              auth_token="sesame")
    try:
        assert good.submit(0, params, batch, jax.random.PRNGKey(0), 0)
        got = good.poll(block=True, timeout=120.0)
        assert got is not None and got[1] is not None
        assert server.pool.auth_rejections == 1
    finally:
        good.close()
        server.close()


# ---------------------------------------------------------------------------
# tentpole: one canonical shadow + one group gradient for lockstep replicas
# ---------------------------------------------------------------------------

def test_two_clients_share_canonical_shadow_and_group_gradient():
    """Two delta-encoded replicas in one sync group: the canonical shadow
    installs once and advances once per seq (the peer's duplicate delta is
    served from the replay ring), both replicas receive the same smoothed
    gradient bitwise, and the server's shadow buffers stay bit-identical to
    the client encoder's."""
    steps = 5
    server = AscentServer(mlp_loss)
    server.serve_in_thread()
    mk = lambda cid: RemoteAscentClient(  # noqa: E731
        server.address, Compressor("none"), job_encoding="int8",
        job_delta=True, client_id=cid, sync_group="dp")
    c1, c2 = mk("replica-0"), mk("replica-1")
    try:
        params = jax.device_get(_params())
        batch = jax.device_get(_batches(1)[0]["ascent"])
        rs = np.random.RandomState(0)
        for step in range(steps):
            rng = jax.random.PRNGKey(step)
            assert c1.submit(0, params, batch, rng, step)
            assert c2.submit(0, params, batch, rng, step)
            got1 = c1.poll(block=True, timeout=120.0)
            got2 = c2.poll(block=True, timeout=120.0)
            assert got1 is not None and got1[1] is not None
            assert got2 is not None and got2[1] is not None
            # the group contract: same (generation, step) -> same gradient,
            # bit for bit, whichever replica's job computed it
            assert _tree_equal(got1[1], got2[1])
            assert got1[2] == got2[2]          # norm too
            params = jax.tree.map(
                lambda x: x + np.float32(0.01) * rs.randn(*x.shape)
                .astype(np.float32), params)
        stats = server.stats()
        assert stats["shadow_installs"] == 1      # ONE canonical install
        assert stats["shadow_skips"] == 1         # the peer's duplicate
        assert stats["deltas_applied"] == steps - 1   # advanced once per seq
        assert stats["delta_replays"] == steps - 1    # peer served from ring
        assert stats["resyncs_sent"] == 0 and stats["detaches_sent"] == 0
        assert stats["group_computes"] == steps
        assert stats["group_hits"] == steps
        assert c1.job_encoder.delta_jobs == steps - 1
        assert c2.job_encoder.delta_jobs == steps - 1
        # bitwise: server canonical shadow == client encoder shadow
        shadow = server.pool._shadows[("dp", 0)]
        srv_bufs = shadow.bufs_copy()
        enc_bufs = [np.asarray(jax.device_get(s))
                    for s in c1.job_encoder._shadow]
        assert srv_bufs is not None and len(srv_bufs) == len(enc_bufs)
        for a, b in zip(srv_bufs, enc_bufs):
            assert np.array_equal(a, b)
    finally:
        c1.close()
        c2.close()
        server.close()


# ---------------------------------------------------------------------------
# isolation: one client dying mid-fit never stalls the survivor
# ---------------------------------------------------------------------------

def test_client_death_leaves_peer_training():
    server = AscentServer(mlp_loss)
    server.serve_in_thread()
    c1 = RemoteAscentClient(server.address, Compressor("none"),
                            client_id="doomed")
    c2 = RemoteAscentClient(server.address, Compressor("none"),
                            client_id="survivor")
    try:
        params = jax.device_get(_params())
        batch = jax.device_get(_batches(1)[0]["ascent"])
        for c in (c1, c2):
            assert c.submit(0, params, batch, jax.random.PRNGKey(0), 0)
            got = c.poll(block=True, timeout=120.0)
            assert got is not None and got[1] is not None
        c1.close()          # dies mid-session from the server's view
        for step in range(1, 5):
            assert c2.submit(0, params, batch, jax.random.PRNGKey(step), step)
            got = c2.poll(block=True, timeout=120.0)
            assert got is not None and got[1] is not None
        assert c2.exchanges == 5 and c2.drops == 0
        deadline = time.monotonic() + 30
        while server.pool.dropped_clients < 1 and time.monotonic() < deadline:
            time.sleep(0.05)
        assert server.pool.dropped_clients >= 1
        assert server.connections == 2
    finally:
        c2.close()
        server.close()


# ---------------------------------------------------------------------------
# backpressure: saturated queue draws BUSY, the fit completes on the ledger
# ---------------------------------------------------------------------------

def test_busy_backpressure_fit_completes_on_ledger(tmp_path):
    """One slow worker, queue depth 1, three depth-1 clients: admission must
    reject with BUSY rather than buffer unboundedly, the rejected exchange
    lands on the client as a failed exchange (staleness ledger), and a fit
    running through the saturated pool still completes every step."""
    steps = 10
    server = AscentServer(mlp_loss, delay_s=0.25, pool_workers=1,
                          queue_depth=1)
    server.serve_in_thread()
    params = jax.device_get(_params())
    batch = jax.device_get(_batches(1)[0]["ascent"])
    stop = threading.Event()

    def _hammer(client, seed):
        step = 0
        while not stop.is_set():
            if client.submit(0, params, batch, jax.random.PRNGKey(seed),
                             step):
                client.poll(block=True, timeout=10.0)
                step += 1
            else:
                time.sleep(0.01)

    noise = [RemoteAscentClient(server.address, Compressor("none"),
                                client_id=f"noise-{i}") for i in range(2)]
    hammers = [threading.Thread(target=_hammer, args=(c, i), daemon=True)
               for i, c in enumerate(noise)]
    for t in hammers:
        t.start()
    try:
        mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5)
        telemetry = StalenessTelemetry(print_summary=False,
                                       jsonl_path=tmp_path / "busy.jsonl")
        with RemoteExecutor(mlp_loss, mcfg, optim.sgd(0.1, momentum=0.9),
                            exec_cfg=ExecutorConfig(
                                max_staleness=2,
                                ascent_addr=server.address,
                                client_id="fit-client")) as ex:
            state = ex.init_state(_params(), jax.random.PRNGKey(1))
            report = Engine(ex, _batches(steps), [telemetry]).fit(state,
                                                                  steps)
        assert report.steps_done == steps          # graceful degradation
        losses = [h["loss"] for h in report.metrics_history]
        assert all(np.isfinite(l) for l in losses)
        # a saturated single-worker pool cannot perturb every step: the
        # ledger's SGD fallback carried some of them
        assert any(h["perturbed"] == 0.0 for h in report.metrics_history)
        deadline = time.monotonic() + 60
        while server.pool.busy_rejections < 1 and time.monotonic() < deadline:
            time.sleep(0.05)
        assert server.pool.busy_rejections >= 1
        clients_saw = sum(c.busy_rejections for c in noise) + \
            ex.client.busy_rejections
        assert clients_saw >= 1
    finally:
        stop.set()
        for t in hammers:
            t.join(timeout=10)
        for c in noise:
            c.close()
        server.close()


# ---------------------------------------------------------------------------
# satellite: pool telemetry flows through StalenessTelemetry jsonl
# ---------------------------------------------------------------------------

def test_pool_telemetry_reaches_jsonl(tmp_path):
    server = AscentServer(mlp_loss)
    server.serve_in_thread()
    try:
        mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5)
        telemetry = StalenessTelemetry(print_summary=False,
                                       jsonl_path=tmp_path / "pool.jsonl")
        with RemoteExecutor(mlp_loss, mcfg, optim.sgd(0.1, momentum=0.9),
                            exec_cfg=ExecutorConfig(
                                lockstep=True,
                                ascent_addr=server.address,
                                client_id="tele-client")) as ex:
            state = ex.init_state(_params(), jax.random.PRNGKey(1))
            report = Engine(ex, _batches(6), [telemetry]).fit(state, 6)
        assert report.steps_done == 6
        records = [json.loads(l) for l in
                   (tmp_path / "pool.jsonl").read_text().splitlines()]
        tagged = [r for r in records if "client_id" in r]
        assert tagged, records
        uid = float(client_uid("tele-client"))
        assert all(r["client_id"] == uid for r in tagged)
        assert uid == float(zlib.crc32(b"tele-client"))
        assert any("pool_depth" in r and "pool_wait_s" in r for r in tagged)
        assert all(r["pool_wait_s"] >= 0.0 for r in tagged
                   if "pool_wait_s" in r)
    finally:
        server.close()


# ---------------------------------------------------------------------------
# acceptance: subprocess pool server, two concurrent RemoteExecutor fits
# ---------------------------------------------------------------------------

def test_pool_subprocess_two_concurrent_fits_share_one_shadow():
    """The acceptance criterion end to end: one spawned pool server with two
    ascent workers, two concurrent lockstep `RemoteExecutor` fits in the
    same sync group feeding delta-encoded streams of the same params. Every
    loss must match bitwise across the replicas (shared group gradient), and
    the server's exit stats line must show exactly one canonical shadow
    install with the peer's deltas served as replays."""
    steps = 8
    handle = spawn_server(MLP_LOSS_SPEC, pool_workers=2)
    barrier = threading.Barrier(2)
    results: dict = {}
    errors: list = []

    def _one(idx: int) -> None:
        try:
            mcfg = MethodConfig(name="async_sam", rho=0.05,
                                ascent_fraction=0.5)
            xcfg = ExecutorConfig(lockstep=True, ascent_addr=handle.addr,
                                  job_compress="int8", job_delta=True,
                                  client_id=f"replica-{idx}",
                                  sync_group="dp")
            losses = []
            with RemoteExecutor(mlp_loss, mcfg,
                                optim.sgd(0.1, momentum=0.9),
                                exec_cfg=xcfg) as ex:
                state = ex.init_state(_params(), jax.random.PRNGKey(1))
                for b in _batches(steps):
                    # per-step barrier: replicas stay within one step of
                    # each other, as a DP launcher's collective would keep
                    # them — the shadow replay ring covers the skew
                    barrier.wait(timeout=180)
                    state, m = ex.step(state, b)
                    losses.append(float(m["loss"]))
                results[idx] = {"losses": losses,
                                "exchanges": ex.client.exchanges,
                                "busy": ex.client.busy_rejections,
                                "detaches": ex.client.detaches}
        except BaseException as e:  # noqa: BLE001 — re-raised by the test
            errors.append(e)
            barrier.abort()

    threads = [threading.Thread(target=_one, args=(i,), daemon=True)
               for i in range(2)]
    try:
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=600)
    finally:
        handle.kill()
    assert not errors, errors
    assert set(results) == {0, 1}
    # shared group gradient -> the two fits are the same fit, bit for bit
    assert results[0]["losses"] == results[1]["losses"]
    assert all(np.isfinite(l) for l in results[0]["losses"])
    for r in results.values():
        assert r["exchanges"] >= steps - 1
        assert r["busy"] == 0 and r["detaches"] == 0
    stats = handle.stats()
    assert stats is not None, list(handle.tail)
    assert stats["connections"] == 2
    assert stats["shadow_installs"] == 1       # ONE canonical shadow
    assert stats["shadow_skips"] >= 1
    # each delta seq advanced the shadow once; the peer's copy replayed
    # (the final step's frames may still be in flight at shutdown)
    assert stats["deltas_applied"] >= steps - 2
    assert stats["delta_replays"] >= steps - 3
    assert stats["group_computes"] >= steps - 2
    assert stats["group_hits"] >= steps - 3
    assert stats["resyncs_sent"] == 0 and stats["auth_rejections"] == 0
    assert stats["exchanges"] >= 2 * (steps - 1)
