"""Runtime layer: hetero async executor, fault tolerance, elastic resharding,
gradient compression."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import Compressor, MethodConfig, init_train_state, make_method
from repro.data import PipelineConfig, TokenPipeline
from repro.models import build_model
from repro.runtime import (AsyncSamExecutor, ExecutorConfig, InjectedFailure,
                           ResilienceConfig, run_resilient)
from repro.utils import trees


def _mlp_loss(params, batch, rng):
    h = jnp.tanh(batch["x"] @ params["w1"])
    logits = h @ params["w2"]
    onehot = jax.nn.one_hot(batch["y"], logits.shape[-1])
    loss = -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))
    return loss, {"logits": logits}


def _mlp_params(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w1": jax.random.normal(k, (8, 32)) * 0.3,
            "w2": jax.random.normal(jax.random.fold_in(k, 1), (32, 4)) * 0.3}


def _batch(seed=0, n=64):
    k = jax.random.PRNGKey(100 + seed)
    return {"x": jax.random.normal(k, (n, 8)),
            "y": jax.random.randint(jax.random.fold_in(k, 1), (n,), 0, 4)}


# ---------------------------------------------------------------------------
# async executor (paper Form B)
# ---------------------------------------------------------------------------

def test_executor_steady_state_tau_is_one():
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5)
    opt = optim.sgd(0.1, momentum=0.9)
    method = make_method(mcfg)
    state = init_train_state(_mlp_params(), opt, method, jax.random.PRNGKey(1))
    with AsyncSamExecutor(_mlp_loss, mcfg, opt) as ex:
        first_loss = None
        for i in range(25):
            state, m = ex.step(state, _batch(i))
            if first_loss is None:
                first_loss = float(m["loss"])
        summary = ex.ledger.summary()
    assert summary["tau"] == 1
    assert summary["refreshes"] >= 20
    assert summary["sgd_fallbacks"] == 0
    assert float(m["loss"]) < first_loss


def test_executor_straggler_grows_tau_then_falls_back_to_sgd():
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5)
    opt = optim.sgd(0.05)
    method = make_method(mcfg)
    state = init_train_state(_mlp_params(), opt, method, jax.random.PRNGKey(1))
    # calibrate the injected straggle against THIS machine's step time so the
    # test stays deterministic under CPU contention: the helper must be far
    # slower than the descent lane
    probe = AsyncSamExecutor(_mlp_loss, mcfg, opt)
    t0 = time.perf_counter()
    state, _ = probe.step(state, _batch(0))
    state, _ = probe.step(state, _batch(1))
    step_s = (time.perf_counter() - t0) / 2
    probe.close()
    xcfg = ExecutorConfig(max_staleness=2,
                          ascent_delay_s=max(0.5, 10.0 * step_s))
    with AsyncSamExecutor(_mlp_loss, mcfg, opt, xcfg) as ex:
        fallbacks = 0
        for i in range(12):
            state, m = ex.step(state, _batch(i))
            fallbacks += m["perturbed"] == 0.0
        summary = ex.ledger.summary()
    # helper ~10x slower than a step: reuse crosses max_staleness => SGD steps
    assert summary["stale_reuses"] > 0 or summary["sgd_fallbacks"] > 0 \
        or fallbacks > 0
    assert np.isfinite(float(m["loss"]))


def test_executor_calibration_returns_sane_fraction():
    mcfg = MethodConfig(name="async_sam", ascent_fraction=0.5)
    opt = optim.sgd(0.05)
    method = make_method(mcfg)
    state = init_train_state(_mlp_params(), opt, method, jax.random.PRNGKey(1))
    with AsyncSamExecutor(_mlp_loss, mcfg, opt) as ex:
        frac = ex.calibrate(state, _batch(0))
    assert 0.05 <= frac <= 1.0


# ---------------------------------------------------------------------------
# fault tolerance: crash-restart equivalence
# ---------------------------------------------------------------------------

def _make_lm_run(tmp_path, n_steps, injector=None, subdir="a"):
    cfg = get_config("olmo-1b", reduced=True)
    bundle = build_model(cfg)
    mcfg = MethodConfig(name="async_sam", rho=0.02, ascent_fraction=0.5)
    method = make_method(mcfg)
    opt = optim.adamw(1e-3)
    params = bundle.init(jax.random.PRNGKey(0))
    state = init_train_state(params, opt, method, jax.random.PRNGKey(1))
    step = jax.jit(method.make_step(bundle.loss_fn, opt))
    pipe = TokenPipeline(cfg, PipelineConfig(global_batch=4, seq_len=16,
                                             ascent_fraction=0.5, prefetch=0))
    mgr = CheckpointManager(tmp_path / subdir, keep=3)
    return run_resilient(step, state, pipe, mgr, n_steps,
                         ResilienceConfig(save_every=5, async_save=False),
                         failure_injector=injector)


def test_crash_restart_reaches_identical_state(tmp_path):
    clean = _make_lm_run(tmp_path, 20, subdir="clean")

    crashed = {"done": False}

    def injector(step):
        if step == 12 and not crashed["done"]:
            crashed["done"] = True
            raise InjectedFailure("simulated node loss at step 12")

    faulty = _make_lm_run(tmp_path, 20, injector=injector, subdir="faulty")
    assert faulty.restarts == 1
    assert faulty.steps_done == clean.steps_done == 20
    # deterministic pipeline + step => bitwise identical final parameters
    assert jax.tree.all(jax.tree.map(
        lambda a, b: jnp.array_equal(a, b),
        clean.final_state.params, faulty.final_state.params))


def test_restart_budget_exhaustion_raises(tmp_path):
    def always_fail(step):
        raise InjectedFailure("dead node")

    with pytest.raises(RuntimeError, match="restart budget"):
        cfg = get_config("olmo-1b", reduced=True)
        bundle = build_model(cfg)
        mcfg = MethodConfig(name="sgd")
        method = make_method(mcfg)
        opt = optim.sgd(0.01)
        params = bundle.init(jax.random.PRNGKey(0))
        state = init_train_state(params, opt, method, jax.random.PRNGKey(1))
        step = jax.jit(method.make_step(bundle.loss_fn, opt))
        pipe = TokenPipeline(cfg, PipelineConfig(global_batch=2, seq_len=8,
                                                 prefetch=0))
        mgr = CheckpointManager(tmp_path / "x", keep=1)
        run_resilient(step, state, pipe, mgr, 10,
                      ResilienceConfig(save_every=5, max_restarts=2,
                                       async_save=False),
                      failure_injector=always_fail)


# ---------------------------------------------------------------------------
# elastic resharding across meshes (subprocess: needs >1 device)
# ---------------------------------------------------------------------------

def test_elastic_reshard_roundtrip(subprocess_py):
    out = subprocess_py("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import build_model
        from repro.runtime import reshard_state
        from repro.core import MethodConfig, make_method, init_train_state
        from repro import optim

        cfg = get_config('olmo-1b', reduced=True)
        bundle = build_model(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        method = make_method(MethodConfig(name='async_sam'))
        opt = optim.adamw(1e-3)
        state = init_train_state(params, opt, method, jax.random.PRNGKey(1))

        mesh_a = jax.make_mesh((4, 2), ('data', 'model'))
        mesh_b = jax.make_mesh((2, 4), ('data', 'model'))
        on_a = reshard_state(state, cfg, mesh_a)
        on_b = reshard_state(on_a, cfg, mesh_b)
        back = jax.device_get(on_b)
        orig = jax.device_get(state)
        ok = jax.tree.all(jax.tree.map(
            lambda x, y: jnp.array_equal(x, y), orig.params, back.params))
        print('RESHARD_OK', bool(ok))
    """, devices=8)
    assert "RESHARD_OK True" in out


# ---------------------------------------------------------------------------
# gradient compression with error feedback
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["int8", "topk"])
def test_compressor_error_feedback_preserves_signal(kind):
    comp = Compressor(kind=kind, topk_fraction=0.25)
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (256,))}
    state = comp.init(g)
    # accumulated quantized signal tracks accumulated true signal (EF property)
    acc_q = jnp.zeros(256)
    acc_g = jnp.zeros(256)
    for i in range(30):
        gi = {"w": jax.random.normal(jax.random.fold_in(key, i), (256,))}
        q, state = comp.compress(gi, state)
        acc_q += q["w"]
        acc_g += gi["w"]
    # residual is bounded, so mean error -> 0 over time
    err = float(jnp.linalg.norm(acc_q - acc_g) / jnp.linalg.norm(acc_g))
    assert err < 0.25, err


def test_compressor_wire_bytes_ordering():
    g = {"w": jnp.zeros((1000,))}
    none_b = Compressor("none").wire_bytes(g)
    int8_b = Compressor("int8").wire_bytes(g)
    topk_b = Compressor("topk", topk_fraction=0.01).wire_bytes(g)
    assert topk_b < int8_b < none_b


def test_executor_with_compressed_ascent_exchange():
    """int8 ascent hand-off: training still descends, wire bytes ~1/4 of fp32."""
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5,
                        compressor="int8")
    opt = optim.sgd(0.1, momentum=0.9)
    method = make_method(mcfg)
    state = init_train_state(_mlp_params(), opt, method, jax.random.PRNGKey(1))
    with AsyncSamExecutor(_mlp_loss, mcfg, opt) as ex:
        first = None
        for i in range(20):
            state, m = ex.step(state, _batch(i))
            if first is None:
                first = float(m["loss"])
        wire = ex.wire_bytes_per_exchange
    n_params = sum(x.size for x in jax.tree.leaves(_mlp_params()))
    assert wire < 0.3 * 4 * n_params      # ~int8 payload vs fp32
    assert float(m["loss"]) < first
