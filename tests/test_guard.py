"""Numerics guard: in-step skip, spike/stale detection, the rho de-escalation
ladder, NumericChaos injection, and diverge-proof PoisonBatch rollback.

The acceptance soak at the bottom pins ISSUE-10's contract: a NumericChaos
run (NaN-gradient window + loss-spike events) completes within its restart
budget with >=1 skip, >=1 de-escalation, >=1 recovery and >=1 poison
rollback visible in the registry keys, final loss finite and close to an
uninjected run — while the SAME injection without the guard diverges.
"""
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import MethodConfig, TrainState, init_train_state, make_method
from repro.data import PipelineConfig, TokenPipeline
from repro.runtime import (GuardConfig, GuardedExecutor, InjectedFailure,
                           NumericChaos, NumericChaosPipeline, NumericRule,
                           PoisonBatch, ResilienceConfig, SpikeDetector,
                           parse_numchaos, run_resilient)
from repro.runtime.guard import _poison_batch


def _lin_loss(params, batch, rng):
    # linear classifier (no squashing): a spike-scaled batch produces a
    # genuinely spiked loss, which tanh MLPs would saturate away
    logits = batch["x"] @ params["w"]
    onehot = jax.nn.one_hot(batch["y"], logits.shape[-1])
    loss = -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))
    return loss, {}


def _lin_params(seed=0):
    return {"w": jax.random.normal(jax.random.PRNGKey(seed), (8, 4)) * 0.3}


def _float_batch(i, n=32, nan=False, scale=1.0):
    k = jax.random.PRNGKey(1000 + i)
    x = np.asarray(jax.random.normal(k, (n, 8)), np.float32) * scale
    if nan:
        x = np.full_like(x, np.nan)
    y = np.asarray(jax.random.randint(jax.random.fold_in(k, 1), (n,), 0, 4))
    return {"x": x, "y": y}


class _CursorPipeline:
    """Stateful float-batch stream; batch content is a function of the
    cursor, so replaying the stream replays the poison (the livelock)."""

    def __init__(self, n, chaos: NumericChaos = None):
        self.n = n
        self.chaos = chaos
        self._cursor = 0

    def state(self):
        return {"cursor": self._cursor}

    def restore(self, st):
        self._cursor = int(st["cursor"])

    def __iter__(self):
        while self._cursor < self.n:
            i = self._cursor
            self._cursor += 1
            b = _float_batch(i)
            yield self.chaos.inject(i, b) if self.chaos is not None else b


# ---------------------------------------------------------------------------
# in-step guard (core/api._finish under guard_update)
# ---------------------------------------------------------------------------

def test_in_step_guard_skips_nonfinite_update_keeps_params():
    opt = optim.sgd(0.1, momentum=0.9)
    mcfg = MethodConfig(name="sgd", guard_update=True)
    method = make_method(mcfg)
    state = init_train_state(_lin_params(), opt, method, jax.random.PRNGKey(1))
    step = jax.jit(method.make_step(_lin_loss, opt))

    state, m = step(state, _float_batch(0))
    assert float(m["update_skipped"]) == 0.0
    assert float(m["nonfinite_count"]) == 0.0
    before = jax.device_get(state.params)

    state, m = step(state, _float_batch(1, nan=True))
    assert float(m["update_skipped"]) == 1.0
    assert float(m["nonfinite_count"]) > 0
    # params (and moments) tree-selected back to the pre-step values ...
    assert jax.tree.all(jax.tree.map(
        lambda a, b: jnp.array_equal(a, b), before, state.params))
    assert all(np.isfinite(x).all() for x in jax.tree.leaves(
        jax.device_get(state.opt_state)))
    # ... while step/rng advanced: the batch is consumed, not replayed
    assert int(state.step) == 2

    state, m = step(state, _float_batch(2))
    assert float(m["update_skipped"]) == 0.0
    assert np.isfinite(float(m["loss"]))


def test_without_guard_nan_batch_poisons_params():
    opt = optim.sgd(0.1)
    mcfg = MethodConfig(name="sgd")          # guard_update defaults off
    method = make_method(mcfg)
    state = init_train_state(_lin_params(), opt, method, jax.random.PRNGKey(1))
    step = jax.jit(method.make_step(_lin_loss, opt))
    state, m = step(state, _float_batch(0, nan=True))
    assert "update_skipped" not in m         # metric surface unchanged
    assert not np.isfinite(jax.device_get(state.params["w"])).all()


def test_async_sam_guard_keeps_carried_ascent_finite():
    opt = optim.adamw(1e-3)
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5,
                        guard_update=True)
    method = make_method(mcfg)
    state = init_train_state(_lin_params(), opt, method, jax.random.PRNGKey(1))
    step = jax.jit(method.make_step(_lin_loss, opt))
    for i in range(3):
        state, m = step(state, _float_batch(i))
    held = jax.device_get(state.method_state.ascent_norm)
    assert np.isfinite(held) and held > 0

    state, m = step(state, _float_batch(3, nan=True))
    ms = state.method_state
    # the NaN refresh never entered the carried state (0 * NaN is still NaN:
    # a poisoned a_t would corrupt every later perturbation even at rho 0)
    assert np.isfinite(jax.device_get(ms.ascent_norm))
    assert all(np.isfinite(x).all()
               for x in jax.tree.leaves(jax.device_get(ms.ascent_grad)))
    assert float(m["update_skipped"]) == 1.0

    state, m = step(state, _float_batch(4))
    assert np.isfinite(float(m["loss"]))
    assert float(m["perturbed"]) == 1.0      # still a SAM step afterwards


def test_ascent_reused_flag_disambiguates_nan_sentinel():
    """Satellite 3: on AsyncSAM-k reuse steps ascent_loss is a NaN SENTINEL;
    ascent_reused=1 is the explicit marker that it is not a genuine NaN."""
    opt = optim.sgd(0.05)
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5,
                        ascent_interval=2)
    method = make_method(mcfg)
    state = init_train_state(_lin_params(), opt, method, jax.random.PRNGKey(1))
    step = jax.jit(method.make_step(_lin_loss, opt))
    seen = {0.0: [], 1.0: []}
    for i in range(6):
        state, m = step(state, _float_batch(i))
        seen[float(m["ascent_reused"])].append(float(m["ascent_loss"]))
    assert seen[1.0] and all(math.isnan(v) for v in seen[1.0])
    assert seen[0.0] and all(math.isfinite(v) for v in seen[0.0])


# ---------------------------------------------------------------------------
# SpikeDetector
# ---------------------------------------------------------------------------

def test_spike_detector_flags_only_positive_excursions():
    det = SpikeDetector(window=16, min_samples=8)
    assert det.score(5.0) is None            # not warmed up
    for i in range(16):
        det.observe(2.0 - 0.01 * i + 0.02 * (i % 3))   # falling, jittery
    assert det.score(1.7) < 8.0              # further improvement: fine
    assert det.score(40.0) > 8.0             # spike
    assert det.score(0.5) < 0                # signed: below median is negative


def test_spike_detector_flat_window_needs_relative_excursion():
    det = SpikeDetector(window=8, min_samples=4)
    for _ in range(8):
        det.observe(1.0)                     # MAD = 0
    assert det.score(1.001) < 8.0            # numeric jitter: not a spike
    assert det.score(3.0) > 8.0              # 3x the median: a spike


# ---------------------------------------------------------------------------
# NumericChaos + pipeline wrapper
# ---------------------------------------------------------------------------

def test_parse_numchaos_grammar_and_errors():
    nc = parse_numchaos("nan_grad:nth=40:span=8,spike:prob=0.01:scale=1e4,"
                        "inf_grad:every=50", seed=3)
    kinds = [r.kind for r in nc.rules]
    assert kinds == ["nan_grad", "spike", "inf_grad"]
    assert nc.rules[0].span == 8 and nc.rules[1].scale == 1e4
    with pytest.raises(ValueError, match="kind"):
        parse_numchaos("frobnicate:nth=1")
    with pytest.raises(ValueError, match="unknown key"):
        parse_numchaos("nan_grad:bogus=1")
    with pytest.raises(ValueError, match="key=val"):
        parse_numchaos("nan_grad:nth")
    with pytest.raises(ValueError, match="empty"):
        parse_numchaos(" , ")


def test_numchaos_is_deterministic_per_index_not_fire_once():
    """Poison is a property of the data: re-asking about the same index
    re-fires identically (this is what makes the replay livelock real)."""
    a = parse_numchaos("spike:prob=0.2,nan_grad:nth=7:span=2", seed=9)
    b = parse_numchaos("spike:prob=0.2,nan_grad:nth=7:span=2", seed=9)
    fires_a = [[a._fires(r, i, idx) for i, r in enumerate(a.rules)]
               for idx in range(200)]
    fires_b = [[b._fires(r, i, idx) for i, r in enumerate(b.rules)]
               for idx in range(200)]
    assert fires_a == fires_b
    assert any(f[0] for f in fires_a)                  # prob rule does fire
    assert fires_a[7][1] and fires_a[8][1] and not fires_a[9][1]
    # replay: asking twice about the same index is idempotent
    assert a._fires(a.rules[1], 1, 7) and a._fires(a.rules[1], 1, 7)


def test_poison_touches_float_leaves_only():
    batch = {"x": np.ones((4, 8), np.float32), "y": np.arange(4)}
    out, hit = _poison_batch(batch, NumericRule("nan_grad", nth=0))
    assert hit
    assert np.isnan(np.asarray(out["x"])).all()
    assert np.array_equal(np.asarray(out["y"]), np.arange(4))  # ints untouched
    tokens_only = {"tokens": np.arange(12).reshape(3, 4)}
    out, hit = _poison_batch(tokens_only, NumericRule("nan_grad", nth=0))
    assert not hit                                  # nothing to poison
    out, _ = _poison_batch({"x": np.full((2, 2), 2.0, np.float32)},
                           NumericRule("spike", nth=0, scale=100.0))
    assert np.allclose(np.asarray(out["x"]), 200.0)


def test_numchaos_pipeline_cursor_state_and_uninjected_peek():
    cfg_arch = get_config("olmo-1b", reduced=True)
    inner = TokenPipeline(cfg_arch, PipelineConfig(global_batch=2, seq_len=8,
                                                   prefetch=0))
    chaos = parse_numchaos("nan_grad:nth=1", seed=0)
    pipe = NumericChaosPipeline(inner, chaos)
    assert "tokens" in pipe.peek()                  # peek: delegated, uninjected
    it = iter(pipe)
    next(it), next(it)
    st = pipe.state()
    assert st["cursor"] == 2 and "inner" in st
    pipe.restore({"cursor": 0, "inner": st["inner"]})
    assert pipe.state()["cursor"] == 0
    # token-only batches have no float leaves: injection is a counted no-op
    assert chaos.fired.get("nan_grad", 0) == 0 and chaos.skipped_no_float == 1


def test_pipeline_state_records_rank_world_identity():
    """Satellite 2: restoring rank 0's cursor into rank 1's pipeline would
    silently resume on the wrong stream shard — restore() refuses."""
    cfg_arch = get_config("olmo-1b", reduced=True)
    p0 = TokenPipeline(cfg_arch, PipelineConfig(global_batch=4, seq_len=8,
                                                rank=0, world=2, prefetch=0))
    p1 = TokenPipeline(cfg_arch, PipelineConfig(global_batch=4, seq_len=8,
                                                rank=1, world=2, prefetch=0))
    st = p0.state()
    assert (st["rank"], st["world"]) == (0, 2)
    p0.restore(st)                                  # same identity: fine
    with pytest.raises(AssertionError, match="identity"):
        p1.restore(st)
    # pre-identity-era states (no rank/world) restore unchanged
    p1.restore({"step": 3, "seed": 0})
    assert p1.state()["step"] == 3


# ---------------------------------------------------------------------------
# GuardedExecutor ladder mechanics (deterministic fake inner executor)
# ---------------------------------------------------------------------------

class _FakeExec:
    """Inner executor whose metrics are scripted via the batch dict."""

    def __init__(self):
        self.rho_scales = []
        self.drops = 0
        self.closed = False

    def step(self, state, batch):
        state = state._replace(step=state.step + 1)
        return state, dict(batch["metrics"])

    def set_rho_scale(self, scale):
        self.rho_scales.append(scale)

    def drop_ascent(self):
        self.drops += 1

    def on_restore(self, state):
        return None

    def close(self):
        self.closed = True


def _fake_state():
    return TrainState(step=jnp.asarray(0, jnp.int32),
                      rng=jax.random.PRNGKey(0), params={"w": jnp.zeros(2)},
                      opt_state=(), method_state=())


def _m(loss=1.0, skipped=0.0, **kw):
    return {"metrics": {"loss": loss, "grad_norm": 1.0,
                        "update_skipped": skipped, **kw}}


def test_guard_ladder_deescalates_then_recovers():
    cfg = GuardConfig(rho_scales=(1.0, 0.5, 0.0), demote_after=2,
                      anomaly_window=4, probation_steps=2, cooldown_steps=3,
                      spike_min_samples=4, rollback=False)
    inner = _FakeExec()
    g = GuardedExecutor(inner, cfg)
    state = _fake_state()
    # two skip anomalies -> one rung down, rho halved through the hook
    state, m = g.step(state, _m(skipped=1.0))
    assert m["guard_state"] == 0.0 and m["steps_skipped"] == 1.0
    state, m = g.step(state, _m(skipped=1.0))
    assert m["guard_state"] == 1.0 and m["rho_scale"] == 0.5
    assert inner.rho_scales == [0.5]
    # two more -> bottom rung: plain descent; no rollback configured, so the
    # guard parks there instead of raising
    state, _ = g.step(state, _m(skipped=1.0))
    state, m = g.step(state, _m(skipped=1.0))
    assert m["guard_state"] == 2.0 and m["rho_scale"] == 0.0
    state, m = g.step(state, _m(skipped=1.0))       # still anomalous at bottom
    assert m["guard_state"] == 2.0
    # clean steps: cooldown-gated promotions climb all the way back
    for _ in range(40):
        state, m = g.step(state, _m())
    assert m["guard_state"] == 0.0 and m["rho_scale"] == 1.0
    assert g.ladder.recoveries >= 2
    g.close()
    assert inner.closed


def test_guard_spike_and_stale_ascent_classification():
    cfg = GuardConfig(rho_scales=(1.0, 0.0), demote_after=2, anomaly_window=4,
                      spike_window=8, spike_min_samples=4, spike_zscore=8.0,
                      stale_norm_mult=10.0, stale_norm_min_samples=4,
                      rollback=False)
    inner = _FakeExec()
    g = GuardedExecutor(inner, cfg)
    state = _fake_state()
    for i in range(8):
        state, _ = g.step(state, _m(loss=1.0 + 0.01 * (i % 3),
                                    ascent_norm=2.0))
    # a loss spike is an anomaly but NOT a skip
    state, m = g.step(state, _m(loss=500.0, ascent_norm=2.0))
    assert "steps_skipped" not in m
    assert sum(g._anomalies) == 1
    # an exploded held-ascent norm triggers the drop hook next step
    state, _ = g.step(state, _m(ascent_norm=2000.0))
    assert sum(g._anomalies) == 0               # 2 anomalies -> demote+clear
    assert g.ladder.level == 1
    state, _ = g.step(state, _m(ascent_norm=2.0))
    assert inner.drops == 1
    # a non-finite ascent norm is an ascent drop too, never a rollback
    state, _ = g.step(state, _m(ascent_norm=float("nan")))
    state, _ = g.step(state, _m(ascent_norm=2.0))
    assert inner.drops == 2


def test_guard_bottom_rung_raises_poison_and_counts_rollback():
    cfg = GuardConfig(rho_scales=(1.0, 0.0), demote_after=2, anomaly_window=4,
                      spike_min_samples=4, rollback=True)
    inner = _FakeExec()
    g = GuardedExecutor(inner, cfg)
    state = _fake_state()
    state, _ = g.step(state, _m(skipped=1.0))
    state, _ = g.step(state, _m(skipped=1.0))   # -> bottom rung
    assert g.ladder.level == 1
    state, _ = g.step(state, _m(skipped=1.0))
    with pytest.raises(PoisonBatch, match="bottom rung"):
        g.step(state, _m(skipped=1.0))
    # the rollback lands in the counters via on_restore; ladder keeps its rung
    g.on_restore(state)
    assert g.poison_rollbacks == 1 and g.ladder.level == 1
    state, m = g.step(state, _m())
    assert m["poison_rollbacks"] == 1.0


def test_guard_severe_nonfinite_state_rolls_back_immediately():
    """Non-finite loss with the update APPLIED (no in-step guard) means the
    params may already be poisoned: no rung can fix that — straight to
    rollback, not a de-escalation."""
    g = GuardedExecutor(_FakeExec(), GuardConfig(rollback=True))
    state = _fake_state()
    state, _ = g.step(state, _m())
    with pytest.raises(PoisonBatch, match="non-finite training state"):
        g.step(state, _m(loss=float("nan")))


def test_guard_delegates_unknown_attrs_to_inner():
    inner = _FakeExec()
    inner.mesh = "the-mesh"
    g = GuardedExecutor(inner, GuardConfig())
    assert g.mesh == "the-mesh"
    with pytest.raises(AttributeError):
        _ = g.nonesuch


# ---------------------------------------------------------------------------
# hetero executor guard hooks
# ---------------------------------------------------------------------------

def test_executor_rho_scale_and_nonfinite_harvest_drop():
    from repro.runtime import AsyncSamExecutor, ExecutorConfig
    # guard_update so the NaN *descent* batch at step 6 skips instead of
    # poisoning the params (this test is about the ascent-lane edge)
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5,
                        guard_update=True)
    opt = optim.sgd(0.05)
    method = make_method(mcfg)
    state = init_train_state(_lin_params(), opt, method, jax.random.PRNGKey(1))
    with AsyncSamExecutor(_lin_loss, mcfg, opt,
                          ExecutorConfig(lockstep=True)) as ex:
        for i in range(4):
            state, m = ex.step(state, _float_batch(i))
        assert float(m["perturbed"]) == 1.0
        assert np.isfinite(m["ascent_norm"]) and m["ascent_norm"] > 0
        # bottom rung: scale 0 forces plain descent while a gradient is held
        ex.set_rho_scale(0.0)
        state, m = ex.step(state, _float_batch(4))
        assert float(m["perturbed"]) == 0.0
        ex.set_rho_scale(1.0)
        state, m = ex.step(state, _float_batch(5))
        assert float(m["perturbed"]) == 1.0
        # a NaN ascent batch produces a non-finite harvest: dropped at the
        # lane edge (never held), counted, and training stays perturbable
        before = ex.nonfinite_drops
        state, m = ex.step(state, _float_batch(6, nan=True))
        state, m = ex.step(state, _float_batch(7))
        state, m = ex.step(state, _float_batch(8))
        assert ex.nonfinite_drops == before + 1
        held_g, held_norm = ex._held
        assert np.isfinite(held_norm)
        assert np.isfinite(float(m["loss"]))
        # drop_ascent clears the held gradient without fencing the lane
        ex.drop_ascent()
        assert ex._held is None and ex.ledger.tau == 0


# ---------------------------------------------------------------------------
# PoisonBatch rollback: cursor advances, no livelock (satellite 1 pin)
# ---------------------------------------------------------------------------

def _poison_step_fn():
    def step_fn(state, batch):
        if np.isnan(np.asarray(batch["x"])).any():
            raise PoisonBatch("poisoned batch content")
        state = state._replace(step=state.step + 1)
        return state, {"loss": jnp.asarray(0.5)}
    return step_fn


def _tiny_state():
    return TrainState(step=jnp.asarray(0, jnp.int32),
                      rng=jax.random.PRNGKey(0), params={"w": jnp.zeros(3)},
                      opt_state={"m": jnp.zeros(3)},
                      method_state={"a": jnp.zeros(3)})


def test_poison_rollback_advances_cursor_past_the_window(tmp_path):
    chaos = NumericChaos([NumericRule("nan_grad", nth=7)], seed=0)
    pipe = _CursorPipeline(40, chaos)
    report = run_resilient(
        _poison_step_fn(), _tiny_state(), pipe,
        CheckpointManager(tmp_path, keep=3), n_steps=12,
        rcfg=ResilienceConfig(save_every=5, max_restarts=3, async_save=False))
    assert report.steps_done == 12
    assert report.poison_rollbacks == 1 and report.restarts == 1
    # the model rolled back (step 5) but the data did NOT: batch 7 was
    # consumed exactly once and never replayed
    assert pipe.state()["cursor"] == 12 + 1 + 2   # 12 steps + poison + rollback gap


def test_node_loss_style_replay_livelocks_on_poison_data(tmp_path):
    """The counterfactual that pins satellite 1: treating a poison batch as
    a node loss (cursor restored) replays the identical batch into the
    identical failure until the restart budget is gone."""
    chaos = NumericChaos([NumericRule("nan_grad", nth=7)], seed=0)
    pipe = _CursorPipeline(40, chaos)

    def step_fn(state, batch):
        if np.isnan(np.asarray(batch["x"])).any():
            raise InjectedFailure("NaN mistaken for a node loss")
        state = state._replace(step=state.step + 1)
        return state, {"loss": jnp.asarray(0.5)}

    with pytest.raises(RuntimeError, match="restart budget"):
        run_resilient(step_fn, _tiny_state(), pipe,
                      CheckpointManager(tmp_path, keep=3), n_steps=12,
                      rcfg=ResilienceConfig(save_every=5, max_restarts=3,
                                            async_save=False))


def test_require_finite_restore_skips_diverged_checkpoints(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5)
    good = {"w": jnp.ones(4)}
    bad = {"w": jnp.array([1.0, float("nan"), 2.0, 3.0])}
    mgr.save(1, good)
    mgr.save(2, bad)
    like = jax.eval_shape(lambda: good)
    restored, _ = mgr.restore(like)                       # default: newest
    assert not np.isfinite(np.asarray(restored["w"])).all()
    restored, _ = mgr.restore(like, require_finite=True)  # falls back past it
    assert np.array_equal(np.asarray(restored["w"]), np.ones(4))


# ---------------------------------------------------------------------------
# acceptance soak (the ISSUE-10 pinned test) + unguarded counterfactual
# ---------------------------------------------------------------------------

class _MethodExec:
    """Minimal fused-form StepExecutor over a jitted method step."""

    def __init__(self, mcfg, loss, opt):
        self.method = make_method(mcfg)
        self._step = jax.jit(self.method.make_step(loss, opt))

    def step(self, state, batch):
        return self._step(state, batch)

    def close(self):
        pass


_SOAK_SPEC = "nan_grad:nth=20,nan_grad:nth=40:span=8,spike:nth=90:span=2:scale=1e4"


def _soak_guard_cfg():
    return GuardConfig(rho_scales=(1.0, 0.5, 0.0), demote_after=2,
                       anomaly_window=4, probation_steps=4, cooldown_steps=4,
                       spike_window=16, spike_min_samples=8, rollback=True)


def _soak_run(tmp_path, n_steps=120):
    opt = optim.adamw(3e-3)
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5,
                        guard_update=True)
    inner = _MethodExec(mcfg, _lin_loss, opt)
    guard = GuardedExecutor(inner, _soak_guard_cfg())
    state = init_train_state(_lin_params(), opt, inner.method,
                             jax.random.PRNGKey(1))
    chaos = parse_numchaos(_SOAK_SPEC, seed=0)
    pipe = _CursorPipeline(400, chaos)
    report = run_resilient(
        guard.step, state, pipe, CheckpointManager(tmp_path, keep=3),
        n_steps=n_steps,
        rcfg=ResilienceConfig(save_every=10, max_restarts=5,
                              async_save=False, require_finite_restore=True),
        on_restore=guard.on_restore)
    return report, guard, chaos


def test_acceptance_guarded_numchaos_run_survives_and_converges(tmp_path):
    report, guard, chaos = _soak_run(tmp_path / "guarded")
    hist = report.metrics_history
    assert report.steps_done == 120

    # the injection really happened: NaN window + spike events all fired
    assert chaos.fired["nan_grad"] >= 9 and chaos.fired["spike"] >= 1

    # contract: >=1 skip, >=1 de-escalation, >=1 recovery, >=1 poison
    # rollback — all visible in the registry keys of metrics_history
    assert max(m.get("steps_skipped", 0) for m in hist) >= 1
    assert max(m.get("guard_state", 0) for m in hist) >= 1          # de-escalated
    assert hist[-1]["guard_state"] == 0.0                           # recovered
    assert guard.ladder.recoveries >= 1
    assert max(m.get("poison_rollbacks", 0) for m in hist) >= 1
    assert report.poison_rollbacks >= 1
    assert report.restarts <= 5                                     # in budget

    # final loss finite and within tolerance of an uninjected run
    final = hist[-1]["loss"]
    assert np.isfinite(final)
    opt = optim.adamw(3e-3)
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5,
                        guard_update=True)
    clean_exec = _MethodExec(mcfg, _lin_loss, opt)
    clean = init_train_state(_lin_params(), opt, clean_exec.method,
                             jax.random.PRNGKey(1))
    for b in _CursorPipeline(120):
        clean, cm = clean_exec.step(clean, b)
    assert abs(final - float(cm["loss"])) < 1.0


# ---------------------------------------------------------------------------
# guard x lane-ladder interplay (satellite 4): numeric de-escalation while
# the remote ascent lane is itself demoted under wire chaos — the two
# ladders act on different failure domains and recover independently
# ---------------------------------------------------------------------------

def test_guard_and_lane_ladder_recover_independently():
    from repro.engine import RemoteExecutor
    from repro.runtime import ExecutorConfig
    from repro.service.ascent_server import AscentServer
    from repro.service.netchaos import ChaosProxy, parse_faults

    server = AscentServer(_lin_loss)
    server.serve_in_thread()
    # hostile opening on the wire: the first four GRAD frames all die, which
    # trips the lane health detector and fails over to the local thread lane
    sched = parse_faults(
        "corrupt:GRAD:nth=1,corrupt:GRAD:nth=2,truncate:GRAD:nth=3,"
        "blackhole:GRAD:nth=4:duration_s=0.2", seed=5)
    proxy = ChaosProxy(server.address, sched)
    xcfg = ExecutorConfig(
        ascent_addr=proxy.addr, reconnect_backoff_s=0.05,
        max_staleness=3, lane_ladder=True,
        health_window=4, health_error_threshold=0.5, health_min_samples=2,
        health_stall_timeout_s=5.0,
        ladder_cooldown_steps=5, ladder_probation_steps=3,
        guard_update=True)                    # exercise the config override
    gcfg = GuardConfig(rho_scales=(1.0, 0.5), demote_after=2,
                       anomaly_window=4, probation_steps=3, cooldown_steps=5,
                       spike_min_samples=8, rollback=False)
    hist = []
    try:
        with RemoteExecutor(_lin_loss, MethodConfig(name="async_sam", rho=0.05,
                                                    ascent_fraction=0.5),
                            optim.sgd(0.1, momentum=0.9),
                            exec_cfg=xcfg) as ex:
            g = GuardedExecutor(ex, gcfg)
            lane = ex._inner._ladder
            state = g.init_state(_lin_params(), jax.random.PRNGKey(1))
            # NaN descent batches arrive while the wire is under attack:
            # numeric anomalies and lane faults overlap in time
            deadline = time.monotonic() + 120.0
            i = 0
            while True:
                state, m = g.step(state, _float_batch(i, nan=i in (8, 9)))
                hist.append(m)
                i += 1
                done = (i >= 40
                        and lane.failovers >= 1 and lane.recoveries >= 1
                        and g.ladder.failovers >= 1
                        and g.ladder.recoveries >= 1
                        and m["lane_state"] == 0.0
                        and m["guard_state"] == 0.0)
                if done:
                    break
                assert time.monotonic() < deadline and i < 2000, (
                    "no independent double recovery within deadline: "
                    f"lane=({lane.failovers},{lane.recoveries}) "
                    f"guard=({g.ladder.failovers},{g.ladder.recoveries})")
                time.sleep(0.015)
    finally:
        proxy.close()
        server.close()
    # both ladders moved, and said so in the registry keys
    assert max(m["lane_state"] for m in hist) >= 1
    assert max(m["guard_state"] for m in hist) >= 1
    assert max(m.get("steps_skipped", 0) for m in hist) >= 1
    assert proxy.fault_count() >= 4
    # losses on non-skip steps stayed finite throughout the overlap
    assert all(np.isfinite(m["loss"]) for m in hist
               if not m.get("update_skipped", 0))


def test_acceptance_same_injection_without_guard_diverges(tmp_path):
    """The counterfactual: identical injection, guard off — the NaN window
    poisons the params and the run never produces a finite loss again."""
    opt = optim.adamw(3e-3)
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5)
    ex = _MethodExec(mcfg, _lin_loss, opt)
    state = init_train_state(_lin_params(), opt, ex.method,
                             jax.random.PRNGKey(1))
    chaos = parse_numchaos(_SOAK_SPEC, seed=0)
    for b in _CursorPipeline(60, chaos):
        state, m = ex.step(state, b)
    assert not np.isfinite(float(m["loss"]))
    assert not np.isfinite(np.asarray(jax.device_get(state.params["w"]))).all()
