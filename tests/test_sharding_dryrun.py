"""Sharding rules + a miniature end-to-end dry-run (subprocess, 8 devices)."""
import pytest


def test_param_rules_basics(subprocess_py):
    out = subprocess_py("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.models.partitioning import make_rules, param_partition_spec

        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        rules = make_rules(mesh)
        # generic matmul weight: in->dp, out->model
        assert param_partition_spec('blocks/attn/wq', (8, 64, 64), rules) == \\
            P(None, ('data',), ('model',))
        # output projection transposes
        assert param_partition_spec('blocks/mlp/wo_mlp', (8, 64, 64), rules) == \\
            P(None, ('model',), ('data',))
        # embed: vocab->model, d->dp
        assert param_partition_spec('embedding/embed', (1000, 64), rules) == \\
            P(('model',), ('data',))
        # expert stack with E divisible -> EP
        assert param_partition_spec('blocks/moe/we_in', (8, 4, 64, 32), rules) == \\
            P(None, ('model',), ('data',), None)
        # expert stack with E NOT divisible -> TP over d_out
        assert param_partition_spec('blocks/moe/we_in', (8, 3, 64, 32), rules) == \\
            P(None, None, ('data',), ('model',))
        # norm scales replicate
        assert param_partition_spec('blocks/ln1/scale', (8, 64), rules) == P()
        # non-divisible dims are dropped (whisper vocab 51865)
        assert param_partition_spec('embedding/embed', (51865, 64), rules) == \\
            P(None, ('data',))
        print('RULES_OK')
    """, devices=8)
    assert "RULES_OK" in out


def test_mini_dryrun_train_and_decode(subprocess_py):
    """Full dry-run machinery on an 8-device host mesh with a reduced arch."""
    out = subprocess_py("""
        import dataclasses, jax
        from repro.configs import get_config
        from repro.core import MethodConfig
        from repro.launch.sharding import (batch_spec_tree, cache_spec_tree,
                                           state_spec_tree, to_named)
        from repro.launch.steps import (make_decode_step, make_train_setup)
        from repro.models import build_model, batch_spec, decode_batch_spec
        from repro.models.config import ShapeSpec
        from repro.models.partitioning import activation_sharding
        from repro.engine import mesh_context

        cfg = get_config('olmo-1b', reduced=True)
        bundle = build_model(cfg)
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        shape = ShapeSpec('mini_train', 'train', 64, 8)

        with mesh_context(mesh), activation_sharding(mesh):
            setup = make_train_setup(bundle, MethodConfig(n_microbatches=2))
            state_sds = jax.eval_shape(lambda: setup.init_state(
                bundle.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1)))
            batch_sds = batch_spec(cfg, shape, ascent_fraction=0.25)
            state_sh = to_named(state_spec_tree(state_sds, cfg, mesh), mesh)
            batch_sh = to_named(batch_spec_tree(batch_sds, mesh), mesh)
            c = jax.jit(setup.step_fn, in_shardings=(state_sh, batch_sh),
                        out_shardings=(state_sh, None), donate_argnums=(0,)
                        ).lower(state_sds, batch_sds).compile()
            from repro.engine import cost_analysis_dict
            assert cost_analysis_dict(c)['flops'] > 0
            print('TRAIN_COMPILED', int(c.memory_analysis().temp_size_in_bytes > 0))

            dshape = ShapeSpec('mini_decode', 'decode', 64, 8)
            step = make_decode_step(bundle)
            params_sds = jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0)))
            cache_sds = jax.eval_shape(lambda: bundle.init_cache(8, 64, pos=63))
            dbatch_sds = decode_batch_spec(cfg, dshape)
            params_sh = to_named(state_spec_tree(params_sds, cfg, mesh), mesh)
            cache_sh = to_named(cache_spec_tree(cache_sds, cfg, mesh), mesh)
            dbatch_sh = to_named(batch_spec_tree(dbatch_sds, mesh), mesh)
            c2 = jax.jit(step, in_shardings=(params_sh, cache_sh, dbatch_sh),
                         out_shardings=(None, cache_sh), donate_argnums=(1,)
                         ).lower(params_sds, cache_sds, dbatch_sds).compile()
            print('DECODE_COMPILED')
    """, devices=8)
    assert "TRAIN_COMPILED 1" in out
    assert "DECODE_COMPILED" in out


def test_sharded_training_matches_single_device(subprocess_py):
    """pjit-sharded AsyncSAM training equals unsharded training bit-for-bit
    (up to float summation order) on the same data."""
    out = subprocess_py("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.core import MethodConfig, make_method, init_train_state
        from repro import optim
        from repro.models import build_model, synth_batch
        from repro.launch.sharding import state_spec_tree, to_named
        from repro.models.partitioning import activation_sharding
        from repro.engine import mesh_context

        cfg = get_config('olmo-1b', reduced=True)
        bundle = build_model(cfg)
        mcfg = MethodConfig(name='async_sam', rho=0.02, ascent_fraction=0.5)
        method = make_method(mcfg)
        opt = optim.sgd(1e-2, momentum=0.9)
        params = bundle.init(jax.random.PRNGKey(0))
        batches = [synth_batch(cfg, 8, 16, jax.random.PRNGKey(i), 0.5)
                   for i in range(4)]

        def run(sharded):
            state = init_train_state(params, opt, method, jax.random.PRNGKey(1))
            step = method.make_step(bundle.loss_fn, opt)
            if sharded:
                mesh = jax.make_mesh((4, 2), ('data', 'model'))
                with mesh_context(mesh), activation_sharding(mesh):
                    sh = to_named(state_spec_tree(
                        jax.eval_shape(lambda: state), cfg, mesh), mesh)
                    state = jax.device_put(state, sh)
                    jstep = jax.jit(step, out_shardings=(sh, None))
                    for b in batches:
                        state, m = jstep(state, b)
            else:
                jstep = jax.jit(step)
                for b in batches:
                    state, m = jstep(state, b)
            return jax.device_get(state.params), float(m['loss'])

        p1, l1 = run(False)
        p8, l8 = run(True)
        import numpy as np
        err = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p8)))
        print('MAXERR', err, 'LOSSDIFF', abs(l1 - l8))
        assert err < 5e-4, err
        assert abs(l1 - l8) < 1e-3
    """, devices=8)
    assert "MAXERR" in out


def test_production_dryrun_cell_subprocess(subprocess_py):
    """The real 512-device production dry-run for one cheap cell."""
    out = subprocess_py("""
        from repro.launch.dryrun import run_cell
        r = run_cell('whisper-tiny', 'decode_32k', save=False, verbose=False)
        assert r.status == 'ok', r.note
        assert r.peak_memory_per_device < 16e9
        print('CELL_OK', r.n_collectives > 0)
    """, devices=512, timeout=560)
    assert "CELL_OK" in out
