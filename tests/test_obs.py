"""Observability layer: typed metric-key registry, tracker/sinks, the
Chrome-trace exporter + overlap report, the STATS protocol frame, and the
jsonl byte-compatibility contract with the pre-registry StalenessTelemetry.

`scripts/tier1.sh --obs` runs this file (after the metric-registry lint)
under a hard timeout with interpret-mode kernels.
"""
import importlib.util
import json
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.core import MethodConfig, slice_ascent_batch
from repro.data.synthetic import ClassificationTask
from repro.engine import (ElasticExecutor, Engine, FusedExecutor,
                          HeteroExecutor, RemoteExecutor, StalenessTelemetry)
from repro.obs import (ENGINE_METRIC_KEYS, ENGINE_OPTIONAL_METRIC_KEYS,
                       METRIC_KEYS, REGISTRY, JsonlSink, MemorySink, Tracker,
                       TraceEventSink, UnknownMetricError, current_tracker,
                       metric_key, registry_table, scalar_metrics,
                       use_tracker, validate_keys)
from repro.runtime import ChaosSchedule, ExecutorConfig, MeshEvent
from repro.service import protocol
from repro.service.ascent_server import AscentServer
from repro.service.client import fetch_pool_stats
from repro.service.protocol import (FrameType, ProtocolError,
                                    STATS_COUNTER_KEYS, decode_stats,
                                    encode_frame, encode_stats,
                                    stats_frame_bytes)
from repro.service.testing import mlp_init, mlp_loss

ROOT = pathlib.Path(__file__).resolve().parent.parent
TASK = ClassificationTask(n_classes=4, dim=8, seed=3)


def _loss(params, batch, rng):
    h = jnp.tanh(batch["x"] @ params["w1"])
    logits = h @ params["w2"]
    onehot = jax.nn.one_hot(batch["y"], logits.shape[-1])
    loss = -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))
    return loss, {"logits": logits}


def _params(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w1": jax.random.normal(k, (8, 32)) * 0.3,
            "w2": jax.random.normal(jax.random.fold_in(k, 1), (32, 4)) * 0.3}


def _batches(n, batch=64, frac=0.5):
    return [{**b, "ascent": slice_ascent_batch(b, frac)}
            for b in TASK.train_batches(batch, n)]


def _mcfg():
    return MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5)


def _overlap_mod():
    """benchmarks/ is not a package: import overlap_report from its path."""
    spec = importlib.util.spec_from_file_location(
        "overlap_report", ROOT / "benchmarks" / "overlap_report.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# registry: derived contract tuples, lookups, strict validation
# ---------------------------------------------------------------------------

def test_contract_tuples_derive_to_historical_values():
    # byte-for-byte the tuples engine/api.py used to hard-code — order is
    # load-bearing for the jsonl schema and every downstream consumer
    assert ENGINE_METRIC_KEYS == ("loss", "grad_norm", "tau", "perturbed")
    assert ENGINE_OPTIONAL_METRIC_KEYS == (
        "wire_bytes", "job_bytes", "grad_bytes", "rtt_s", "pool_depth",
        "pool_wait_s", "client_id", "mesh_devices", "resize_events",
        "resize_time_s", "lane_state", "lane_failovers", "lane_recoveries",
        "guard_state", "rho_scale", "steps_skipped", "nonfinite_count",
        "poison_rollbacks")
    # the engine re-export keeps old imports working
    from repro.engine import ENGINE_METRIC_KEYS as legacy
    assert legacy is ENGINE_METRIC_KEYS


def test_registry_lookup_and_validation():
    assert metric_key("tau").required and metric_key("tau").source == "lane"
    with pytest.raises(UnknownMetricError):
        metric_key("nonesuch")
    validate_keys(["loss", "tau", "step_time_s"])
    with pytest.raises(UnknownMetricError, match="bogus"):
        validate_keys(["loss", "bogus"])
    table = registry_table()
    assert all(f"`{k.name}`" in table for k in METRIC_KEYS)


def test_strict_memory_sink_rejects_unregistered_key():
    strict = MemorySink(strict=True)
    strict.log({"loss": 1.0, "tau": 1}, step=0)           # registered: fine
    with pytest.raises(UnknownMetricError):
        strict.log({"loss": 1.0, "made_up_key": 2.0}, step=1)
    assert len(strict.steps) == 1
    relaxed = MemorySink(strict=False)
    relaxed.log({"made_up_key": 2.0}, step=0)             # tolerated
    assert relaxed.steps == [(0, {"made_up_key": 2.0})]


def test_lint_script_passes_on_tree():
    r = subprocess.run([sys.executable,
                        str(ROOT / "scripts" / "lint_metric_registry.py")],
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# tracker: global install, counters/histograms, spans
# ---------------------------------------------------------------------------

def test_use_tracker_scoped_install_and_null_default():
    base = current_tracker()
    assert base.log({"loss": 1.0}, step=0) is None        # null: cheap no-op
    trk = Tracker([MemorySink()])
    with use_tracker(trk) as active:
        assert current_tracker() is trk is active
    assert current_tracker() is base


def test_tracker_counters_and_histogram_summary():
    trk = Tracker()
    for _ in range(3):
        trk.count("harvests")
    for v in (1.0, 2.0, 3.0, 4.0):
        trk.histogram("step_time_s", v)
    s = trk.summary()
    assert s["counters"] == {"harvests": 3}
    h = s["histograms"]["step_time_s"]
    assert (h["count"], h["min"], h["max"]) == (4, 1.0, 4.0)
    assert h["p50"] == 2.0 and h["p95"] == 3.0


def test_span_records_lane_args_and_survives_exceptions():
    sink = MemorySink()
    trk = Tracker([sink])
    with trk.span("descent_compute", lane="descent", step=7):
        pass
    with pytest.raises(RuntimeError):
        with trk.span("ascent_compute", lane="ascent-thread", gen=3):
            raise RuntimeError("boom")
    trk.span_at("ascent_exchange", lane="ascent-thread", t0=1.0, t1=1.5,
                tau=1)
    assert [s.name for s in sink.spans] == [
        "descent_compute", "ascent_compute", "ascent_exchange"]
    assert sink.spans_on("ascent")[0].args == {"gen": 3}
    assert sink.spans[2].duration_s == pytest.approx(0.5)
    assert sink.spans[0].args["step"] == 7


# ---------------------------------------------------------------------------
# jsonl: sink byte-compatible with the pre-registry StalenessTelemetry
# ---------------------------------------------------------------------------

def _golden_record(step, metrics, step_time_s):
    """The record the pre-tracker StalenessTelemetry.on_step built inline."""
    loss = metrics.get("loss")
    rec = {"step": int(step),
           "tau": int(metrics.get("tau", 0)),
           "perturbed": float(metrics.get("perturbed", 0.0)),
           "step_time_s": step_time_s,
           "loss": float(loss) if loss is not None else None}
    for key in ("wire_bytes", "job_bytes", "grad_bytes", "rtt_s",
                "pool_depth", "pool_wait_s", "client_id", "mesh_devices",
                "resize_events", "resize_time_s"):
        if key in metrics:
            rec[key] = float(metrics[key])
    return json.dumps(rec)


def test_jsonl_sink_byte_compatible_with_historical_schema(tmp_path):
    rows = [
        (0, {"loss": 0.5, "tau": 0, "perturbed": 0.0, "grad_norm": 1.0},
         0.0123),
        (1, {"loss": 0.4, "tau": 1, "perturbed": 1.0, "grad_norm": 0.9,
             "wire_bytes": 4096.0, "job_bytes": 3072.0, "grad_bytes": 1024.0,
             "rtt_s": 0.002}, 0.011),
        (2, {"tau": 2, "perturbed": 1.0, "pool_depth": 3.0,
             "pool_wait_s": 0.001, "client_id": 7.0, "mesh_devices": 4.0,
             "resize_events": 1.0, "resize_time_s": 0.2}, 0.0105),
    ]
    path = tmp_path / "telemetry.jsonl"
    sink = JsonlSink(path)
    for step, metrics, dt in rows:
        sink.log({**metrics, "step_time_s": dt}, step=step)
    sink.close()
    got = path.read_text().splitlines()
    want = [_golden_record(step, m, dt) for step, m, dt in rows]
    assert got == want                      # bytes, field order included


def test_staleness_telemetry_streams_through_jsonl_sink(tmp_path):
    path = tmp_path / "tau.jsonl"
    tel = StalenessTelemetry(print_summary=False, jsonl_path=path)
    with FusedExecutor(_loss, _mcfg(), optim.sgd(0.1, momentum=0.9),
                       donate=False) as ex:
        state = ex.init_state(_params(), jax.random.PRNGKey(1))
        Engine(ex, _batches(4), [tel]).fit(state, 4)
    recs = [json.loads(line) for line in path.read_text().splitlines()]
    assert len(recs) == 4
    assert list(recs[0])[:5] == ["step", "tau", "perturbed", "step_time_s",
                                 "loss"]
    assert [r["step"] for r in recs] == [1, 2, 3, 4]


# ---------------------------------------------------------------------------
# every executor logs registered keys through the engine's tracker route
# ---------------------------------------------------------------------------

def _fit_with_strict_tracker(ex, n, events=None):
    sink = MemorySink(strict=True)     # raises on any unregistered write
    with ex:
        state = ex.init_state(_params(), jax.random.PRNGKey(1))
        Engine(ex, _batches(n)).fit(state, n, events=events,
                                    tracker=Tracker([sink]))
    return sink


@pytest.mark.parametrize("kind", ["fused", "hetero", "elastic"])
def test_executors_emit_registered_keys_every_step(kind):
    opt = optim.sgd(0.1, momentum=0.9)
    if kind == "fused":
        ex = FusedExecutor(_loss, _mcfg(), opt, donate=False)
    elif kind == "hetero":
        ex = HeteroExecutor(_loss, _mcfg(), opt,
                            exec_cfg=ExecutorConfig(lockstep=True))
    else:
        ex = ElasticExecutor(HeteroExecutor(_loss, _mcfg(), opt))
    events = (ChaosSchedule([MeshEvent(step=3, devices=4)])
              if kind == "elastic" else None)
    sink = _fit_with_strict_tracker(ex, 6, events=events)
    assert len(sink.steps) == 6
    for _, metrics in sink.steps:
        assert set(ENGINE_METRIC_KEYS) <= set(metrics)
        assert "step_time_s" in metrics
    if kind == "elastic":
        assert all(m["mesh_devices"] >= 1.0 for _, m in sink.steps)
        resizes = [s for s in sink.spans if s.name == "mesh_resize"]
        assert resizes and resizes[0].lane == "elastic"
        assert resizes[0].args["devices"] == 4
    if kind == "hetero":
        lanes = {s.lane for s in sink.spans}
        assert "descent" in lanes and "ascent-thread" in lanes


def test_remote_executor_registered_keys_and_live_stats_scrape():
    server = AscentServer(mlp_loss)
    server.serve_in_thread()
    try:
        xcfg = ExecutorConfig(lockstep=True, ascent_addr=server.address)
        sink = MemorySink(strict=True)
        with RemoteExecutor(mlp_loss, _mcfg(), optim.sgd(0.1, momentum=0.9),
                            exec_cfg=xcfg) as ex:
            state = ex.init_state(mlp_init(jax.random.PRNGKey(0)),
                                  jax.random.PRNGKey(1))
            Engine(ex, _batches(6)).fit(state, 6, tracker=Tracker([sink]))
            # scrape while the training client is still attached
            snap = fetch_pool_stats(server.address)
        for _, metrics in sink.steps:
            assert set(ENGINE_METRIC_KEYS) <= set(metrics)
        assert any("wire_bytes" in m for _, m in sink.steps)
        rpc = [s for s in sink.spans if s.name == "ascent_rpc"]
        assert rpc and all(s.args["wire_bytes"] > 0 for s in rpc)
        # the STATS snapshot saw the fit: exchanges counted, the training
        # client listed (the observer scrape itself excluded), one shadow
        assert snap["exchanges"] >= 5
        assert snap["workers"] >= 1 and snap["queue_capacity"] >= 1
        assert len(snap["clients_detail"]) == 1
        assert snap["clients_detail"][0]["exchanges"] >= 5
        # one canonical shadow for the client's attach scope (gen is the
        # *mesh* generation — 0 until a resize)
        assert len(snap["shadows_detail"]) == 1
        assert snap["shadows_detail"][0]["scope_uid"] > 0
        # exact wire accounting, measured == modeled like JOB/GRAD frames
        frame = encode_frame(FrameType.STATS, encode_stats(snap))
        assert len(frame) == stats_frame_bytes(len(snap["clients_detail"]),
                                               len(snap["shadows_detail"]))
    finally:
        server.close()


# ---------------------------------------------------------------------------
# STATS frame: exact bytes, roundtrip, hostile payloads
# ---------------------------------------------------------------------------

def test_stats_roundtrip_and_exact_modeled_bytes():
    snap = {"workers": 2, "queue_capacity": 32, "queue_depth": 5,
            **{k: i * 3 for i, k in enumerate(STATS_COUNTER_KEYS)},
            "clients_detail": [
                {"uid": 7, "group_uid": 9, "exchanges": 41,
                 "last_wait_s": 0.125},
                {"uid": 8, "group_uid": 0, "exchanges": 2,
                 "last_wait_s": 0.0}],
            "shadows_detail": [
                {"scope_uid": 9, "gen": 12, "sync": 3, "seq": 40,
                 "replays": 1}]}
    payload = encode_stats(snap)
    assert decode_stats(payload) == snap
    frame = encode_frame(FrameType.STATS, payload)
    assert len(frame) == stats_frame_bytes(2, 1)
    # empty pool: fixed layout only
    empty = decode_stats(encode_stats({}))
    assert empty["clients_detail"] == [] and empty["shadows_detail"] == []
    assert len(encode_frame(FrameType.STATS, encode_stats({}))) \
        == stats_frame_bytes(0, 0)


def test_stats_decode_rejects_hostile_payloads():
    good = encode_stats({})
    with pytest.raises(ProtocolError, match="version"):
        decode_stats(bytes([99]) + good[1:])
    with pytest.raises(ProtocolError, match="trailing"):
        decode_stats(good + b"\x00")
    with pytest.raises(ProtocolError, match="shorter"):
        decode_stats(good[:8])
    # announced client count overruns the actual bytes
    truncated = bytearray(good)
    truncated[-8:-4] = (5).to_bytes(4, "big")    # n_clients=5, no entries
    with pytest.raises(ProtocolError, match="overruns"):
        decode_stats(bytes(truncated))
    assert protocol.PROTO_REVISION >= protocol.STATS_REVISION == 4


# ---------------------------------------------------------------------------
# trace exporter + overlap report: the acceptance criterion end-to-end
# ---------------------------------------------------------------------------

def test_hetero_lockstep_trace_is_perfetto_loadable_with_overlap(tmp_path):
    trace_path = tmp_path / "overlap.json"
    sink = TraceEventSink(trace_path)
    with HeteroExecutor(_loss, _mcfg(), optim.sgd(0.1, momentum=0.9),
                        exec_cfg=ExecutorConfig(lockstep=True)) as ex:
        state = ex.init_state(_params(), jax.random.PRNGKey(1))
        with Tracker([sink]) as trk:
            Engine(ex, _batches(12)).fit(state, 12, tracker=trk)
    trace = json.loads(trace_path.read_text())
    evs = trace["traceEvents"]
    # structure Perfetto needs: one pid, named tracks, X spans with ts/dur
    lanes = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"descent", "ascent-thread"} <= lanes
    spans = [e for e in evs if e["ph"] == "X"]
    assert spans and all(e["ts"] >= 0 and e["dur"] >= 0 for e in spans)
    assert {e["name"] for e in spans} >= {
        "train_step", "descent_compute", "ascent_compute", "ascent_exchange"}
    counters = {e["name"] for e in evs if e["ph"] == "C"}
    assert {"loss", "tau"} <= counters
    # and the paper's claim: perturbation time hides under descent compute
    report = _overlap_mod().compute_overlap(trace)
    assert report["steps"] == 12
    assert report["ascent_busy_s"] > 0
    assert report["hidden_fraction"] > 0
    assert report["step_time_p95_s"] >= report["step_time_p50_s"] > 0


def test_overlap_math_on_synthetic_trace():
    mod = _overlap_mod()
    mk = lambda name, ts, dur: {"name": name, "ph": "X", "ts": ts,  # noqa
                                "dur": dur, "cat": "x", "pid": 1, "tid": 1}
    trace = {"traceEvents": [
        mk("descent_compute", 0, 100), mk("descent_compute", 200, 100),
        mk("ascent_compute", 50, 100),     # 50us under descent of 100us busy
        mk("ascent_compute", 400, 50),     # fully exposed
        mk("train_step", 0, 120), mk("train_step", 200, 110),
    ]}
    rep = mod.compute_overlap(trace)
    assert rep["ascent_busy_s"] == pytest.approx(150e-6)
    assert rep["hidden_s"] == pytest.approx(50e-6)
    assert rep["hidden_fraction"] == pytest.approx(50 / 150)
    assert rep["steps"] == 2
    assert rep["step_time_p50_s"] == pytest.approx(110e-6)
    # no ascent work at all -> fraction is 0, not a ZeroDivisionError
    assert mod.compute_overlap({"traceEvents": []})["hidden_fraction"] == 0.0


def test_scalar_metrics_filters_to_floatable():
    out = scalar_metrics({"loss": jnp.float32(0.5), "tau": 1,
                          "logits": jnp.zeros((4, 4)), "note": "skip"})
    assert out == {"loss": 0.5, "tau": 1.0}
    assert REGISTRY["loss"].trace_counter
